"""Error-feedback gradient compression for slow (DCN/pod-axis) links.

int8 quantization with residual error feedback: the de/re-quantization error
is carried in fp32 state and added back before the next compression, so the
compressed SGD trajectory tracks the exact one (Seide et al. / EF-SGD).
Used on the `pod` axis where DCN bandwidth (~25 GB/s/host) is the gradient
bottleneck; ICI-axis reductions stay exact.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.optim.quantized import QLeaf


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress(grads, error_state):
    """-> (quantized grads pytree of QLeaf, new corrected fp32 reference)."""
    corrected = jax.tree.map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error_state)
    q = jax.tree.map(lambda c: QLeaf.from_dense(c, signed=True), corrected)
    return q, corrected


def decompress_and_update_error(q, corrected):
    """-> (dequantized grads, new error residuals)."""
    deq = jax.tree.map(lambda v: v.dense(), q,
                       is_leaf=lambda x: isinstance(x, QLeaf))
    new_err = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return deq, new_err


def compressed_allreduce(grads, error_state, axis_name: str):
    """Inside shard_map: int8 all-reduce over `axis_name` with error
    feedback.  Returns (averaged grads fp32, new error state)."""
    q, corrected = compress(grads, error_state)
    deq, new_err = decompress_and_update_error(q, corrected)
    summed = jax.tree.map(lambda d: jax.lax.pmean(d, axis_name), deq)
    return summed, new_err
