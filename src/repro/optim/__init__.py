from repro.optim.optimizers import (adamw, sgd_momentum, OptState,
                                    apply_updates, global_norm, clip_by_global_norm)
from repro.optim.schedules import cosine_schedule, linear_warmup
from repro.optim.quantized import QuantizedMoments, quantize_moments, dequantize_moments
