"""LR schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(peak_lr: float, warmup_steps: int):
    def fn(step):
        return peak_lr * jnp.minimum(1.0, step.astype(jnp.float32)
                                     / max(warmup_steps, 1))
    return fn


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1):
    def fn(step):
        t = step.astype(jnp.float32)
        warm = t / max(warmup_steps, 1)
        prog = jnp.clip((t - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(t < warmup_steps, warm, cos)
    return fn
