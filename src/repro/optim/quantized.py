"""Int8 optimizer-state quantization (blockwise absmax, Adam moments at
1 byte each) — the memory trick that fits 480B/671B-param training states on
a 256-chip pod (EXPERIMENTS.md §Dry-run).

Each moment leaf becomes a ``QLeaf`` pytree node (int8 payload + fp32
per-block scales; shape/sign static) so the whole optimizer state stays a
jit-compatible pytree that shards like the parameters.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


@jax.tree_util.register_pytree_node_class
class QLeaf:
    def __init__(self, q, scale, shape, signed):
        self.q = q              # int8 (n_blocks, BLOCK)
        self.scale = scale      # fp32 (n_blocks, 1)
        self.shape = tuple(shape)
        self.signed = bool(signed)

    def tree_flatten(self):
        return (self.q, self.scale), (self.shape, self.signed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, aux[0], aux[1])

    @classmethod
    def from_dense(cls, x: jax.Array, signed: bool) -> "QLeaf":
        flat = x.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % BLOCK
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, BLOCK)
        absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) + 1e-12
        if signed:
            q = jnp.clip(jnp.round(blocks / absmax * 127), -127, 127)
        else:
            q = jnp.clip(jnp.round(blocks / absmax * 255) - 128, -128, 127)
        return cls(q.astype(jnp.int8), absmax, x.shape, signed)

    def dense(self) -> jax.Array:
        if self.signed:
            blocks = self.q.astype(jnp.float32) / 127.0 * self.scale
        else:
            blocks = (self.q.astype(jnp.float32) + 128.0) / 255.0 * self.scale
        n = math.prod(self.shape) if self.shape else 1
        return blocks.reshape(-1)[:n].reshape(self.shape)


QuantizedMoments = Any  # pytree with QLeaf leaves


def _is_qleaf(x):
    return isinstance(x, QLeaf)


def quantize_moments(tree, *, signed: bool):
    return jax.tree.map(lambda x: QLeaf.from_dense(x, signed), tree)


def dequantize_moments(tree):
    return jax.tree.map(lambda q: q.dense(), tree, is_leaf=_is_qleaf)
