"""Optimizers (pure-JAX pytree transforms; optax is not available offline).

``adamw``/``sgd_momentum`` return (init_fn, update_fn) pairs.  State layout
mirrors the param tree so the ASA param PartitionSpecs apply verbatim to the
optimizer state (sharded identically — ZeRO follows for free under HP).

``adamw(..., quantized=True)`` stores moments int8 (optim/quantized.py):
6 bytes/param total instead of 16 — the preset the giant-MoE configs use.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.quantized import dequantize_moments, quantize_moments


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment  (or QuantizedMoments)
    nu: Any          # second moment (or QuantizedMoments)
    extra: Any = None


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw(lr: Callable | float, *, b1=0.9, b2=0.95, eps=1e-8,
          weight_decay=0.1, quantized: bool = False):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        mu, nu = zeros, jax.tree.map(jnp.copy, zeros)
        if quantized:
            mu = quantize_moments(mu, signed=True)
            nu = quantize_moments(nu, signed=False)
        return OptState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state: OptState, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu_f = dequantize_moments(state.mu) if quantized else state.mu
        nu_f = dequantize_moments(state.nu) if quantized else state.nu
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu_f = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, mu_f, g32)
        nu_f = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, nu_f, g32)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** t), mu_f)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** t), nu_f)
        lr_t = lr_fn(step)
        upd = jax.tree.map(
            lambda m, v, p: -lr_t * (m / (jnp.sqrt(v) + eps)
                                     + weight_decay * p.astype(jnp.float32)),
            mu_hat, nu_hat, params)
        mu_s = quantize_moments(mu_f, signed=True) if quantized else mu_f
        nu_s = quantize_moments(nu_f, signed=False) if quantized else nu_f
        return upd, OptState(step, mu_s, nu_s)

    return init, update


def sgd_momentum(lr: Callable | float, *, momentum=0.9, weight_decay=0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), mom, None)

    def update(grads, state: OptState, params):
        step = state.step + 1
        mu = jax.tree.map(
            lambda m, g, p: momentum * m + g.astype(jnp.float32)
            + weight_decay * p.astype(jnp.float32),
            state.mu, grads, params)
        lr_t = lr_fn(step)
        upd = jax.tree.map(lambda m: -lr_t * m, mu)
        return upd, OptState(step, mu, None)

    return init, update


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)
