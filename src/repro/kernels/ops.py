"""Jit'd wrappers exposing the Pallas kernels with model-layer layouts.

The model passes (B, S, H, D) tensors; the kernels want head-major layouts.
On non-TPU backends the kernels run in interpret mode (CPU validation); the
production TPU path drops the same calls onto the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


@functools.partial(jax.jit, static_argnames=("scale", "causal"))
def flash_attention(q, k, v, *, scale=None, causal=True):
    """q: (B,S,H,D); k,v: (B,T,Hkv,D) with Hkv | H -> (B,S,H,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if Hkv != H:                       # GQA: expand kv heads to q heads
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention(qt, kt, vt, scale=scale, causal=causal)
    return out.transpose(0, 2, 1, 3)


def ssd_scan(cfg, x, Bm, Cm, dt, a, h0=None):
    """Model-layer adapter: x (B,S,H,P), Bm/Cm (B,S,G,N) group-mapped to
    heads, dt/a (B,S,H).  Returns (y (B,S,H,P) f32, h_final (B,H,P,N))."""
    B, S, H, P = x.shape
    G = Bm.shape[2]
    head_group = jnp.arange(H) // (H // G)
    Bh = Bm[:, :, head_group, :].transpose(0, 2, 1, 3)   # (B,H,S,N)
    Ch = Cm[:, :, head_group, :].transpose(0, 2, 1, 3)
    xt = x.transpose(0, 2, 1, 3)                          # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)
    at = a.transpose(0, 2, 1)
    y, h_final = _ssd.ssd_scan(xt, Bh, Ch, dtt, at, h0=h0, chunk=cfg.chunk)
    return y.transpose(0, 2, 1, 3), h_final


@jax.jit
def rmsnorm(x, scale):
    return _rn.rmsnorm(x, scale)
