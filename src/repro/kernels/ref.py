"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, scale: float, causal: bool = True):
    """q: (B,S,H,D); k,v: (B,T,H,D) (kv already expanded to q heads).
    fp32 softmax, dense logits."""
    B, S, H, D = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssd_scan_ref(x, Bm, Cm, dt, a, h0=None):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    x: (B,S,H,P); Bm,Cm: (B,S,H,N) (already per-head); dt,a: (B,S,H).
    h_t = exp(a_t) h_{t-1} + dt_t * x_t ⊗ B_t ;  y_t = C_t · h_t
    Returns (y (B,S,H,P) f32, h_final (B,H,P,N) f32).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, b_t, c_t, dt_t, a_t = inp
        h = h * jnp.exp(a_t)[:, :, None, None] + \
            jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t, b_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (x, Bm, Cm, dt, a))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h_final


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)
