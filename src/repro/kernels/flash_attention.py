"""Flash attention — Pallas TPU kernel.

Online-softmax tiling: grid (B, H, num_q_blocks, num_kv_blocks) with the kv
axis innermost (sequential).  Per-invocation VMEM working set:

    q     (block_q, d)     — revisited across the kv axis (index_map pins j)
    k, v  (block_k, d)     — streamed HBM->VMEM per kv block
    acc   (block_q, d) f32 + m,l (block_q,) f32 scratch — persist across kv

Causal blocks above the diagonal are skipped with pl.when (the MXU never
sees them — this is the 2x-flops win over the XLA fallback path).
block_q = block_k = 128 keeps every matmul dim MXU-aligned (128x128).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  seq_q: int, seq_k: int):
    i = pl.program_id(2)            # q block
    j = pl.program_id(3)            # kv block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k
    # causal: skip blocks entirely above the diagonal
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_k
        if causal:
            mask = mask & (q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, scale: float | None = None,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool | None = None):
    """q: (B,H,S,D); k,v: (B,H,T,D) — kv pre-expanded to q heads.
    Returns (B,H,S,D) in q.dtype."""
    B, H, S, D = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    bq, bk = min(block_q, S), min(block_k, T)
    pad_q, pad_k = (-S) % bq, (-T) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = q.shape[2] // bq, k.shape[2] // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=bq, block_k=bk,
        seq_q=S, seq_k=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max m
            pltpu.VMEM((bq,), jnp.float32),        # running sum l
            pltpu.VMEM((bq, D), jnp.float32),      # accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S] if pad_q else out
