"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid (B, H, num_chunks), chunk axis innermost/sequential; the recurrent
state h (P, N) lives in VMEM scratch and persists across the chunk axis.
Per chunk the intra-chunk quadratic term (Q x Q decay-weighted scores) runs
on the MXU; the inter-chunk term applies the carried state.  Q = chunk = 128
keeps the score matmul MXU-shaped.

Inputs are pre-mapped per head (groups broadcast to heads by ops.py):
    x  (B, H, S, P)   dt,a (B, H, S)   Bm,Cm (B, H, S, N)
Outputs: y (B, H, S, P) f32, h_final (B, H, P, N) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, h0_ref,
                y_ref, hout_ref, h_ref, *, chunk: int):
    c_idx = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)          # (Q,)

    cum = jnp.cumsum(a)                          # (Q,)
    # intra-chunk: scores[i,j] = (C_i . B_j) dt_j exp(cum_i - cum_j), j <= i
    seg = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(iota_i >= iota_j, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    scores = cb * decay * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)

    # inter-chunk: y_i += exp(cum_i) * C_i . h_prev
    h = h_ref[...]                               # (P, N)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (Q,N)x(P,N)^T -> (Q,P)

    # state update: h' = exp(cum_Q) h + sum_j exp(cum_Q - cum_j) dt_j x_j B_j^T
    w = (jnp.exp(cum[-1] - cum) * dt)[:, None] * x              # (Q,P)
    h_new = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        w, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (P,N)
    h_ref[...] = h_new
    y_ref[0, 0] = y

    @pl.when(c_idx == nc - 1)
    def _final():
        hout_ref[0, 0] = h_new


def ssd_scan(x, Bm, Cm, dt, a, h0=None, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool | None = None):
    """See module docstring for shapes."""
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:  # a=0, dt=0 padding leaves the state untouched
        def padf(t):
            return jnp.pad(t, [(0, 0), (0, 0), (0, pad)]
                           + [(0, 0)] * (t.ndim - 3))
        x, Bm, Cm, dt, a = map(padf, (x, Bm, Cm, dt, a))
    nc = x.shape[2] // Q
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc * Q, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, Bm, Cm, dt, a, h0)
    return y[:, :, :S], h_final
