"""Profiling — ASA Algorithm 1 lines 6-7 and the re-profile trigger (21-23).

Two layers:
  * ComponentProfiler — measures wall-time of jitted per-component apply fns
    (initial profiling phase).  On CPU this measures the smoke-scale configs;
    on TPU the same harness times the real blocks.  Measurements are turned
    into *calibration factors* (measured / predicted) for the cost model.
  * StepMonitor — EMA of live step times; signals drift (paper: "if
    communication patterns changed significantly -> re-profile").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax


@dataclasses.dataclass
class ProfileResult:
    name: str
    mean_s: float
    n: int


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


class ComponentProfiler:
    """Times per-component jitted fns and derives calibration factors."""

    def __init__(self):
        self.measurements: dict[str, ProfileResult] = {}

    def profile(self, name: str, fn: Callable, *args,
                iters: int = 5) -> ProfileResult:
        mean = time_fn(jax.jit(fn), *args, iters=iters)
        res = ProfileResult(name, mean, iters)
        self.measurements[name] = res
        return res

    def calibration(self, predicted: dict[str, float]) -> dict[str, float]:
        """measured/predicted per component (1.0 when unmeasured)."""
        out = {}
        for name, pred in predicted.items():
            m = self.measurements.get(name)
            if m is not None and pred > 0:
                out[name] = max(m.mean_s / pred, 1e-3)
        return out


class StepMonitor:
    """EMA step-time drift detector -> re-profile trigger.

    Train-time use: the trainer feeds step wall times and re-plans when
    ``update`` returns True.  Serve-time use: the continuous-batching
    engine feeds every ``step()`` duration and exports ``ema`` /
    ``drift_fraction()`` as telemetry gauges (``step_time_ema_s`` /
    ``step_time_drift``) plus a ``replan_triggers`` counter — the
    re-profile signal the adaptive serving scheduler (ROADMAP item 3)
    subscribes to.
    """

    def __init__(self, alpha: float = 0.1, drift_threshold: float = 0.25,
                 min_steps: int = 20):
        self.alpha = alpha
        self.threshold = drift_threshold
        self.min_steps = min_steps
        self.ema: Optional[float] = None
        self.baseline: Optional[float] = None
        self.steps = 0

    def update(self, step_time_s: float) -> bool:
        """Record one step; returns True when drift warrants re-planning."""
        self.steps += 1
        self.ema = (step_time_s if self.ema is None
                    else (1 - self.alpha) * self.ema + self.alpha * step_time_s)
        if self.baseline is None and self.steps >= self.min_steps:
            self.baseline = self.ema
        if self.baseline is None or self.steps < self.min_steps:
            return False
        drift = abs(self.ema - self.baseline) / self.baseline
        if drift > self.threshold:
            self.baseline = self.ema      # re-arm after trigger
            return True
        return False

    def drift_fraction(self) -> Optional[float]:
        """Current |ema - baseline| / baseline, or None before the
        baseline exists — the live drift gauge telemetry exports."""
        if self.baseline is None or self.ema is None:
            return None
        return abs(self.ema - self.baseline) / self.baseline
