"""ASA cost model — t_comp / t_comm / mem per (component, strategy)
(paper §III-C), re-expressed for a (pod, data, model) TPU mesh.

Two operating modes (DESIGN.md §4):
  faithful=True  — the paper's model: per-component computation + strategy
                   communication terms only (no transition/resharding costs).
  faithful=False — adds activation-resharding costs at strategy boundaries,
                   pod-axis (DCN) gradient reduction, and bandwidth-bound
                   compute (max(flops, HBM) per component).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import hardware as HW
from repro.core.components import Component
from repro.core.strategy import Strategy

PARAM_BYTES = 2       # bf16 params
GRAD_BYTES = 4        # fp32 gradient reduction
OPT_BYTES = 12        # AdamW: fp32 m + v + master


@dataclasses.dataclass(frozen=True)
class MeshShape:
    data: int
    model: int
    pod: int = 1

    @property
    def chips(self):
        return self.data * self.model * self.pod


@dataclasses.dataclass
class CostTerms:
    t_comp: float
    t_comm: float
    mem_params: float      # per-device bytes: params + grads + optimizer
    mem_act: float         # per-device bytes: activations / KV cache

    @property
    def time(self):
        return self.t_comp + self.t_comm


@dataclasses.dataclass
class CostModel:
    hw: HW.HardwareProfile
    mesh: MeshShape
    mode: str = "train"            # train | prefill | decode
    faithful: bool = True
    remat: str = "selective"       # none | selective | full
    microbatches: int = 1          # grad-accumulation chunks (train act memory)
    seq_sharded: bool = False      # Megatron-SP: layer-boundary activations
                                   # sharded over `model` on the seq axis
    fs_allowed: bool = True        # FS requires global_batch % chips == 0
    moe_ep: bool = False           # EP-major MoE: experts over `data`,
                                   # expert-FF over `model` (a2a dispatch)
    opt_bytes_per_param: float = OPT_BYTES
    grad_bytes: float = GRAD_BYTES
    param_bytes: float = PARAM_BYTES
    # per-component measured-time calibration (profiler feedback), name->factor
    calibration: Optional[dict] = None

    # activation-memory multiplier per remat policy (how many activation-sized
    # tensors a block keeps for backward; calibrated against dry-run
    # memory_analysis — "full" still stores the bf16 layer-input stack plus
    # XLA's hoisted f32 convert of it, ~3 act-sized tensors)
    _REMAT_FACTOR = {"none": 16.0, "selective": 8.0, "full": 3.0}

    # ------------------------------------------------------------------
    def component_cost(self, c: Component, s: Strategy, *,
                       uniform: bool = False) -> CostTerms:
        """Cost of running component `c` under strategy `s`.

        uniform=True evaluates the strategy as a *global static* scheme
        (baselines): DP then shards batch over every mesh axis.
        """
        m = self.mesh
        train = self.mode == "train"
        eff_flops = self.hw.peak_flops * self.hw.matmul_efficiency

        # ---- compute ----------------------------------------------------
        flops = c.total_flops_fwd * (3.0 if train else 1.0)
        if s == Strategy.DP:
            # DP-full: batch over all chips (uniform) or over data axis with
            # the model axis idle (mixed assignment, replicated compute).
            denom = m.chips if uniform else m.data * m.pod * (
                m.model if uniform else 1)
        else:
            denom = m.chips
        t_comp = flops / denom / eff_flops

        # params resident per device under s (HP/FS: ZeRO over data+pod/all)
        shard = {Strategy.DP: 1,
                 Strategy.MP: m.model,
                 Strategy.HP: m.model * m.data * m.pod,
                 Strategy.FS: m.chips}[s]
        if self.moe_ep and c.moe_a2a_bytes > 0 and s in (Strategy.MP,
                                                         Strategy.HP):
            shard = m.data * m.model    # EP-major: E@data x FF@model
        p_local = c.total_params * self.param_bytes / shard

        if not self.faithful:
            # bandwidth-bound floor: reading weights + activations from HBM
            bytes_touched = p_local + c.act_bytes * c.count / (m.data * m.pod)
            t_comp = max(t_comp, bytes_touched / self.hw.hbm_bw)

        if self.calibration and c.name in self.calibration:
            t_comp *= self.calibration[c.name]

        # ---- communication ----------------------------------------------
        t_comm = 0.0
        act_local = c.act_bytes / (m.data * m.pod)     # batch-sharded activation
        is_moe = c.moe_a2a_bytes > 0
        if train:
            gbytes = c.total_params * self.grad_bytes
            if s == Strategy.FS:
                # ZeRO-3 over all chips: ag(bf16 params) fwd + bwd + rs(grads)
                # — gathers repeat per microbatch (grad accumulation)
                pb = c.total_params * self.param_bytes
                t_comm += 2 * self.microbatches * HW.allgather_time(
                    pb, m.chips, self.hw.link_bw)
                t_comm += HW.reducescatter_time(gbytes, m.chips,
                                                self.hw.link_bw)
            elif s == Strategy.DP:
                n = m.chips if uniform else m.data
                t_comm += HW.ring_allreduce_time(gbytes, n, self.hw.link_bw)
            elif s == Strategy.MP:
                t_comm += HW.ring_allreduce_time(gbytes / m.model, m.data,
                                                 self.hw.link_bw)
            elif is_moe and self.moe_ep:
                # EP-major: dispatch/combine a2a only (counted below);
                # grads stay fully sharded — reduce only router/shared bits
                t_comm += HW.ring_allreduce_time(
                    gbytes / (m.model * m.data), m.data, self.hw.link_bw)
            elif is_moe:
                # HP for MoE = EP over `model` x expert-tensor over `data`:
                # partial-sum all-reduces of the expert outputs over `data`
                # (3x: fwd + bwd wrt act + bwd wrt weights) — no ZeRO gather.
                t_comm += 3 * HW.ring_allreduce_time(act_local, m.data,
                                                     self.hw.link_bw)
                t_comm += HW.ring_allreduce_time(
                    gbytes / (m.model * m.data), m.data, self.hw.link_bw)
            else:  # HP / ZeRO-3: ag fwd + ag bwd + rs grads over data (+pod)
                pb = c.total_params * self.param_bytes / m.model
                t_comm += 2 * self.microbatches * HW.allgather_time(
                    pb, m.data, self.hw.link_bw)
                t_comm += HW.reducescatter_time(
                    c.total_params * self.grad_bytes / m.model, m.data,
                    self.hw.link_bw)
                if m.pod > 1:   # gather the pod-resident shard over DCN
                    t_comm += 2 * self.microbatches * HW.allgather_time(
                        pb / m.data, m.pod, self.hw.dcn_bw or self.hw.link_bw)
            if not self.faithful and m.pod > 1:
                # pod-axis (DCN) gradient reduction of the local shard
                t_comm += HW.ring_allreduce_time(
                    gbytes / shard, m.pod, self.hw.dcn_bw or self.hw.link_bw)
        else:
            if s == Strategy.FS:                  # gathers weights per step
                t_comm += HW.allgather_time(c.total_params * self.param_bytes,
                                            m.chips, self.hw.link_bw)
            elif s == Strategy.HP and not is_moe:  # ZeRO-3 gathers per step
                pb = c.total_params * self.param_bytes / m.model
                t_comm += HW.allgather_time(pb, m.data, self.hw.link_bw)
            elif s == Strategy.HP and is_moe:
                t_comm += HW.ring_allreduce_time(act_local, m.data,
                                                 self.hw.link_bw)

        if s in (Strategy.MP, Strategy.HP):
            # model-axis activation all-reduces (fwd; x3 for train incl. bwd);
            # sequence parallelism replaces each all-reduce with
            # reduce-scatter + all-gather == same ring bytes, half the
            # redundant traffic => 0.5x effective
            sp = 0.5 if self.seq_sharded else 1.0
            n_ar = c.n_model_allreduce * c.count * (3.0 if train else 1.0)
            t_comm += sp * n_ar * HW.ring_allreduce_time(act_local, m.model,
                                                         self.hw.link_bw)
            if c.moe_a2a_bytes:
                a2a = c.moe_a2a_bytes * c.count / (m.data * m.pod)
                t_comm += (3.0 if train else 1.0) * HW.alltoall_time(
                    a2a, m.model, self.hw.link_bw)

        # ---- memory -------------------------------------------------------
        mem_params = p_local * (1 + (self.grad_bytes + self.opt_bytes_per_param)
                                / self.param_bytes if train else 1)
        if train:
            # only the live microbatch's activations are resident (grad accum)
            batch_shards = m.chips if s == Strategy.FS else m.data * m.pod
            mem_act = c.act_bytes * c.count / batch_shards * \
                self._REMAT_FACTOR[self.remat] / self.microbatches
            if s in (Strategy.MP, Strategy.HP) and (
                    self.seq_sharded or c.kind in ("embed", "head")):
                # embed/head activations are the vocab-sharded logits under
                # MP/HP; other layers shard only with sequence parallelism
                mem_act /= m.model
        else:
            kv_shard = m.model if s in (Strategy.MP, Strategy.HP) else 1
            mem_act = c.kv_bytes * c.count / (m.data * m.pod) / kv_shard
        return CostTerms(t_comp, t_comm, mem_params, mem_act)

    # ------------------------------------------------------------------
    def transition_cost(self, prev: Strategy, nxt: Strategy,
                        act_bytes: float) -> float:
        """Activation resharding at a strategy boundary (optimized mode only):
        DP-full <-> MP/HP implies batch-axis redistribution (all-to-all)."""
        if self.faithful or prev == nxt:
            return 0.0
        if Strategy.DP in (prev, nxt):
            return HW.alltoall_time(act_bytes / (self.mesh.data * self.mesh.pod),
                                    self.mesh.model, self.hw.link_bw)
        return 0.0

    # ------------------------------------------------------------------
    def assignment_cost(self, comps: list[Component],
                        assignment: dict[str, Strategy], *,
                        uniform: bool = False) -> dict:
        """Total per-step cost + per-device memory of an assignment."""
        t_comp = t_comm = mem = 0.0
        prev: Optional[Strategy] = None
        for c in comps:
            s = assignment[c.name]
            ct = self.component_cost(c, s, uniform=uniform)
            t_comp += ct.t_comp
            t_comm += ct.t_comm
            mem += ct.mem_params + ct.mem_act
            if prev is not None:
                t_comm += self.transition_cost(prev, s, c.act_bytes)
            prev = s
        return {"t_comp": t_comp, "t_comm": t_comm, "time": t_comp + t_comm,
                "mem_per_device": mem,
                "comm_fraction": t_comm / max(t_comp + t_comm, 1e-12)}


# ---------------------------------------------------------------------------
# serving-step predictions — the analytic side of the static-cost contract.
# analysis/ircost.py extracts the same quantities from the lowered IR;
# analysis/tracecheck.py (cost-drift analyzer) gates on their agreement and
# the pair is committed to BENCH_static_costs.json.
# ---------------------------------------------------------------------------

# Relative FLOP tolerance between predict_serving_step and XLA's
# cost_analysis() of the compiled step.  The analytic model counts matmul
# FLOPs; XLA additionally counts elementwise work (norms, rope, softmax,
# masking, sampler) and is free to rematerialize — agreement is structural,
# not exact.  Calibrated over the registry archs by tests/test_tracecheck.py.
SERVING_FLOPS_RTOL = 0.5

# XLA's "bytes accessed" charges every operand of every fused op; the
# analytic estimate counts params + cache pools + boundary activations once.
# Only order-of-magnitude agreement is meaningful.
SERVING_BYTES_RFACTOR = 16.0


def predict_serving_step(arch, *, batch: int, new_tokens: int,
                         table_len: int) -> dict:
    """Analytic cost of ONE jitted paged serving step (forward only).

    ``new_tokens`` is the tokens computed per row this step: the prefill
    chunk size C for paged_prefill, 1 for paged_decode.  ``table_len`` is
    the padded per-row attention capacity ``max_blocks_per_seq *
    block_size`` — paged attention scores every query against that full
    (masked) span, so it is the effective T for score/gather FLOPs AND the
    per-row cache bytes touched.

    Returns {"flops", "bytes"} — floats, whole batch, per step.
    """
    from repro.core.components import build_components

    mode = "decode" if new_tokens == 1 else "prefill"
    seq_len = table_len if mode == "decode" else new_tokens
    comps = build_components(arch, seq_len=seq_len, batch=batch, mode=mode,
                             attn_span=table_len, moe_capacity=True)
    db = 4 if arch.param_dtype == "float32" else 2
    flops = sum(c.total_flops_fwd for c in comps)
    # kv_bytes/act_bytes are bf16-denominated in components.py; rescale.
    cache = sum(c.kv_bytes * c.count for c in comps) * (db / PARAM_BYTES)
    acts = sum(c.act_bytes * c.count for c in comps) * (db / PARAM_BYTES)
    params = sum(c.total_params for c in comps) * db
    return {"flops": float(flops), "bytes": float(params + cache + 2 * acts)}
