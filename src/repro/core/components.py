"""Logical component graph — ASA step 1 (Algorithm 1, line 4).

A component is a (segment, block-kind) group: the unit to which the scheduler
assigns a parallelism strategy.  Param counts are *exact* (jax.eval_shape over
the real initializer — no allocation); FLOPs/activation/comm metadata are
analytical, calibrated against ``compiled.cost_analysis()`` by the profiler.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as T

BF16 = 2  # bytes


@dataclasses.dataclass
class Component:
    name: str                  # e.g. "seg0/b1:attn.mixer", "embed", "head"
    kind: str                  # block kind | embed | head | encoder | mtp
    count: int                 # applications per forward pass
    params: float              # parameter count PER APPLICATION
    shared_params: bool        # params shared across applications (zamba2)
    flops_fwd: float           # FLOPs per application per step (whole batch)
    act_bytes: float           # output activation bytes per application
    n_model_allreduce: int     # model-axis activation all-reduces per app fwd
    moe_a2a_bytes: float = 0.0   # all-to-all bytes per app fwd (MoE dispatch+combine)
    kv_bytes: float = 0.0        # decode/prefill cache bytes per application
    path: tuple = ()             # param-tree path prefix for sharding rules
    keys: Optional[tuple] = None  # sub-component: block-dict keys it owns

    @property
    def total_params(self) -> float:
        return self.params if self.shared_params else self.params * self.count

    @property
    def total_flops_fwd(self) -> float:
        return self.flops_fwd * self.count


# block kinds split into separately-schedulable mixer/ffn sub-components
# (paper Fig. 6 granularity: attention vs MLP vs embedding)
SPLIT_KEYS = {
    "attn":      ({"norm1", "attn"}, {"norm2", "mlp"}),
    "enc_attn":  ({"norm1", "attn"}, {"norm2", "mlp"}),
    "moe_attn":  ({"norm1", "attn"}, {"norm2", "moe"}),
    "mla":       ({"norm1", "attn"}, {"norm2", "moe"}),
    "mla_dense": ({"norm1", "attn"}, {"norm2", "mlp"}),
    "cross_attn": ({"norm1", "attn"}, {"norm2", "mlp", "mlp_gate"}),
    "wdec":      ({"norm1", "attn", "norm2", "xattn"}, {"norm3", "mlp"}),
}


def _tree_size(tree) -> int:
    import math
    return sum(math.prod(leaf.shape) if leaf.shape else 1
               for leaf in jax.tree.leaves(tree))


@functools.lru_cache(maxsize=64)
def abstract_params(arch: ArchConfig):
    """Exact parameter ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(
        lambda: T.init_lm(jax.random.PRNGKey(0), arch))


def param_count(arch: ArchConfig) -> int:
    return _tree_size(abstract_params(arch))


def active_param_count(arch: ArchConfig) -> int:
    """Active params per token (MoE: routed top_k of n_experts + always-on)."""
    total = 0
    for c in build_components(arch, seq_len=1, batch=1, mode="train"):
        p = c.total_params
        if arch.moe and c.keys and "moe" in c.keys:
            m = arch.moe
            expert_p = 3 * arch.d_model * m.d_ff      # per expert (gated mlp)
            p -= c.count * expert_p * (m.n_experts - m.top_k)
        total += p
    return int(total)


# ---------------------------------------------------------------------------
# per-kind analytics
# ---------------------------------------------------------------------------

def _attn_flops(arch: ArchConfig, B, S, T_eff, d_model=None, n_heads=None):
    nh = n_heads or arch.n_heads
    D = d_model or arch.d_model
    hd = arch.resolved_head_dim if d_model is None else D // nh
    nkv = min(arch.n_kv_heads, nh) if d_model is None else nh
    qd, kvd = nh * hd, nkv * hd
    proj = 2 * B * S * D * (qd + 2 * kvd) + 2 * B * S * qd * D
    attn = 4 * B * S * T_eff * qd
    return proj + attn


def _mlp_flops(D, F, B, S, gated=True):
    return 2 * B * S * D * F * (3 if gated else 2)


def _moe_flops(arch: ArchConfig, B, S):
    m = arch.moe
    D = arch.d_model
    f = 2 * B * S * D * m.n_experts                      # router
    f += _mlp_flops(D, m.d_ff, B, S) * m.top_k           # routed experts
    if m.n_shared_experts:
        f += _mlp_flops(D, m.shared_d_ff or m.d_ff, B, S)
    if m.dense_d_ff:
        f += _mlp_flops(D, m.dense_d_ff, B, S)
    return f


def _moe_flops_capacity(arch: ArchConfig, B, S):
    """FLOPs the capacity-based dispatch (models/moe.py) actually executes:
    every expert computes its full capacity ``C = cf*K*S/E`` of token rows
    (padded or not), plus the dispatch/combine einsums — this is what the
    lowered IR's cost analysis counts, unlike the analytic top-k routing
    of :func:`_moe_flops` which undercounts by ~capacity_factor."""
    m = arch.moe
    D = arch.d_model
    E, K = m.n_experts, m.top_k
    C = max(1, int(m.capacity_factor * K * S / E))
    f = 2 * B * S * D * E                                # router
    f += _mlp_flops(D, m.d_ff, B, E * C)                 # E experts x C rows
    f += 2 * 2 * B * S * E * C * D                       # dispatch + combine
    if m.n_shared_experts:
        f += _mlp_flops(D, m.shared_d_ff or m.d_ff, B, S)
    if m.dense_d_ff:
        f += _mlp_flops(D, m.dense_d_ff, B, S)
    return f


def _mamba_flops(arch: ArchConfig, B, S, decode=False):
    s = arch.ssm
    D = arch.d_model
    d_in = s.expand * D
    H = d_in // s.head_dim
    P, G, N = s.head_dim, s.n_groups, s.d_state
    gn = G * N
    proj = 2 * B * S * D * (2 * d_in + 2 * gn + H) + 2 * B * S * d_in * D
    conv = 2 * B * S * s.d_conv * (d_in + 2 * gn)
    if decode:
        ssd = 4 * B * S * H * P * N                       # state update + readout
    else:
        Q = min(s.chunk, S)
        ssd = 2 * B * S * Q * (gn + H * P) + 4 * B * S * H * P * N
    return proj + conv + ssd


def _mla_flops(arch: ArchConfig, B, S, T_eff):
    m, D, H = arch.mla, arch.d_model, arch.n_heads
    f = 2 * B * S * D * m.q_lora_rank
    f += 2 * B * S * m.q_lora_rank * H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    f += 2 * B * S * D * (m.kv_lora_rank + m.qk_rope_head_dim)
    f += 2 * B * S * H * m.qk_nope_head_dim * m.kv_lora_rank        # q absorb
    f += 2 * B * S * T_eff * H * (m.kv_lora_rank + m.qk_rope_head_dim)  # scores
    f += 2 * B * S * T_eff * H * m.kv_lora_rank                      # ctx gather
    f += 2 * B * S * H * m.kv_lora_rank * m.v_head_dim               # v up-proj
    f += 2 * B * S * H * m.v_head_dim * D                            # out proj
    return f


def _kv_bytes(arch: ArchConfig, kind: str, B, max_len) -> float:
    if kind in ("attn", "moe_attn"):
        return 2 * B * max_len * min(arch.n_kv_heads, arch.n_heads) * \
            arch.resolved_head_dim * BF16
    if kind in ("mla", "mla_dense"):
        return B * max_len * (arch.mla.kv_lora_rank + arch.mla.qk_rope_head_dim) * BF16
    if kind == "mamba2":
        s = arch.ssm
        d_in = s.expand * arch.d_model
        H = d_in // s.head_dim
        return B * (H * s.head_dim * s.d_state + (s.d_conv - 1) *
                    (d_in + 2 * s.n_groups * s.d_state)) * 4
    if kind == "cross_attn":
        return 2 * B * arch.n_img_tokens * min(arch.n_kv_heads, arch.n_heads) * \
            arch.resolved_head_dim * BF16
    if kind == "wdec":
        enc_len = arch.encoder.seq_len if arch.encoder else 1500
        per_hd = min(arch.n_kv_heads, arch.n_heads) * arch.resolved_head_dim
        return 2 * B * (max_len + enc_len) * per_hd * BF16
    if kind == "shared_attn":
        d2 = 2 * arch.d_model
        return 2 * B * max_len * d2 * BF16
    return 0.0


# how many model-axis activation all-reduces one application incurs (fwd)
N_ALLREDUCE = {"attn": 2, "enc_attn": 2, "moe_attn": 1, "mla": 1, "mla_dense": 2,
               "mamba2": 1, "cross_attn": 2, "wdec": 3, "shared_attn": 3,
               "embed": 1, "head": 0, "mtp": 2}


def build_components(arch: ArchConfig, *, seq_len: int, batch: int,
                     mode: str = "train", attn_span: Optional[int] = None,
                     moe_capacity: bool = False) -> list[Component]:
    """mode: train | prefill | decode.  For decode, S=1 and attention spans
    the full ``seq_len`` cache.

    ``attn_span`` overrides the effective attention span T_eff: the paged
    serving steps score every query against the *full padded block table*
    (``max_blocks_per_seq * block_size`` key positions, masked), not the
    causal-average span — pass that capacity here when modelling a jitted
    paged step.  Setting it also marks the build as a serving *step* view:
    the encoder component is zeroed (it runs once at slot admission, never
    inside prefill/decode).  ``moe_capacity`` switches MoE FLOPs to the
    capacity-based dispatch actually executed (see _moe_flops_capacity).
    """
    aparams = abstract_params(arch)
    B = batch
    S = 1 if mode == "decode" else seq_len
    if attn_span is not None:
        T_eff = attn_span
    else:
        T_eff = seq_len if mode == "decode" else (seq_len + 1) / 2
    moe_fn = _moe_flops_capacity if moe_capacity else _moe_flops
    D = arch.d_model
    act = B * S * D * BF16
    comps: list[Component] = []

    gated = arch.act in ("silu", "geglu")

    def kind_flops(kind):
        """-> (mixer_flops, ffn_flops) per application."""
        if kind == "enc_attn":
            enc_len = arch.encoder.seq_len if arch.encoder else S
            return (_attn_flops(arch, B, enc_len, enc_len / 2),
                    _mlp_flops(D, arch.encoder.d_ff if arch.encoder
                               else arch.d_ff, B, enc_len, gated=gated))
        if kind == "attn":
            return (_attn_flops(arch, B, S, T_eff),
                    _mlp_flops(D, arch.d_ff, B, S, gated=gated))
        if kind == "moe_attn":
            return (_attn_flops(arch, B, S, T_eff), moe_fn(arch, B, S))
        if kind == "mla":
            return (_mla_flops(arch, B, S, T_eff), moe_fn(arch, B, S))
        if kind == "mla_dense":
            return (_mla_flops(arch, B, S, T_eff),
                    _mlp_flops(D, arch.d_ff, B, S, gated=gated))
        if kind == "mamba2":
            return (_mamba_flops(arch, B, S, decode=(mode == "decode")), 0.0)
        if kind == "cross_attn":
            return (_attn_flops(arch, B, S, arch.n_img_tokens),
                    _mlp_flops(D, arch.d_ff, B, S, gated=gated))
        if kind == "wdec":
            enc_len = arch.encoder.seq_len
            return (_attn_flops(arch, B, S, T_eff)
                    + _attn_flops(arch, B, S, enc_len),
                    _mlp_flops(D, arch.d_ff, B, S, gated=False))
        if kind == "shared_attn":
            d2 = 2 * D
            f = _attn_flops(arch, B, S, T_eff, d_model=d2, n_heads=arch.n_heads)
            f += _mlp_flops(d2, arch.d_ff, B, S, gated=gated)
            f += 2 * B * S * d2 * D                      # app_proj
            return (f, 0.0)
        raise ValueError(kind)

    # embedding
    comps.append(Component(
        name="embed", kind="embed", count=1,
        params=_tree_size(aparams["embed"]), shared_params=False,
        flops_fwd=2 * B * S * D,      # gather+scale (cheap)
        act_bytes=act, n_model_allreduce=N_ALLREDUCE["embed"], path=("embed",)))

    # encoder (whisper) — one component for the whole encoder stack
    if arch.encoder is not None:
        enc_params = _tree_size(aparams["encoder"])
        comps.append(Component(
            name="encoder", kind="enc_attn", count=arch.encoder.n_layers,
            params=enc_params / arch.encoder.n_layers, shared_params=False,
            flops_fwd=(0.0 if mode == "decode" or attn_span is not None
                       else sum(kind_flops("enc_attn"))),
            act_bytes=B * arch.encoder.seq_len * D * BF16,
            n_model_allreduce=2, path=("encoder",)))

    # zamba2 shared block params (applications are counted in the segments)
    shared_params_count = (_tree_size(aparams["shared"])
                           if "shared" in aparams else 0)

    for si, seg in enumerate(arch.pattern):
        for bi, kind in enumerate(seg.blocks):
            sub = aparams["segments"][si][f"b{bi}"]
            path = ("segments", si, f"b{bi}")
            f_mixer, f_ffn = kind_flops(kind)
            if kind in SPLIT_KEYS:
                mixer_keys, ffn_keys = SPLIT_KEYS[kind]
                p_mixer = sum(_tree_size(sub[k]) for k in mixer_keys
                              if k in sub) / seg.repeat
                p_ffn = sum(_tree_size(sub[k]) for k in ffn_keys
                            if k in sub) / seg.repeat
                comps.append(Component(
                    name=f"seg{si}/b{bi}:{kind}.mixer", kind=kind,
                    count=seg.repeat, params=p_mixer, shared_params=False,
                    flops_fwd=f_mixer, act_bytes=act,
                    n_model_allreduce=(2 if kind == "wdec" else 1),
                    kv_bytes=_kv_bytes(arch, kind, B, seq_len),
                    path=path, keys=tuple(sorted(mixer_keys))))
                comps.append(Component(
                    name=f"seg{si}/b{bi}:{kind}.ffn", kind=kind,
                    count=seg.repeat, params=p_ffn, shared_params=False,
                    flops_fwd=f_ffn, act_bytes=act, n_model_allreduce=1,
                    moe_a2a_bytes=(2 * act * arch.moe.top_k
                                   if kind in ("moe_attn", "mla") and arch.moe
                                   else 0.0),
                    path=path, keys=tuple(sorted(ffn_keys))))
            else:
                per_app = _tree_size(sub) / seg.repeat
                if kind == "shared_attn":
                    per_app = per_app + shared_params_count / seg.repeat
                comps.append(Component(
                    name=f"seg{si}/b{bi}:{kind}", kind=kind, count=seg.repeat,
                    params=per_app, shared_params=False,
                    flops_fwd=f_mixer + f_ffn, act_bytes=act,
                    n_model_allreduce=N_ALLREDUCE[kind],
                    kv_bytes=_kv_bytes(arch, kind, B, seq_len),
                    path=path))

    # head
    head_params = (0 if arch.tie_embeddings else _tree_size(aparams.get("head", {})))
    comps.append(Component(
        name="head", kind="head", count=1,
        params=head_params, shared_params=False,
        flops_fwd=2 * B * S * D * arch.padded_vocab,
        act_bytes=B * S * arch.padded_vocab * 4,
        n_model_allreduce=N_ALLREDUCE["head"], path=("head",)))

    if arch.mtp and mode == "train":
        comps.append(Component(
            name="mtp", kind="mtp", count=1,
            params=_tree_size(aparams["mtp"]), shared_params=False,
            flops_fwd=sum(kind_flops("attn")) + 2 * B * S * (2 * D) * D,
            act_bytes=act, n_model_allreduce=2, path=("mtp",)))
    return comps


def components_for_shape(arch: ArchConfig, shape: ShapeSpec) -> list[Component]:
    return build_components(arch, seq_len=shape.seq_len,
                            batch=shape.global_batch, mode=shape.kind)
