"""Parallelism strategies — the paper's {DP, MP, HP} as sharding policies."""
from __future__ import annotations

import enum


class Strategy(str, enum.Enum):
    DP = "DP"    # replicate weights; batch over `data`; compute replicated on `model`
    MP = "MP"    # tensor/expert/head-parallel over `model`; batch over `data`
    HP = "HP"    # MP over `model` + ZeRO-3/FSDP weight sharding over `data`
    FS = "FS"    # fully-sharded (ZeRO-3 over ALL axes): batch over data x model,
                 # weights gathered per layer — beyond-paper strategy (§Perf);
                 # uniform-only (batch layout must be globally consistent)

    def __str__(self):
        return self.value


# the paper's strategy set (mixed assignments draw from these)
ALL_STRATEGIES = (Strategy.DP, Strategy.MP, Strategy.HP)
# uniform/static candidates additionally include FS
UNIFORM_STRATEGIES = (Strategy.DP, Strategy.MP, Strategy.HP, Strategy.FS)

# strategies ordered by per-device parameter memory (most -> least)
MEMORY_ORDER = (Strategy.DP, Strategy.MP, Strategy.HP, Strategy.FS)
