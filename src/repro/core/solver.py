"""ASA strategy optimizer — paper §III-C / Algorithm 1 line 8.

    min_{s_i}  Σ_i ( t_comp(c_i, s_i) + t_comm(c_i, s_i) )
    s.t.       Σ_i mem(c_i, s_i) ≤ M_j  per device

Solvers:
  * exhaustive  — exact, for |C| ≤ exhaustive_limit (tests/validation)
  * greedy      — per-component argmin, then knapsack-style repair toward
                  feasibility by the best Δmem/Δtime switch (production)

Invariant (property-tested): the returned assignment is memory-feasible when
any feasible assignment exists, and its cost ≤ every *uniform static*
strategy's cost under the same model — i.e. adaptive dominates static, the
paper's headline claim.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core.components import Component
from repro.core.costmodel import CostModel
from repro.core.strategy import ALL_STRATEGIES, UNIFORM_STRATEGIES, Strategy


@dataclasses.dataclass
class Plan:
    assignment: dict[str, Strategy]
    cost: dict                     # assignment_cost() report
    feasible: bool
    method: str


def _mem_of(cm: CostModel, comps, assignment) -> float:
    return cm.assignment_cost(comps, assignment)["mem_per_device"]


def solve_uniform(cm: CostModel, comps: list[Component],
                  strategy: Strategy) -> Plan:
    """Static baseline: one strategy for every component."""
    assignment = {c.name: strategy for c in comps}
    cost = cm.assignment_cost(comps, assignment, uniform=True)
    return Plan(assignment, cost,
                cost["mem_per_device"] <= cm.hw.hbm_bytes, f"uniform-{strategy}")


def solve_exhaustive(cm: CostModel, comps: list[Component],
                     mem_limit: Optional[float] = None) -> Plan:
    M = mem_limit if mem_limit is not None else cm.hw.hbm_bytes
    best, best_cost = None, None
    for combo in itertools.product(ALL_STRATEGIES, repeat=len(comps)):
        assignment = {c.name: s for c, s in zip(comps, combo)}
        cost = cm.assignment_cost(comps, assignment)
        if cost["mem_per_device"] > M:
            continue
        if best_cost is None or cost["time"] < best_cost["time"]:
            best, best_cost = assignment, cost
    if best is None:   # nothing feasible: fall back to min-memory assignment
        assignment = {c.name: Strategy.HP for c in comps}
        return Plan(assignment, cm.assignment_cost(comps, assignment),
                    False, "exhaustive-infeasible")
    return Plan(best, best_cost, True, "exhaustive")


def solve_greedy(cm: CostModel, comps: list[Component],
                 mem_limit: Optional[float] = None) -> Plan:
    """Per-component argmin + memory repair (production path).

    Repair loop: while over the memory budget, apply the single
    component-strategy switch with the smallest Δtime per byte saved.
    """
    M = mem_limit if mem_limit is not None else cm.hw.hbm_bytes
    per = {}
    for c in comps:
        per[c.name] = {s: cm.component_cost(c, s) for s in ALL_STRATEGIES}
    assignment = {c.name: min(per[c.name], key=lambda s: per[c.name][s].time)
                  for c in comps}

    def total_mem():
        return sum(per[c.name][assignment[c.name]].mem_params
                   + per[c.name][assignment[c.name]].mem_act for c in comps)

    guard = 0
    while total_mem() > M and guard < 10 * len(comps):
        guard += 1
        best_switch, best_ratio = None, None
        for c in comps:
            cur = per[c.name][assignment[c.name]]
            cur_mem = cur.mem_params + cur.mem_act
            for s in ALL_STRATEGIES:
                if s == assignment[c.name]:
                    continue
                cand = per[c.name][s]
                saved = cur_mem - (cand.mem_params + cand.mem_act)
                if saved <= 0:
                    continue
                dt = cand.time - cur.time
                ratio = dt / saved
                if best_ratio is None or ratio < best_ratio:
                    best_ratio, best_switch = ratio, (c.name, s)
        if best_switch is None:
            break   # no memory-saving switch remains
        assignment[best_switch[0]] = best_switch[1]

    cost = cm.assignment_cost(comps, assignment)
    return Plan(assignment, cost, cost["mem_per_device"] <= M, "greedy")


def solve(cm: CostModel, comps: list[Component],
          mem_limit: Optional[float] = None,
          exhaustive_limit: int = 8) -> Plan:
    """Best of {mixed assignment, uniform DP/MP/HP} — guarantees the
    adaptive plan never loses to a static scheme under the same model."""
    M = mem_limit if mem_limit is not None else cm.hw.hbm_bytes
    if len(comps) <= exhaustive_limit:
        mixed = solve_exhaustive(cm, comps, mem_limit)
    else:
        mixed = solve_greedy(cm, comps, mem_limit)
    candidates = [mixed]
    for s in UNIFORM_STRATEGIES:      # FS participates as a uniform scheme
        if s == Strategy.FS and not cm.fs_allowed:
            continue
        u = solve_uniform(cm, comps, s)
        u = Plan(u.assignment, u.cost, u.cost["mem_per_device"] <= M, u.method)
        candidates.append(u)
    feasible = [p for p in candidates if p.feasible]
    if not feasible:
        return mixed
    return min(feasible, key=lambda p: p.cost["time"])
