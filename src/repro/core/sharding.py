"""Assignment -> GSPMD sharding translation (DESIGN.md §2 table).

Per-leaf PartitionSpecs are derived from the parameter tree *path* (module
and leaf names fixed by the model substrate), the component's assigned
Strategy, and divisibility of the dims by the mesh axes.

Fallback rule: any dim that an axis does not divide is replicated instead —
JAX rejects uneven shardings (verified), and head-count-dependent reshapes
(e.g. arctic 56 heads, minitron 24 heads vs model=16) would force GSPMD
reshards.  Such attention mixers keep replicated weights under MP and shard
only over `data` (ZeRO-style) under HP; their FFN halves shard fully.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.components import SPLIT_KEYS, abstract_params
from repro.core.costmodel import MeshShape
from repro.core.strategy import Strategy

# EP layout for MoE expert stacks: "model" (baseline: experts over `model`,
# expert-tensor over `data` under HP) or "data" (optimized EP-major: experts
# over `data`, expert-FF over `model`; pairs with moe.EP_CONSTRAINTS)
MOE_EP_AXIS = "model"

# column-parallel modules (shard d_out over `model`); row-parallel (d_in)
COL = {"wq", "wk", "wv", "w_in", "w_gate", "z_proj", "x_proj", "dt_proj",
       "wq_a", "wq_b", "wk_b", "wv_b"}
ROW = {"wo", "w_out", "out_proj"}
# always-replicated small weights (see module docstring / mamba2.py note)
REPL = {"b_proj", "c_proj", "wkv_a", "router", "conv_b", "conv_c",
        "q_norm", "k_norm", "kv_norm", "norm", "norm1", "norm2", "norm3",
        "final_norm", "gate", "mlp_gate", "dt_bias", "cls", "pos"}


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _q_heads_ok(arch: ArchConfig, mesh: MeshShape) -> bool:
    """wq/wo shard iff the (B,S,q_dim@model)->(B,S,H,hd) reshape stays
    sharded, i.e. n_heads % model == 0 (else: arctic 56H, minitron 24H)."""
    return _div(arch.n_heads, mesh.model)


def _kv_heads_ok(arch: ArchConfig, mesh: MeshShape) -> bool:
    """wk/wv shard iff n_kv_heads % model == 0.  When false they stay
    replicated (tiny: D x kv_dim) and layers._expand_kv broadcasts the
    replicated k/v into the q-head-sharded layout."""
    return _div(min(arch.n_kv_heads, arch.n_heads), mesh.model)


def _sanitize(spec: P, shape: tuple, mesh: MeshShape) -> P:
    """Replicate any dim an axis doesn't divide (safety net)."""
    sizes = {"data": mesh.data, "model": mesh.model, "pod": mesh.pod}
    out = []
    for i, ax in enumerate(spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= sizes[a]
        out.append(ax if i < len(shape) and _div(shape[i], total) else None)
    return P(*out)


def leaf_spec(names: tuple, shape: tuple, strat: Strategy,
              mesh: MeshShape, arch: ArchConfig) -> P:
    """Spec for an UNSTACKED leaf (stack prefix added by caller)."""
    rank = len(shape)
    mod = names[-2] if len(names) >= 2 else names[-1]
    leaf = names[-1]
    in_moe = "moe" in names
    shared_blk = "shared" in names

    if strat == Strategy.DP:
        return P(*([None] * rank))
    if strat == Strategy.FS:
        # FS weight layout == HP's 2-axis sharding; the difference is the
        # batch/activation layout (over ALL axes), set by the launcher.
        strat = Strategy.HP

    hp = strat == Strategy.HP
    # HP shards the ZeRO dim over pod too (multi-pod: params /512 not /256)
    data_ax = ("data", "pod") if (hp and mesh.pod > 1) else "data"

    # ---- embedding / head -------------------------------------------------
    if leaf == "embedding":
        return P("model", data_ax if hp else None)
    if "head" in names and leaf == "w":
        return P(data_ax if hp else None, "model")
    if "head" in names and leaf == "b":
        return P("model")

    # ---- MoE expert-stacked arrays (E, D, F) / (E, F, D) ------------------
    if in_moe and leaf in ("w_in", "w_gate", "w_out") and rank == 3:
        if MOE_EP_AXIS == "data":
            # EP-major: experts over `data`, expert-FF dim over `model`
            # (w_in/w_gate: (E,D,F) -> F; w_out: (E,F,D) -> F is dim 1)
            return (P("data", None, "model") if leaf in ("w_in", "w_gate")
                    else P("data", "model", None))
        return P("model", data_ax if hp else None, None)

    # ---- norms / replicated -----------------------------------------------
    if mod in REPL or leaf in REPL:
        # mamba2's gated rmsnorm scale lives on the head-sharded d_inner
        if mod == "norm" and "mixer" in names and arch.ssm is not None:
            return P("model")
        return P(*([None] * rank))

    # ---- attention q/k/v/o with head-divisibility gating -------------------
    if mod in ("wq", "wk", "wv", "wo") and not in_moe:
        if shared_blk:                         # zamba2 shared block: full MHA
            ok = _div(arch.n_heads, mesh.model)
        elif mod in ("wk", "wv"):
            ok = _kv_heads_ok(arch, mesh)
        else:
            ok = _q_heads_ok(arch, mesh)
        if not ok:
            # fallback: ZeRO-only sharding under HP, replicate under MP
            if hp and leaf == "w":
                return P(data_ax, None)
            return P(*([None] * rank))

    # mamba2 head-sharded projections need H % model == 0
    if mod in ("z_proj", "x_proj", "dt_proj", "out_proj") and arch.ssm is not None:
        H = (arch.ssm.expand * arch.d_model) // arch.ssm.head_dim
        if not _div(H, mesh.model):
            if hp:
                return P(data_ax, None) if leaf == "w" else P(None)
            return P(*([None] * rank))

    if mod == "conv_x" or (mod in ("conv_x",) and leaf in ("w", "b")):
        return P(None, "model") if leaf == "w" else P("model")
    if leaf in ("A_log", "D") and rank == 1:
        return P("model")

    if mod in COL:
        if leaf == "w":
            return P(data_ax if hp else None, "model")
        return P("model")           # bias on the sharded output dim
    if mod in ROW:
        if leaf == "w":
            return P("model", data_ax if hp else None)
        return P(*([None] * rank))  # bias after the all-reduce: replicated

    if mod == "app_proj":           # zamba2 per-application out projection
        if leaf == "w":
            return P("model", data_ax if hp else None)
        return P(*([None] * rank))
    if mod == "proj":               # mtp concat projection
        return P(None, "model") if leaf == "w" else P("model")

    return P(*([None] * rank))


# ---------------------------------------------------------------------------
# component lookup
# ---------------------------------------------------------------------------

def _names_of(path) -> tuple:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "idx"):
            out.append(k.idx)
        elif hasattr(k, "name"):
            out.append(k.name)
    return tuple(out)


def component_name_of(names: tuple, arch: ArchConfig) -> Optional[str]:
    if names[0] == "embed":
        return "embed"
    if names[0] == "head":
        return "head"
    if names[0] == "mtp":
        return "mtp"
    if names[0] == "encoder":
        return "encoder"
    if names[0] == "final_norm":
        return None
    if names[0] == "shared":
        for si, seg in enumerate(arch.pattern):
            for bi, kind in enumerate(seg.blocks):
                if kind == "shared_attn":
                    return f"seg{si}/b{bi}:shared_attn"
        return None
    if names[0] == "segments":
        si, b = names[1], names[2]
        bi = int(b[1:])
        kind = arch.pattern[si].blocks[bi]
        if kind in SPLIT_KEYS:
            mixer_keys, _ = SPLIT_KEYS[kind]
            sub = "mixer" if names[3] in mixer_keys else "ffn"
            return f"seg{si}/b{bi}:{kind}.{sub}"
        return f"seg{si}/b{bi}:{kind}"
    return None


def _stack_depth(names: tuple) -> int:
    return 1 if names[0] == "segments" or \
        (names[0] == "encoder" and len(names) > 1 and names[1] == "segments") else 0


# ---------------------------------------------------------------------------
# public builders
# ---------------------------------------------------------------------------

def param_specs(arch: ArchConfig, assignment: dict[str, Strategy],
                mesh: MeshShape):
    """PartitionSpec tree mirroring init_lm's params exactly."""
    aparams = abstract_params(arch)

    def rule(path, leaf):
        names = _names_of(path)
        comp = component_name_of(names, arch)
        strat = assignment.get(comp, Strategy.DP) if comp else Strategy.DP
        depth = _stack_depth(names)
        spec = leaf_spec(tuple(n for n in names if isinstance(n, str)),
                         leaf.shape[depth:], strat, mesh, arch)
        full = P(*([None] * depth + list(spec)))
        return _sanitize(full, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, aparams)


def batch_axes(mesh: MeshShape, batch: int, *, full: bool = False):
    """Largest batch sharding the mesh allows for this batch size.
    full=True (FS / uniform-DP): batch over every axis when divisible."""
    if full:
        axes = tuple(a for a, n in (("pod", mesh.pod), ("data", mesh.data),
                                    ("model", mesh.model)) if n > 1)
        total = mesh.chips
        if axes and _div(batch, total):
            return axes
    if mesh.pod > 1 and _div(batch, mesh.pod * mesh.data):
        return ("pod", "data")
    if _div(batch, mesh.data):
        return "data"
    return None


def token_spec(mesh: MeshShape, batch: int, *, full: bool = False) -> P:
    return P(batch_axes(mesh, batch, full=full), None)


def opt_state_specs(opt_sds, param_specs_tree, mesh: MeshShape):
    """Specs for an OptState pytree.

    fp32 moments mirror the param specs (ZeRO follows the HP params for
    free).  Int8 QLeaf moments are flat (n_blocks, 256) — shard dim0 over
    every mesh axis that divides it (fully-sharded optimizer state).
    """
    from repro.optim.quantized import QLeaf

    def flat_rule(leaf):
        n = leaf.shape[0]
        for axes in ((("data", "model", "pod") if mesh.pod > 1
                      else ("data", "model")),
                     ("data", "model"), ("data",), None):
            if axes is None:
                return P(*([None] * len(leaf.shape)))
            total = 1
            sizes = {"data": mesh.data, "model": mesh.model, "pod": mesh.pod}
            for a in axes:
                total *= sizes[a]
            if _div(n, total):
                return P(axes, *([None] * (len(leaf.shape) - 1)))

    def moment_specs(m_sds):
        has_q = any(isinstance(x, QLeaf)
                    for x in jax.tree.leaves(
                        m_sds, is_leaf=lambda t: isinstance(t, QLeaf)))
        if has_q:
            return jax.tree.map(flat_rule, m_sds)
        return param_specs_tree

    step, mu, nu, extra = opt_sds
    return type(opt_sds)(P(), moment_specs(mu), moment_specs(nu),
                         None if extra is None else jax.tree.map(flat_rule, extra))


def cache_specs(arch: ArchConfig, assignment: dict[str, Strategy],
                mesh: MeshShape, batch: int):
    """Spec tree mirroring init_cache: per-segment stacked block caches."""
    ba = batch_axes(mesh, batch)

    def kv_time_spec(strat, extra_rank):
        # (repeat, B, T, ...) — time axis sharded over `model` under MP/HP
        t_ax = "model" if strat in (Strategy.MP, Strategy.HP) else None
        return P(None, ba, t_ax, *([None] * extra_rank))

    specs = []
    for si, seg in enumerate(arch.pattern):
        seg_spec = {}
        for bi, kind in enumerate(seg.blocks):
            if kind in SPLIT_KEYS:
                comp = f"seg{si}/b{bi}:{kind}.mixer"
            else:
                comp = f"seg{si}/b{bi}:{kind}"
            strat = assignment.get(comp, Strategy.DP)
            if kind in ("attn", "moe_attn"):
                seg_spec[f"b{bi}"] = {"k": kv_time_spec(strat, 2),
                                      "v": kv_time_spec(strat, 2),
                                      "pos": P(None)}
            elif kind in ("mla", "mla_dense"):
                seg_spec[f"b{bi}"] = {"c_kv": kv_time_spec(strat, 1),
                                      "k_rope": kv_time_spec(strat, 1),
                                      "pos": P(None)}
            elif kind == "mamba2":
                H = (arch.ssm.expand * arch.d_model) // arch.ssm.head_dim
                h_ax = "model" if (strat in (Strategy.MP, Strategy.HP)
                                   and _div(H, mesh.model)) else None
                seg_spec[f"b{bi}"] = {
                    "conv_x": P(None, ba, None, h_ax),
                    "conv_b": P(None, ba, None, None),
                    "conv_c": P(None, ba, None, None),
                    "ssm": P(None, ba, h_ax, None, None)}
            elif kind == "cross_attn":
                seg_spec[f"b{bi}"] = {"k": P(None, ba, None, None, None),
                                      "v": P(None, ba, None, None, None)}
            elif kind == "wdec":
                seg_spec[f"b{bi}"] = {
                    "self": {"k": kv_time_spec(strat, 2),
                             "v": kv_time_spec(strat, 2), "pos": P(None)},
                    "cross": {"k": P(None, ba, None, None, None),
                              "v": P(None, ba, None, None, None)}}
            elif kind == "shared_attn":
                _div(arch.n_heads, mesh.model)   # validates divisibility
                t_ax = "model" if (strat in (Strategy.MP, Strategy.HP)) else None
                seg_spec[f"b{bi}"] = {"k": P(None, ba, t_ax, None, None),
                                      "v": P(None, ba, t_ax, None, None),
                                      "pos": P(None)}
            else:
                seg_spec[f"b{bi}"] = None
        specs.append(seg_spec)
    return specs


def paged_cache_specs(arch: ArchConfig, assignment: dict[str, Strategy],
                      mesh: MeshShape):
    """Spec tree mirroring init_paged_cache: per-segment stacked pools for
    both serving state classes.

    attn-family block pools are (repeat, num_blocks, block_size, Hkv,
    head_dim).  They have no batch axis and their block axis is gathered
    through block tables every step, so unlike cache_specs the time axis
    cannot carry the MP shard; instead the kv-head axis shards over `model`
    (the classic paged-KV layout) whenever the head count divides, else the
    pool is replicated.

    Slot-state pools have a leading (repeat, slots+1) prefix.  mamba2 state
    shards its SSM head axis over `model` (mirroring the training-plan cache
    layout); cross-attn K/V shards its kv-head axis like the attn pools.

    zamba2's shared block pages a full-MHA pool per application (head axis
    over `model` when n_heads divides); whisper's wdec carries a paged
    self-attn pool plus a slot-state encoder-K/V pool; MLA's latent
    (c_kv, k_rope) pools are replicated — the rank axis is contracted inside
    the absorbed-score einsums and is tiny by design (the point of MLA).

    Specs are emitted in GSPMD's *canonical* form (trailing Nones stripped,
    fully-replicated as P()): the pools are device_put with these specs at
    engine init and then flow through the jitted steps, whose output
    shardings come back canonicalized — a non-canonical initial spec hashes
    differently and silently retraces every step on its second call
    (caught by the tracecheck trace-cache analyzer)."""
    def _canon(spec):
        parts = tuple(spec)
        while parts and parts[-1] is None:
            parts = parts[:-1]
        return P(*parts)

    specs = []
    for si, seg in enumerate(arch.pattern):
        seg_spec = {}
        for bi, kind in enumerate(seg.blocks):
            if kind not in ("attn", "moe_attn", "mamba2", "cross_attn",
                            "mla", "mla_dense", "shared_attn", "wdec"):
                raise ValueError(
                    f"paged/slot-state cache unsupported for block kind "
                    f"{kind!r}")
            comp = f"seg{si}/b{bi}:{kind}.mixer" if kind in SPLIT_KEYS \
                else f"seg{si}/b{bi}:{kind}"
            strat = assignment.get(comp, Strategy.DP)
            mp = strat in (Strategy.MP, Strategy.HP)
            if kind == "mamba2":
                H = (arch.ssm.expand * arch.d_model) // arch.ssm.head_dim
                h_ax = "model" if (mp and _div(H, mesh.model)) else None
                seg_spec[f"b{bi}"] = {
                    "conv_x": P(None, None, None, h_ax),
                    "conv_b": P(None, None, None, None),
                    "conv_c": P(None, None, None, None),
                    "ssm": P(None, None, h_ax, None, None)}
                continue
            if kind in ("mla", "mla_dense"):
                seg_spec[f"b{bi}"] = {"c_kv": P(None, None, None, None),
                                      "k_rope": P(None, None, None, None)}
                continue
            if kind == "shared_attn":
                h_ax = "model" if (mp and _div(arch.n_heads, mesh.model)) \
                    else None
                pool = P(None, None, None, h_ax, None)
                seg_spec[f"b{bi}"] = {"k": pool, "v": pool}
                continue
            h_ax = "model" if (mp and _kv_heads_ok(arch, mesh)) else None
            pool = P(None, None, None, h_ax, None)
            if kind == "wdec":
                seg_spec[f"b{bi}"] = {"self": {"k": pool, "v": pool},
                                      "cross": {"k": pool, "v": pool}}
                continue
            seg_spec[f"b{bi}"] = {"k": pool, "v": pool}
        specs.append(seg_spec)
    return jax.tree.map(_canon, specs)
