"""Hardware profiles for the ASA cost model and roofline analysis.

TPU_V5E is the deployment target (roofline constants per the spec);
V100_CLUSTER reproduces the paper's own 8-GPU setting for Table I validation.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # per chip, bf16/fp16 FLOP/s
    hbm_bw: float              # per chip, bytes/s
    link_bw: float             # per link, bytes/s (ICI / NVLink)
    hbm_bytes: float           # per chip HBM capacity
    # inter-pod (DCN) bandwidth per host, bytes/s; 0 => single-pod only
    dcn_bw: float = 0.0
    # fraction of peak realistically achievable on large matmuls (MFU ceiling
    # used by the *cost model*, not the roofline — roofline uses raw peak)
    matmul_efficiency: float = 0.6


TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    peak_flops=197e12,         # bf16
    hbm_bw=819e9,
    link_bw=50e9,              # ~50 GB/s per ICI link
    hbm_bytes=16e9,
    dcn_bw=25e9,
    matmul_efficiency=0.6,
)

V100_CLUSTER = HardwareProfile(
    name="v100_nvlink",
    peak_flops=125e12,         # fp16 tensor core
    hbm_bw=900e9,
    link_bw=25e9,              # NVLink2 per direction per link
    hbm_bytes=32e9,
    dcn_bw=0.0,
    matmul_efficiency=0.45,    # V100-era utilization on 25M-86M param models
)


def ring_allreduce_time(bytes_: float, n: int, link_bw: float) -> float:
    """Bandwidth-optimal ring all-reduce: 2*(n-1)/n * bytes / link_bw."""
    if n <= 1 or bytes_ == 0:
        return 0.0
    return 2.0 * (n - 1) / n * bytes_ / link_bw


def allgather_time(bytes_out: float, n: int, link_bw: float) -> float:
    """Ring all-gather of a full tensor of `bytes_out` total size."""
    if n <= 1 or bytes_out == 0:
        return 0.0
    return (n - 1) / n * bytes_out / link_bw


def reducescatter_time(bytes_in: float, n: int, link_bw: float) -> float:
    if n <= 1 or bytes_in == 0:
        return 0.0
    return (n - 1) / n * bytes_in / link_bw


def alltoall_time(bytes_: float, n: int, link_bw: float) -> float:
    if n <= 1 or bytes_ == 0:
        return 0.0
    return (n - 1) / n * bytes_ / link_bw
