"""AdaptiveScheduler — the paper's ASA as a first-class JAX feature.

plan()        profile -> estimate -> solve -> sharding specs   (Alg. 1, 4-9)
replan()      periodic re-profile + strategy update            (Alg. 1, 21-23)
baselines()   static DP / MP / HP plans for comparison         (paper Table I)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import components as C
from repro.core import hardware as HW
from repro.core import sharding as SH
from repro.core import solver as SV
from repro.core.costmodel import CostModel, MeshShape
from repro.core.profiler import ComponentProfiler, StepMonitor
from repro.core.strategy import ALL_STRATEGIES


@dataclasses.dataclass
class SchedulePlan:
    arch: ArchConfig
    shape: ShapeSpec
    mesh: MeshShape
    plan: SV.Plan
    comps: list
    microbatches: int = 1

    @property
    def assignment(self):
        return self.plan.assignment

    @property
    def uniform(self) -> Optional[str]:
        """'DP'|'MP'|'HP' when the winning plan is a static uniform scheme."""
        if self.plan.method.startswith("uniform-"):
            return self.plan.method.split("-", 1)[1]
        return None

    def param_specs(self):
        return SH.param_specs(self.arch, self.assignment, self.mesh)

    def cache_specs(self, batch: int):
        return SH.cache_specs(self.arch, self.assignment, self.mesh, batch)

    def paged_cache_specs(self):
        return SH.paged_cache_specs(self.arch, self.assignment, self.mesh)

    def summary(self) -> str:
        rows = [f"  {c.name:<36s} -> {self.assignment[c.name]}"
                for c in self.comps]
        cost = self.plan.cost
        head = (f"ASA plan [{self.arch.name} x {self.shape.name} "
                f"mesh=({self.mesh.pod}x{self.mesh.data}x{self.mesh.model})] "
                f"method={self.plan.method} feasible={self.plan.feasible}\n"
                f"  predicted: t_comp={cost['t_comp']*1e3:.2f}ms "
                f"t_comm={cost['t_comm']*1e3:.2f}ms "
                f"comm%={cost['comm_fraction']*100:.1f} "
                f"mem/dev={cost['mem_per_device']/1e9:.2f}GB")
        return "\n".join([head] + rows)


OPT_PRESETS = {
    # bytes per param: (grad, optimizer-state)
    "adamw32": (4.0, 12.0),     # fp32 grads + fp32 m/v/master
    "adamw8bit": (2.0, 2.0),    # bf16 grad accum + int8 m/v (optim/quantized.py)
}


class AdaptiveScheduler:
    def __init__(self, hw: HW.HardwareProfile = HW.TPU_V5E, *,
                 faithful: bool = True, remat: str = "selective",
                 mem_limit_fraction: float = 0.9, opt_preset: str = "adamw32",
                 seq_sharded: bool = False, moe_ep: bool = False):
        self.hw = hw
        self.faithful = faithful
        self.remat = remat
        self.seq_sharded = seq_sharded
        self.moe_ep = moe_ep
        self.mem_limit_fraction = mem_limit_fraction
        self.grad_bytes, self.opt_bytes = OPT_PRESETS[opt_preset]
        self.opt_preset = opt_preset
        self.profiler = ComponentProfiler()
        self.monitor = StepMonitor()
        self._calibration: dict[str, float] = {}

    # ------------------------------------------------------------------
    def _cost_model(self, mesh: MeshShape, mode: str,
                    microbatches: int = 1,
                    fs_allowed: bool = True) -> CostModel:
        return CostModel(hw=self.hw, mesh=mesh, mode=mode,
                         faithful=self.faithful, remat=self.remat,
                         microbatches=microbatches,
                         seq_sharded=self.seq_sharded,
                         fs_allowed=fs_allowed,
                         moe_ep=self.moe_ep,
                         grad_bytes=self.grad_bytes,
                         opt_bytes_per_param=self.opt_bytes,
                         calibration=self._calibration or None)

    def plan(self, arch: ArchConfig, shape: ShapeSpec,
             mesh: MeshShape) -> SchedulePlan:
        """Solve; escalate grad-accumulation microbatching until the
        activation working set fits (train only)."""
        comps = C.components_for_shape(arch, shape)
        limit = self.hw.hbm_bytes * self.mem_limit_fraction
        max_mb = max(1, shape.global_batch // (mesh.data * mesh.pod)) \
            if shape.kind == "train" else 1
        # FS (ZeRO-3 over all chips) needs one whole example per chip
        fs_ok = (shape.kind == "train"
                 and shape.global_batch % mesh.chips == 0)
        best = None        # (plan, mb) — cheapest feasible across mb values
        mb = 1
        while True:
            cm = self._cost_model(mesh, shape.kind, microbatches=mb,
                                  fs_allowed=fs_ok)
            plan = SV.solve(cm, comps, mem_limit=limit)
            if plan.feasible and (best is None
                                  or plan.cost["time"] < best[0].cost["time"]):
                best = (plan, mb)
            if mb >= max_mb:
                break
            mb *= 2
        if best is None:
            best = (plan, mb)
        return SchedulePlan(arch, shape, mesh, best[0], comps,
                            microbatches=best[1])

    def baselines(self, arch: ArchConfig, shape: ShapeSpec,
                  mesh: MeshShape) -> dict[str, SV.Plan]:
        comps = C.components_for_shape(arch, shape)
        cm = self._cost_model(mesh, shape.kind)
        return {str(s): SV.solve_uniform(cm, comps, s) for s in ALL_STRATEGIES}

    # ------------------------------------------------------------------
    def record_step(self, step_time_s: float) -> bool:
        """Feed live step times; True => caller should replan()."""
        return self.monitor.update(step_time_s)

    def calibrate(self, measured: dict[str, float],
                  predicted: dict[str, float]):
        """Update per-component calibration factors from measurements."""
        for name, t in measured.items():
            p = predicted.get(name)
            if p and p > 0:
                self._calibration[name] = max(t / p, 1e-3)

    def replan(self, arch: ArchConfig, shape: ShapeSpec,
               mesh: MeshShape) -> SchedulePlan:
        """Re-solve with current calibration (Alg. 1 line 22)."""
        return self.plan(arch, shape, mesh)
