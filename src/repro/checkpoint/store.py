"""Checkpointing substrate: sharded npz + json manifest.

Production posture (DESIGN.md §7):
  * atomic commit — write to tmp dir, fsync, rename; a crash mid-save never
    corrupts the latest checkpoint
  * async save — background thread snapshots device arrays to host then
    writes; the train loop stalls only for the device->host copy
  * keep-k GC
  * restore **with resharding** — leaves are device_put against the current
    mesh's NamedShardings, so a checkpoint taken on one mesh restarts on
    another (elastic restart after losing a slice)
  * manifest carries step / rng / data-offset for exact-resume
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np


def _flat_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [v for _, v in flat], treedef


def save_pytree(path: pathlib.Path, tree, *, manifest_extra: Optional[dict] = None):
    path = pathlib.Path(path)
    tmp = path.with_name(path.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    keys, leaves, _ = _flat_with_paths(tree)
    arrays = {}
    for i, (k, v) in enumerate(zip(keys, leaves)):
        arrays[f"a{i}"] = np.asarray(v)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {"keys": keys, "time": time.time()}
    manifest.update(manifest_extra or {})
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)                       # atomic commit


def restore_pytree(path: pathlib.Path, like_tree, *, shardings=None):
    """Restore into the structure of `like_tree`; device_put each leaf to
    `shardings` (same treedef) when given — reshard-on-restore."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    keys, leaves, treedef = _flat_with_paths(like_tree)
    assert keys == manifest["keys"], "checkpoint/model structure mismatch"
    loaded = [data[f"a{i}"] for i in range(len(keys))]
    if shardings is not None:
        s_leaves = jax.tree.leaves(shardings,
                                   is_leaf=lambda x: hasattr(x, "spec"))
        loaded = [jax.device_put(a.astype(leaf.dtype), s)
                  for a, leaf, s in zip(loaded, leaves, s_leaves)]
    else:
        loaded = [jax.device_put(a.astype(leaf.dtype)) for a, leaf in
                  zip(loaded, leaves)]
    return jax.tree_util.tree_unflatten(treedef, loaded), manifest


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> pathlib.Path:
        return self.dir / f"step_{step:010d}"

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, *, extra: Optional[dict] = None):
        self.wait()
        # snapshot to host synchronously (consistent cut), write async
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save_pytree(self._step_dir(step), host_tree,
                        manifest_extra={"step": step, **(extra or {})})
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def restore(self, like_tree, *, step: Optional[int] = None,
                shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return restore_pytree(self._step_dir(step), like_tree,
                              shardings=shardings)

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
