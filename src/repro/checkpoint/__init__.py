from repro.checkpoint.store import (CheckpointManager, save_pytree,
                                    restore_pytree)
