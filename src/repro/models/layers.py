"""Core neural-net layers, pure-functional JAX.

Convention: every module is an ``init_*(key, ...) -> params`` plus an
``apply`` function taking ``(params, x, ...)``.  Params are plain dicts so the
ASA sharding layer can mirror them with PartitionSpec trees (see
``core/sharding.py`` — spec builders are written alongside these inits and a
property test asserts tree-structure equality).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

Params = dict
Array = jax.Array


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, stddev):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: Optional[float] = None) -> Params:
    stddev = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": _normal(key, (d_in, d_out), dtype, stddev)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: Array) -> Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> Array:
    """Inverse frequencies, shape (head_dim // 2,). float32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    dt = x.dtype
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    angles = angles[..., None, :]                               # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# attention (MHA / GQA / MQA, optional qk-norm, causal or bidirectional,
# optional cross-attention, optional output gate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    use_rope: bool = True
    qk_norm: bool = False
    causal: bool = True
    bias: bool = False
    gated: bool = False          # tanh-gated output (llama-vision cross blocks)
    softmax_scale: Optional[float] = None

    @property
    def q_dim(self):
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self):
        return self.n_kv_heads * self.head_dim


def init_attention(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    p = {
        "wq": init_dense(ks[0], cfg.d_model, cfg.q_dim, bias=cfg.bias, dtype=dtype),
        "wk": init_dense(ks[1], cfg.d_model, cfg.kv_dim, bias=cfg.bias, dtype=dtype),
        "wv": init_dense(ks[2], cfg.d_model, cfg.kv_dim, bias=cfg.bias, dtype=dtype),
        "wo": init_dense(ks[3], cfg.q_dim, cfg.d_model, bias=cfg.bias, dtype=dtype,
                         scale=1.0 / math.sqrt(cfg.q_dim)),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(cfg.head_dim, dtype)
        p["k_norm"] = init_rmsnorm(cfg.head_dim, dtype)
    if cfg.gated:
        p["gate"] = jnp.zeros((), dtype)
    return p


def _expand_kv(t: Array, n_heads: int) -> Array:
    """(B,T,Hkv,D) -> (B,T,H,D) by broadcasting each kv head over its q-group.

    Broadcast-then-reshape keeps the head axis shardable over `model` to the
    same degree as q's head axis (the kv source is replicated when
    Hkv < mesh model size — see core/sharding.py)."""
    B, T, Hkv, D = t.shape
    group = n_heads // Hkv
    t = jnp.broadcast_to(t[:, :, :, None, :], (B, T, Hkv, group, D))
    return t.reshape(B, T, n_heads, D)


SDPA_CHUNK = 512          # q-block size for the chunked XLA path
SDPA_CHUNK_THRESHOLD = 1024   # chunk when S*T exceeds threshold^2


def _sdpa_dense(q, k, v, *, causal, scale, q_pos, kv_len):
    B, S, H, D = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    mask = None
    if causal:
        qp = q_pos if q_pos is not None else jnp.arange(S)
        kp = jnp.arange(T)
        mask = qp[:, None] >= kp[None, :]          # (S, T)
    if kv_len is not None:
        valid = jnp.arange(T) < kv_len             # (T,)
        mask = valid[None, :] if mask is None else (mask & valid[None, :])
    if mask is not None:
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _sdpa_chunked(q, k, v, *, causal, scale, q_pos, kv_len,
                  chunk=SDPA_CHUNK):
    """Scan over query blocks: peak logits memory B*H*chunk*T instead of
    B*H*S*T.  XLA lowers the scan body once; this is the memory-sane lowering
    the dry-run uses for 4k-32k sequences (the Pallas kernel replaces it on
    real TPUs)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    C = min(chunk, S)
    pad = (-S) % C
    qp = q_pos if q_pos is not None else jnp.arange(S)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qp = jnp.pad(qp, (0, pad), constant_values=-1)   # -1 => fully masked
    nq = q.shape[1] // C
    q_blocks = jnp.moveaxis(q.reshape(B, nq, C, H, D), 1, 0)
    p_blocks = qp.reshape(nq, C)
    kp = jnp.arange(T)

    def body(_, xs):
        qb, pb = xs                                      # (B,C,H,D), (C,)
        lg = jnp.einsum("bchd,bthd->bhct", qb, k).astype(jnp.float32) * scale
        if causal:
            mask = pb[:, None] >= kp[None, :]
        else:
            mask = (pb[:, None] >= 0) & jnp.ones((1, T), bool)
        if kv_len is not None:
            mask = mask & (kp[None, :] < kv_len)
        lg = jnp.where(mask[None, None], lg, -1e30)
        pr = jax.nn.softmax(lg, axis=-1).astype(v.dtype)
        ob = jnp.einsum("bhct,bthd->bchd", pr, v)
        return 0.0, ob

    _, out = jax.lax.scan(body, 0.0, (q_blocks, p_blocks))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * C, H, D)
    return out[:, :S]


def _sdpa(q: Array, k: Array, v: Array, *, causal: bool, scale: float,
          q_pos: Optional[Array] = None, kv_len: Optional[Array] = None) -> Array:
    """q: (B,S,H,D); k,v: (B,T,Hkv,D) with Hkv | H.  Pure-jnp reference path
    (the 'xla' impl); auto-switches to the q-block-chunked form when the
    logits tensor would be large.  ``kv_len`` masks slots >= kv_len."""
    B, S, H, D = q.shape
    T = k.shape[1]
    k, v = _expand_kv(k.astype(q.dtype), H), _expand_kv(v.astype(q.dtype), H)
    if S * T > SDPA_CHUNK_THRESHOLD ** 2 and S > SDPA_CHUNK:
        return _sdpa_chunked(q, k, v, causal=causal, scale=scale,
                             q_pos=q_pos, kv_len=kv_len)
    return _sdpa_dense(q, k, v, causal=causal, scale=scale,
                       q_pos=q_pos, kv_len=kv_len)


def _paged_sdpa(q: Array, k: Array, v: Array, *, scale: float,
                q_pos: Array, kv_len: Array) -> Array:
    """SDPA with *per-sequence* causal masks: q_pos (B,S), kv_len (B,).

    Masked entries contribute exactly-zero probability (exp underflows), so
    the result is bitwise identical to the contiguous-cache decode path on
    the unmasked prefix — the greedy-parity test in tests/test_serving.py
    relies on this."""
    B, S, H, D = q.shape
    T = k.shape[1]
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    kp = jnp.arange(T)
    mask = q_pos[:, :, None] >= kp[None, None, :]            # (B,S,T) causal
    mask = mask & (kp[None, None, :] < kv_len[:, None, None])
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def paged_flat_indices(positions: Array, seq: int, block_tables: Array,
                       block_size: int,
                       new_lens: Optional[Array] = None
                       ) -> tuple[Array, Array]:
    """Logical->physical paging arithmetic shared by every paged cache
    (attention KV here, MLA latents in mla.py).

    Returns (q_pos (B, S), flat (B, S)): per-token absolute positions and
    flat row indices into an (NB * block_size, ...) pool for ``seq`` new
    tokens starting at positions[b].  Out-of-table writes (position beyond
    the table's capacity) and padded rows (>= new_lens[b]) divert to the
    null block's scratch rows — clamping them into a live block would
    silently overwrite resident state."""
    qp = positions[:, None] + jnp.arange(seq)[None, :]        # (B, S)
    logical = qp // block_size
    width = block_tables.shape[1]
    blk = jnp.take_along_axis(block_tables, jnp.minimum(logical, width - 1),
                              axis=1)
    flat = blk * block_size + qp % block_size                 # (B, S)
    flat = jnp.where(logical < width, flat, qp % block_size)
    if new_lens is not None:
        valid = jnp.arange(seq)[None, :] < new_lens[:, None]
        flat = jnp.where(valid, flat, jnp.arange(seq)[None, :] % block_size)
    return qp, flat


def paged_attention(p: Params, cfg: AttnConfig, x: Array, *,
                    cache: Params, positions: Array,
                    block_tables: Array,
                    new_lens: Optional[Array] = None) -> tuple[Array, Params]:
    """Self-attention over a block-paged KV pool (vLLM-style paged KV).

    cache: {"k": (NB, BS, Hkv, D), "v": ...} — a *physical block pool* shared
    by every request; ``block_tables`` (B, max_blocks) int32 maps each
    sequence's logical block j to a physical block (block 0 is the reserved
    null block — idle batch slots point every entry there).  ``positions``
    (B,) int32 is each sequence's token count before this call; the S new
    tokens are written at logical positions positions[b]..positions[b]+S-1
    and attention runs over the gathered logical view with per-sequence
    causal/length masks.  ``new_lens`` (B,) < S marks rows past it as
    padding: their writes are diverted to the null block and their tokens
    never enter kv_len, so callers can fix the chunk shape (one jit trace)
    regardless of actual prompt-chunk length.  Serving layer:
    repro/serving/paged_cache.py.
    """
    B, S, _ = x.shape
    NB, BS, Hkv, D = cache["k"].shape
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = dense(p["wk"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = dense(p["wv"], x).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    # scatter new k/v into their pages (flat row index = block * BS + offset;
    # overrun/padded writes divert to the null block — see paged_flat_indices)
    qp, flat = paged_flat_indices(positions, S, block_tables, BS,
                                  new_lens=new_lens)
    if cfg.use_rope:
        q = apply_rope(q, qp, cfg.rope_theta)
        k = apply_rope(k, qp, cfg.rope_theta)
    flat = flat.reshape(-1)                                  # (B*S,)
    ck = cache["k"].reshape(NB * BS, Hkv, D).at[flat].set(
        k.astype(cache["k"].dtype).reshape(B * S, Hkv, D)).reshape(NB, BS, Hkv, D)
    cv = cache["v"].reshape(NB * BS, Hkv, D).at[flat].set(
        v.astype(cache["v"].dtype).reshape(B * S, Hkv, D)).reshape(NB, BS, Hkv, D)
    # gather each sequence's pages back into logical order
    T = block_tables.shape[1] * BS
    gk = ck[block_tables].reshape(B, T, Hkv, D).astype(q.dtype)
    gv = cv[block_tables].reshape(B, T, Hkv, D).astype(q.dtype)
    scale = cfg.softmax_scale or (1.0 / math.sqrt(cfg.head_dim))
    kv_len = positions + (new_lens if new_lens is not None else S)
    out = _paged_sdpa(q, _expand_kv(gk, cfg.n_heads), _expand_kv(gv, cfg.n_heads),
                      scale=scale, q_pos=qp, kv_len=kv_len)
    y = dense(p["wo"], out.reshape(B, S, cfg.q_dim))
    return y, {"k": ck, "v": cv}


def attention(p: Params, cfg: AttnConfig, x: Array, *,
              kv_input: Optional[Array] = None,
              cache: Optional[Params] = None,
              positions: Optional[Array] = None,
              block_tables: Optional[Array] = None,
              new_lens: Optional[Array] = None,
              impl: str = "xla") -> tuple[Array, Optional[Params]]:
    """Self- or cross-attention.

    cache (decode): {"k": (B,T,Hkv,D), "v": ..., "pos": scalar int32} — new
    k/v written at ``pos``; returns updated cache.  For cross-attention the
    cache holds precomputed encoder K/V and is not updated.  When
    ``block_tables`` is given the cache is a paged block pool instead and
    dispatches to :func:`paged_attention` (per-sequence positions).
    """
    if block_tables is not None:
        assert cache is not None and positions is not None
        return paged_attention(p, cfg, x, cache=cache, positions=positions,
                               block_tables=block_tables, new_lens=new_lens)
    B, S, _ = x.shape
    src = kv_input if kv_input is not None else x
    q = dense(p["wq"], x).reshape(B, S, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
    scale = cfg.softmax_scale or (1.0 / math.sqrt(cfg.head_dim))

    is_cross = kv_input is not None or (cache is not None and "pos" not in cache)
    if is_cross:
        if kv_input is not None:      # compute (and possibly store) cross K/V
            T = kv_input.shape[1]
            k = dense(p["wk"], kv_input).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
            v = dense(p["wv"], kv_input).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
            if cfg.qk_norm:
                k = rmsnorm(p["k_norm"], k)
            new_cache = ({"k": k.astype(cache["k"].dtype),
                          "v": v.astype(cache["v"].dtype)}
                         if cache is not None else None)
        else:                          # precomputed cross K/V from the cache
            k, v = cache["k"], cache["v"]
            new_cache = cache
        if cfg.use_rope:
            q = apply_rope(q, positions if positions is not None else jnp.arange(S),
                           cfg.rope_theta)
        out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype), causal=False, scale=scale)
    else:
        k = dense(p["wk"], src).reshape(B, src.shape[1], cfg.n_kv_heads, cfg.head_dim)
        v = dense(p["wv"], src).reshape(B, src.shape[1], cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            k = rmsnorm(p["k_norm"], k)
        if cache is not None and "pos" in cache:
            # decode: write k/v at cache["pos"], attend to the full prefix
            pos = cache["pos"]
            if cfg.use_rope:
                pp = jnp.full((S,), 0, jnp.int32) + pos + jnp.arange(S)
                q = apply_rope(q, pp, cfg.rope_theta)
                k = apply_rope(k, pp, cfg.rope_theta)
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            out = _sdpa(q, ck, cv, causal=True, scale=scale,
                        q_pos=pos + jnp.arange(S), kv_len=pos + S)
            new_cache = {"k": ck, "v": cv, "pos": pos + S}
        else:
            if positions is None:
                positions = jnp.arange(S)
            if cfg.use_rope:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
            if impl == "pallas" and cfg.causal and kv_input is None:
                from repro.kernels import ops as kops
                out = kops.flash_attention(q, k, v, scale=scale)
            else:
                out = _sdpa(q, k, v, causal=cfg.causal, scale=scale)
            new_cache = None
    y = dense(p["wo"], out.reshape(B, S, cfg.q_dim))
    if cfg.gated:
        y = jnp.tanh(p["gate"].astype(y.dtype)) * y
    return y, new_cache


def init_attention_cache(cfg: AttnConfig, batch: int, max_len: int,
                         dtype=jnp.bfloat16) -> Params:
    shp = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def init_paged_attention_cache(cfg: AttnConfig, num_blocks: int,
                               block_size: int, dtype=jnp.bfloat16) -> Params:
    """Physical KV block pool shared by all requests (no batch axis; block 0
    is the reserved null block).  See :func:`paged_attention`."""
    shp = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}


# ---------------------------------------------------------------------------
# MLPs: SwiGLU / GeGLU / plain GELU
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, *, act: str = "silu",
             bias: bool = False, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_in": init_dense(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
         "w_out": init_dense(ks[2], d_ff, d_model, bias=bias, dtype=dtype,
                             scale=1.0 / math.sqrt(d_ff))}
    if act in ("silu", "geglu"):  # gated variants carry a second in-proj
        p["w_gate"] = init_dense(ks[1], d_model, d_ff, bias=bias, dtype=dtype)
    return p


def mlp(p: Params, x: Array, act: str = "silu") -> Array:
    h = dense(p["w_in"], x)
    if act == "silu":
        h = jax.nn.silu(dense(p["w_gate"], x)) * h
    elif act == "geglu":
        h = jax.nn.gelu(dense(p["w_gate"], x), approximate=True) * h
    elif act == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    elif act == "relu":
        h = jax.nn.relu(h)
    else:
        raise ValueError(act)
    return dense(p["w_out"], h)


# ---------------------------------------------------------------------------
# embeddings & head
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"embedding": _normal(key, (vocab, d_model), dtype, 1.0)}


def embed(p: Params, tokens: Array, d_model: int) -> Array:
    return jnp.take(p["embedding"], tokens, axis=0) * (d_model ** 0.5)


def unembed(p: Params, x: Array) -> Array:
    """Tied head: logits = x @ E^T (fp32 accumulation)."""
    return jnp.einsum("bsd,vd->bsv", x, p["embedding"]).astype(jnp.float32)
