"""Mixture-of-Experts layer (GShard-style dense dispatch, TPU-friendly).

Routing variants:
  * softmax top-k (Arctic)                      — ``router="softmax"``
  * sigmoid score + top-k + renormalize (DSv3)  — ``router="sigmoid"``
Optional: shared expert(s) always active (DeepSeek-V3), dense residual FFN in
parallel with the MoE branch (Arctic).

Dispatch is the capacity-based one-hot einsum (no sort/gather) so it shards
cleanly under GSPMD: experts live on the ``model`` axis (EP), tokens on
``data``.  Dropped tokens (over capacity) fall back to the residual stream.

EP-major mode (launcher-set, EXPERIMENTS.md §Perf): when weights+batch share
the ``data`` axis, expert-tensor sharding forces GSPMD to re-gather expert
weights per use (observed 12 TB/device on arctic-480b).  Setting
``EP_CONSTRAINTS = ("data", "model")`` pins the dispatched token block to an
expert-major layout — experts over ``data``, expert-FF over ``model`` — so
GSPMD lowers dispatch/combine as all-to-alls (GShard) and weights stay put.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L

Params = dict
Array = jax.Array

# (expert_axis, ff_axis, batch_axes) or None — set by the launcher before
# lowering; requires an ambient mesh (jax.set_mesh) when set.
EP_CONSTRAINTS: Optional[tuple] = None


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden dim
    n_experts: int
    top_k: int
    router: str = "softmax"    # or "sigmoid"
    capacity_factor: float = 1.25
    n_shared_experts: int = 0  # always-active shared experts (DSv3: 1)
    shared_d_ff: int = 0       # hidden dim of the shared expert branch
    dense_d_ff: int = 0        # parallel dense residual FFN (Arctic)
    act: str = "silu"
    aux_loss_weight: float = 0.01


def init_moe(key, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in = 1.0 / math.sqrt(D)
    s_out = 1.0 / math.sqrt(F)

    def estack(k, shape, stddev):
        return L._normal(k, shape, dtype, stddev)

    p = {
        "router": {"w": L._normal(ks[0], (D, E), jnp.float32, s_in)},
        "w_in": estack(ks[1], (E, D, F), s_in),     # expert-stacked
        "w_gate": estack(ks[2], (E, D, F), s_in),
        "w_out": estack(ks[3], (E, F, D), s_out),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], D, cfg.shared_d_ff or F * cfg.n_shared_experts,
                                 act=cfg.act, dtype=dtype)
    if cfg.dense_d_ff:
        p["dense"] = L.init_mlp(ks[5], D, cfg.dense_d_ff, act=cfg.act, dtype=dtype)
    return p


def _act(h: Array, g: Array, act: str) -> Array:
    if act == "silu":
        return jax.nn.silu(g) * h
    if act == "geglu":
        return jax.nn.gelu(g, approximate=True) * h
    raise ValueError(act)


def moe(p: Params, cfg: MoEConfig, x: Array) -> tuple[Array, Array]:
    """x: (B, S, D) -> (out, aux_loss).  Tokens grouped per (B) row."""
    if EP_CONSTRAINTS is not None:
        ep_ax, ff_ax, batch_axes = EP_CONSTRAINTS
        # NOTE: an explicit "un-shard seq at entry" constraint here measured
        # WORSE (13.6 vs 11.1 TB/dev on arctic — §Perf): GSPMD's own
        # placement of the seq gather inside the dispatch einsum beats a
        # forced boundary reshard.  Keep propagation free at entry.
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * K * S / E))  # per-group expert capacity

    scores = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"]["w"])
    if cfg.router == "softmax":
        probs = jax.nn.softmax(scores, axis=-1)
    else:  # sigmoid + renormalize among selected (DeepSeek-V3 style)
        probs = jax.nn.sigmoid(scores)

    gate_vals, idx = jax.lax.top_k(probs, K)            # (B,S,K)
    if cfg.router == "sigmoid":
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    else:
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # position of each (token, k) inside its expert's buffer
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)            # (B,S,K,E)
    pos_in_expert = (jnp.cumsum(onehot.reshape(B, S * K, E), axis=1)
                     .reshape(B, S, K, E) - 1)
    keep = (pos_in_expert < C) & (onehot > 0)                   # capacity mask
    # dispatch tensor (B,S,E,C): token s -> slot (e, c)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos_in_expert, -1), C, dtype=x.dtype)
    disp = jnp.einsum("bske,bskec->bsec", onehot.astype(x.dtype), pos_oh)
    comb = jnp.einsum("bsk,bske,bskec->bsec",
                      gate_vals.astype(x.dtype), onehot.astype(x.dtype), pos_oh)

    xe = jnp.einsum("bsd,bsec->becd", x, disp)                  # (B,E,C,D)
    if EP_CONSTRAINTS is not None:
        # expert-major: the dispatch becomes an all-to-all (B@ep -> E@ep)
        xe = jax.lax.with_sharding_constraint(xe, P(None, ep_ax, None, None))
    h = jnp.einsum("becd,edf->becf", xe, p["w_in"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(x.dtype))
    ye = jnp.einsum("becf,efd->becd", _act(h, g, cfg.act), p["w_out"].astype(x.dtype))
    if EP_CONSTRAINTS is not None:
        ye = jax.lax.with_sharding_constraint(ye, P(None, ep_ax, None, None))
    out = jnp.einsum("becd,bsec->bsd", ye, comb)

    if cfg.n_shared_experts:
        out = out + L.mlp(p["shared"], x, cfg.act)
    if cfg.dense_d_ff:
        out = out + L.mlp(p["dense"], x, cfg.act)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(onehot.astype(jnp.float32).sum(2), axis=(0, 1))   # fraction routed
    pe = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * E * jnp.sum(me * pe / K)
    return out, aux
