"""Block registry: init / apply / cache-init per block kind.

Blocks are the ASA's *logical components* (DESIGN.md §1): the scheduler
assigns a parallelism strategy per block kind per segment.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import mla as MLA
from repro.models import moe as MOE

Params = dict
Array = jax.Array

ZERO = jnp.zeros((), jnp.float32)


def norm_init(arch: ArchConfig, d: int, dtype) -> Params:
    return L.init_layernorm(d, dtype) if arch.norm == "layernorm" else L.init_rmsnorm(d, dtype)


def norm_apply(arch: ArchConfig, p: Params, x: Array) -> Array:
    return L.layernorm(p, x) if arch.norm == "layernorm" else L.rmsnorm(p, x)


def attn_cfg_for(arch: ArchConfig, *, causal=True, gated=False, d_model=None,
                 n_heads=None, use_rope=True) -> L.AttnConfig:
    nh = n_heads or arch.n_heads
    dm = d_model or arch.d_model
    hd = arch.resolved_head_dim if d_model is None else dm // nh
    n_kv = min(arch.n_kv_heads, nh) if d_model is None else nh
    return L.AttnConfig(
        d_model=dm, n_heads=nh, n_kv_heads=n_kv, head_dim=hd,
        rope_theta=arch.rope_theta, use_rope=use_rope and arch.rope_theta > 0,
        qk_norm=arch.qk_norm, causal=causal, bias=arch.attn_bias, gated=gated)


def moe_cfg_for(arch: ArchConfig) -> MOE.MoEConfig:
    m = arch.moe
    return MOE.MoEConfig(
        d_model=arch.d_model, d_ff=m.d_ff, n_experts=m.n_experts, top_k=m.top_k,
        router=m.router, capacity_factor=m.capacity_factor,
        n_shared_experts=m.n_shared_experts, shared_d_ff=m.shared_d_ff,
        dense_d_ff=m.dense_d_ff, act=arch.act)


def ssm_cfg_for(arch: ArchConfig) -> M2.Mamba2Config:
    s = arch.ssm
    return M2.Mamba2Config(d_model=arch.d_model, d_state=s.d_state,
                           head_dim=s.head_dim, expand=s.expand,
                           n_groups=s.n_groups, d_conv=s.d_conv, chunk=s.chunk)


def mla_cfg_for(arch: ArchConfig) -> MLA.MLAConfig:
    m = arch.mla
    return MLA.MLAConfig(d_model=arch.d_model, n_heads=arch.n_heads,
                         q_lora_rank=m.q_lora_rank, kv_lora_rank=m.kv_lora_rank,
                         qk_nope_head_dim=m.qk_nope_head_dim,
                         qk_rope_head_dim=m.qk_rope_head_dim,
                         v_head_dim=m.v_head_dim, rope_theta=arch.rope_theta)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, kind: str, arch: ArchConfig, dtype) -> Params:
    d = arch.d_model
    ks = jax.random.split(key, 6)
    if kind == "attn":
        return {"norm1": norm_init(arch, d, dtype),
                "attn": L.init_attention(ks[0], attn_cfg_for(arch), dtype),
                "norm2": norm_init(arch, d, dtype),
                "mlp": L.init_mlp(ks[1], d, arch.d_ff, act=arch.act, dtype=dtype)}
    if kind == "enc_attn":
        cfg = attn_cfg_for(arch, causal=False, use_rope=False)
        dff = arch.encoder.d_ff if arch.encoder else arch.d_ff
        return {"norm1": norm_init(arch, d, dtype),
                "attn": L.init_attention(ks[0], cfg, dtype),
                "norm2": norm_init(arch, d, dtype),
                "mlp": L.init_mlp(ks[1], d, dff, act=arch.act, dtype=dtype)}
    if kind == "moe_attn":
        return {"norm1": norm_init(arch, d, dtype),
                "attn": L.init_attention(ks[0], attn_cfg_for(arch), dtype),
                "norm2": norm_init(arch, d, dtype),
                "moe": MOE.init_moe(ks[1], moe_cfg_for(arch), dtype)}
    if kind == "mla":
        return {"norm1": norm_init(arch, d, dtype),
                "attn": MLA.init_mla(ks[0], mla_cfg_for(arch), dtype),
                "norm2": norm_init(arch, d, dtype),
                "moe": MOE.init_moe(ks[1], moe_cfg_for(arch), dtype)}
    if kind == "mla_dense":
        return {"norm1": norm_init(arch, d, dtype),
                "attn": MLA.init_mla(ks[0], mla_cfg_for(arch), dtype),
                "norm2": norm_init(arch, d, dtype),
                "mlp": L.init_mlp(ks[1], d, arch.d_ff, act=arch.act, dtype=dtype)}
    if kind == "mamba2":
        return {"norm": norm_init(arch, d, dtype),
                "mixer": M2.init_mamba2(ks[0], ssm_cfg_for(arch), dtype)}
    if kind == "cross_attn":
        cfg = attn_cfg_for(arch, causal=False, gated=True, use_rope=False)
        return {"norm1": norm_init(arch, d, dtype),
                "attn": L.init_attention(ks[0], cfg, dtype),
                "norm2": norm_init(arch, d, dtype),
                "mlp": L.init_mlp(ks[1], d, arch.d_ff, act=arch.act, dtype=dtype),
                "mlp_gate": jnp.zeros((), dtype)}
    if kind == "wdec":
        self_cfg = attn_cfg_for(arch, causal=True, use_rope=False)
        cross_cfg = attn_cfg_for(arch, causal=False, use_rope=False)
        return {"norm1": norm_init(arch, d, dtype),
                "attn": L.init_attention(ks[0], self_cfg, dtype),
                "norm2": norm_init(arch, d, dtype),
                "xattn": L.init_attention(ks[1], cross_cfg, dtype),
                "norm3": norm_init(arch, d, dtype),
                "mlp": L.init_mlp(ks[2], d, arch.d_ff, act=arch.act, dtype=dtype)}
    if kind == "shared_attn":
        # zamba2: per-application params only (projection of the shared block's
        # 2d-wide output back to d); the shared weights live in init_shared().
        return {"app_proj": L.init_dense(ks[0], 2 * d, d, dtype=dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


def init_shared(key, arch: ArchConfig, dtype) -> Params:
    """Zamba2 shared transformer block over concat(x, x0) — width 2*d."""
    d2 = 2 * arch.d_model
    cfg = attn_cfg_for(arch, d_model=d2, n_heads=arch.n_heads)
    ks = jax.random.split(key, 2)
    return {"norm1": norm_init(arch, d2, dtype),
            "attn": L.init_attention(ks[0], cfg, dtype),
            "norm2": norm_init(arch, d2, dtype),
            "mlp": L.init_mlp(ks[1], d2, arch.d_ff, act=arch.act, dtype=dtype)}


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_block_cache(kind: str, arch: ArchConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Optional[Params]:
    if kind in ("attn", "moe_attn"):
        return L.init_attention_cache(attn_cfg_for(arch), batch, max_len, dtype)
    if kind in ("mla", "mla_dense"):
        return MLA.init_mla_cache(mla_cfg_for(arch), batch, max_len, dtype)
    if kind == "mamba2":
        return M2.init_mamba2_cache(ssm_cfg_for(arch), batch)
    if kind == "cross_attn":
        cfg = attn_cfg_for(arch, causal=False, use_rope=False)
        shp = (batch, arch.n_img_tokens, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if kind == "wdec":
        cfg = attn_cfg_for(arch, causal=False, use_rope=False)
        enc_len = arch.encoder.seq_len if arch.encoder else 1500
        shp = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        self_cache = L.init_attention_cache(
            attn_cfg_for(arch, use_rope=False), batch, max_len, dtype)
        return {"self": self_cache,
                "cross": {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}}
    if kind == "shared_attn":
        cfg = attn_cfg_for(arch, d_model=2 * arch.d_model, n_heads=arch.n_heads)
        return L.init_attention_cache(cfg, batch, max_len, dtype)
    if kind == "enc_attn":
        return None
    raise ValueError(kind)


def init_paged_block_cache(kind: str, arch: ArchConfig, num_blocks: int,
                           block_size: int, dtype=jnp.bfloat16, *,
                           slots: int = 0) -> Params:
    """Serving cache pool for one block (continuous-batching engine).

    attn-family kinds — including zamba2's weight-shared block (its pool is
    stacked per *application* by init_paged_cache's repeat axis, so each of
    the shared block's applications pages its own KV) and MLA's latent
    (c_kv, k_rope) cache — get a physical *block pool* (length-indexed,
    paged through block tables).  mamba2 / cross_attn state is O(1) per
    request — not length-indexed, so paging does not apply; they get a
    *slot-indexed state pool* instead: ``slots`` rows plus a trailing
    reserved null row (see models/mamba2.mamba2_slot).  whisper's wdec
    carries both classes: paged self-attn KV plus a slot-state pool holding
    the per-request encoder cross K/V (written once at admission)."""
    if kind in ("attn", "moe_attn"):
        return L.init_paged_attention_cache(attn_cfg_for(arch), num_blocks,
                                            block_size, dtype)
    if kind == "shared_attn":
        cfg = attn_cfg_for(arch, d_model=2 * arch.d_model,
                           n_heads=arch.n_heads)
        return L.init_paged_attention_cache(cfg, num_blocks, block_size,
                                            dtype)
    if kind in ("mla", "mla_dense"):
        return MLA.init_paged_mla_cache(mla_cfg_for(arch), num_blocks,
                                        block_size, dtype)
    if kind in ("mamba2", "cross_attn", "wdec"):
        if slots <= 0:
            raise ValueError(
                f"slot-state pool for {kind!r} needs slots > 0 (one state "
                f"row per engine slot + the null row)")
        if kind == "mamba2":
            # fp32 recurrent state, matching init_block_cache's wave path
            return M2.init_mamba2_cache(ssm_cfg_for(arch), slots + 1)
        if kind == "wdec":
            if arch.encoder is None:
                raise ValueError(
                    f"{arch.name}: wdec blocks need arch.encoder (its "
                    f"seq_len sizes the per-slot cross-K/V pool)")
            self_cfg = attn_cfg_for(arch, use_rope=False)
            cross_cfg = attn_cfg_for(arch, causal=False, use_rope=False)
            enc_len = arch.encoder.seq_len
            shp = (slots + 1, enc_len, cross_cfg.n_kv_heads,
                   cross_cfg.head_dim)
            return {"self": L.init_paged_attention_cache(
                        self_cfg, num_blocks, block_size, dtype),
                    "cross": {"k": jnp.zeros(shp, dtype),
                              "v": jnp.zeros(shp, dtype)}}
        cfg = attn_cfg_for(arch, causal=False, use_rope=False)
        shp = (slots + 1, arch.n_img_tokens, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    raise ValueError(f"no paged/slot-state serving cache for block kind "
                     f"{kind!r}")


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def apply_block(p: Params, kind: str, arch: ArchConfig, x: Array, *,
                x0: Optional[Array] = None,
                cross_input: Optional[Array] = None,
                shared: Optional[Params] = None,
                cache: Optional[Params] = None,
                positions: Optional[Array] = None,
                block_tables: Optional[Array] = None,
                new_lens: Optional[Array] = None,
                slot_ids: Optional[Array] = None,
                impl: str = "xla"):
    """-> (x, new_cache, aux_loss).  ``block_tables`` selects the paged-KV
    decode path for attn-family kinds; ``slot_ids`` selects the slot-state
    pool path for mamba2 / cross_attn (see serving/cache_manager.py)."""
    aux = ZERO
    if (block_tables is not None or slot_ids is not None) and \
            kind not in ("attn", "moe_attn", "mamba2", "cross_attn",
                         "mla", "mla_dense", "shared_attn", "wdec"):
        raise ValueError(f"continuous-batching serving unsupported for block "
                         f"kind {kind!r}")
    if kind in ("attn", "enc_attn", "moe_attn"):
        causal = kind != "enc_attn"
        cfg = attn_cfg_for(arch, causal=causal, use_rope=(kind != "enc_attn"))
        h, new_cache = L.attention(p["attn"], cfg, norm_apply(arch, p["norm1"], x),
                                   cache=cache, positions=positions,
                                   block_tables=block_tables,
                                   new_lens=new_lens, impl=impl)
        x = x + h
        if kind == "moe_attn":
            h, aux = MOE.moe(p["moe"], moe_cfg_for(arch),
                             norm_apply(arch, p["norm2"], x))
        else:
            h = L.mlp(p["mlp"], norm_apply(arch, p["norm2"], x), arch.act)
        return x + h, new_cache, aux

    if kind in ("mla", "mla_dense"):
        if block_tables is not None:
            h, new_cache = MLA.mla_paged_attention(
                p["attn"], mla_cfg_for(arch),
                norm_apply(arch, p["norm1"], x), cache=cache,
                positions=positions, block_tables=block_tables,
                new_lens=new_lens)
        else:
            h, new_cache = MLA.mla_attention(p["attn"], mla_cfg_for(arch),
                                             norm_apply(arch, p["norm1"], x),
                                             cache=cache, positions=positions)
        x = x + h
        if kind == "mla":
            h, aux = MOE.moe(p["moe"], moe_cfg_for(arch),
                             norm_apply(arch, p["norm2"], x))
        else:
            h = L.mlp(p["mlp"], norm_apply(arch, p["norm2"], x), arch.act)
        return x + h, new_cache, aux

    if kind == "mamba2":
        normed = norm_apply(arch, p["norm"], x)
        if slot_ids is not None:
            h, new_cache = M2.mamba2_slot(p["mixer"], ssm_cfg_for(arch),
                                          normed, pool=cache,
                                          slot_ids=slot_ids,
                                          new_lens=new_lens, impl=impl)
        else:
            h, new_cache = M2.mamba2(p["mixer"], ssm_cfg_for(arch), normed,
                                     cache=cache, impl=impl)
        return x + h, new_cache, aux

    if kind == "cross_attn":
        cfg = attn_cfg_for(arch, causal=False, gated=True, use_rope=False)
        if slot_ids is not None:
            # slot-state pool: per-request cross K/V rows are read-only here
            # (written once at admission — transformer.admit_slot)
            rows = {"k": cache["k"][slot_ids], "v": cache["v"][slot_ids]}
            h, _ = L.attention(p["attn"], cfg,
                               norm_apply(arch, p["norm1"], x),
                               cache=rows, impl=impl)
            new_cache = cache
        else:
            h, new_cache = L.attention(p["attn"], cfg,
                                       norm_apply(arch, p["norm1"], x),
                                       kv_input=cross_input, cache=cache,
                                       impl=impl)
        x = x + h
        h = L.mlp(p["mlp"], norm_apply(arch, p["norm2"], x), arch.act)
        x = x + jnp.tanh(p["mlp_gate"].astype(h.dtype)) * h
        return x, new_cache, aux

    if kind == "wdec":
        self_cfg = attn_cfg_for(arch, causal=True, use_rope=False)
        cross_cfg = attn_cfg_for(arch, causal=False, use_rope=False)
        c_self = cache["self"] if cache is not None else None
        c_cross = cache["cross"] if cache is not None else None
        h, nc_self = L.attention(p["attn"], self_cfg,
                                 norm_apply(arch, p["norm1"], x),
                                 cache=c_self, positions=positions,
                                 block_tables=block_tables,
                                 new_lens=new_lens, impl=impl)
        x = x + h
        if slot_ids is not None:
            # slot-state pool: per-request encoder cross K/V rows are
            # read-only here (written once at admission —
            # transformer.admit_slot runs the encoder)
            rows = {"k": c_cross["k"][slot_ids], "v": c_cross["v"][slot_ids]}
            h, _ = L.attention(p["xattn"], cross_cfg,
                               norm_apply(arch, p["norm2"], x),
                               cache=rows, impl=impl)
            nc_cross = c_cross
        else:
            h, nc_cross = L.attention(p["xattn"], cross_cfg,
                                      norm_apply(arch, p["norm2"], x),
                                      kv_input=cross_input, cache=c_cross,
                                      impl=impl)
        x = x + h
        h = L.mlp(p["mlp"], norm_apply(arch, p["norm3"], x), arch.act)
        new_cache = ({"self": nc_self, "cross": nc_cross}
                     if cache is not None else None)
        return x + h, new_cache, aux

    if kind == "shared_attn":
        assert shared is not None and x0 is not None
        d2 = 2 * arch.d_model
        cfg = attn_cfg_for(arch, d_model=d2, n_heads=arch.n_heads)
        z = jnp.concatenate([x, x0], axis=-1)
        # block_tables route to the per-application paged pool (the cache
        # passed here is this application's slice of the repeat-stacked
        # pool, so weight sharing never mixes two applications' KV)
        h, new_cache = L.attention(shared["attn"], cfg,
                                   norm_apply(arch, shared["norm1"], z),
                                   cache=cache, positions=positions,
                                   block_tables=block_tables,
                                   new_lens=new_lens, impl=impl)
        z = z + h
        z = z + L.mlp(shared["mlp"], norm_apply(arch, shared["norm2"], z), arch.act)
        return x + L.dense(p["app_proj"], z), new_cache, aux

    raise ValueError(kind)
