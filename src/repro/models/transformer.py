"""StackedLM: composes an ArchConfig's segment pattern into init/apply.

Homogeneous segments are `lax.scan`ned over their repeat count (params stacked
on a leading axis) to keep HLO size and dry-run compile time bounded for
54-100-layer architectures.  Heterogeneous patterns (hybrid/VLM) are segments
whose body applies several block kinds in order.

Entry points:
  init_lm(key, arch)                          -> params
  init_cache(arch, batch, max_len, dtype)     -> cache
  lm_apply(params, arch, tokens, ...)         -> LMOutput(logits, cache, aux, hidden)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models import layers as L

Params = dict
Array = jax.Array


class LMOutput(NamedTuple):
    logits: Array
    cache: Optional[Any]
    aux: Array                       # scalar auxiliary loss (MoE balance, ...)
    hidden: Optional[Array] = None   # pre-head hidden states (for MTP)


def _compute_dtype(arch: ArchConfig):
    return jnp.bfloat16 if arch.dtype == "bfloat16" else jnp.float32


def _param_dtype(arch: ArchConfig):
    return jnp.float32 if arch.param_dtype == "float32" else jnp.bfloat16


def sinusoidal_at(positions: Array, d_model: int) -> Array:
    """Sinusoidal embeddings for arbitrary integer positions: (S,) -> (S, D)."""
    pos = positions.astype(jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((positions.shape[0], d_model))
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    # odd d_model: only floor(d/2) cos columns exist, angle has ceil(d/2)
    pe = pe.at[:, 1::2].set(jnp.cos(angle[:, : d_model // 2]))
    return pe


def sinusoidal_positions(seq_len: int, d_model: int) -> Array:
    return sinusoidal_at(jnp.arange(seq_len), d_model)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(key, arch: ArchConfig) -> Params:
    dt = _param_dtype(arch)
    n_seg = len(arch.pattern)
    ks = jax.random.split(key, n_seg + 5)
    params: Params = {
        "embed": L.init_embedding(ks[0], arch.padded_vocab, arch.d_model, dt),
        "final_norm": B.norm_init(arch, arch.d_model, dt),
    }
    if not arch.tie_embeddings:
        params["head"] = L.init_dense(ks[1], arch.d_model, arch.padded_vocab, dtype=dt)
    if any("shared_attn" in seg.blocks for seg in arch.pattern):
        params["shared"] = B.init_shared(ks[2], arch, dt)
    if arch.encoder is not None:
        enc_keys = jax.random.split(ks[3], 1)[0]
        params["encoder"] = {
            "segments": [_init_segment(enc_keys, ("enc_attn",),
                                       arch.encoder.n_layers, arch, dt)],
            "final_norm": B.norm_init(arch, arch.d_model, dt),
        }
    if arch.mtp:
        params["mtp"] = {
            "proj": L.init_dense(ks[4], 2 * arch.d_model, arch.d_model, dtype=dt),
            "block": B.init_block(jax.random.fold_in(ks[4], 1), "attn", arch, dt),
            "norm": B.norm_init(arch, arch.d_model, dt),
        }
    params["segments"] = [
        _init_segment(ks[5 + i], seg.blocks, seg.repeat, arch, dt)
        for i, seg in enumerate(arch.pattern)
    ]
    return params


def _init_segment(key, blocks: tuple, repeat: int, arch: ArchConfig, dt) -> Params:
    """Params stacked along a leading `repeat` axis (scan xs)."""
    def one(k):
        kk = jax.random.split(k, len(blocks))
        return {f"b{i}": B.init_block(kk[i], kind, arch, dt)
                for i, kind in enumerate(blocks)}
    return jax.vmap(one)(jax.random.split(key, repeat))


def init_cache(arch: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> list:
    """Per-segment stacked caches (leading repeat axis)."""
    caches = []
    for seg in arch.pattern:
        def one(_):
            return {f"b{i}": B.init_block_cache(kind, arch, batch, max_len, dtype)
                    for i, kind in enumerate(seg.blocks)}
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one(r) for r in range(seg.repeat)]) \
            if seg.repeat > 1 else jax.tree.map(lambda x: x[None], one(0))
        caches.append(stacked)
    return caches


def init_paged_cache(arch: ArchConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16, *, slots: int = 0) -> list:
    """Per-segment stacked serving cache pools (leading repeat axis).

    Two state classes, side by side (serving/cache_manager.py is the host
    side of both):
      * attn-family blocks — including zamba2's shared block (per-
        application pools via this function's repeat stacking) and MLA's
        latent cache — get *paged block pools*: no batch axis; the pool is
        shared by every in-flight request and indexed through per-request
        block tables (layers.paged_attention, mla.mla_paged_attention);
      * mamba2 / cross_attn blocks get *slot-indexed state pools* — leading
        axis ``slots + 1`` (O(1)-per-request state: one row per engine slot
        plus a reserved null row for inactive batch rows).  ``slots`` must
        be > 0 when the pattern contains such blocks.  wdec blocks carry
        both: a paged self-attn pool and a slot-state encoder-K/V pool."""
    caches = []
    for seg in arch.pattern:
        def one(_):
            return {f"b{i}": B.init_paged_block_cache(kind, arch, num_blocks,
                                                      block_size, dtype,
                                                      slots=slots)
                    for i, kind in enumerate(seg.blocks)}
        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[one(r) for r in range(seg.repeat)]) \
            if seg.repeat > 1 else jax.tree.map(lambda x: x[None], one(0))
        caches.append(stacked)
    return caches


def encode_frontend(params: Params, arch: ArchConfig, frontend: Array, *,
                    impl: str = "xla", remat: str = "none",
                    act_sharding=None) -> Array:
    """Run the fixed-length encoder stack over precomputed frame embeddings
    (B, enc_len, d_model) -> encoder output (B, enc_len, d_model).  Shared
    by the training/wave forward (lm_apply's audio branch) and by serving
    admission (admit_slot runs it ONCE per request, never per step)."""
    cdt = _compute_dtype(arch)
    enc = frontend.astype(cdt)
    enc = enc + sinusoidal_positions(enc.shape[1], arch.d_model).astype(cdt)
    enc_p = params["encoder"]
    for segp in enc_p["segments"]:
        enc, _, _ = _apply_segment(segp, ("enc_attn",), arch, enc,
                                   impl=impl, remat=remat,
                                   act_sharding=act_sharding)
    return B.norm_apply(arch, enc_p["final_norm"], enc)


def _scatter_cross_kv(pool: Params, slot_id, attn_stack: Params,
                      cfg, src: Array) -> Params:
    """Project ``src`` (T, d_model) through each application's wk/wv (params
    stacked over the segment repeat axis) and write the result into this
    slot's rows of a (repeat, slots+1, T, Hkv, D) cross-K/V pool.  Shared by
    the cross_attn (vision frontend) and wdec (encoder output) admission
    branches so the projection convention cannot drift between them."""
    def kv_of(pl, cfg=cfg, f=src):
        k = L.dense(pl["wk"], f).reshape(-1, cfg.n_kv_heads, cfg.head_dim)
        v = L.dense(pl["wv"], f).reshape(-1, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            k = L.rmsnorm(pl["k_norm"], k)
        return k, v

    k, v = jax.vmap(kv_of)(attn_stack)                       # (repeat, T, ..)
    return {"k": pool["k"].at[:, slot_id].set(k.astype(pool["k"].dtype)),
            "v": pool["v"].at[:, slot_id].set(v.astype(pool["v"].dtype))}


def admit_slot(params: Params, arch: ArchConfig, pools: list, slot_id,
               frontend: Optional[Array] = None) -> list:
    """Reset one engine slot's rows across every slot-state pool (paged KV
    block pools pass through untouched — block reuse is handled by the
    allocator instead).

    mamba2 rows are zeroed (fresh recurrent state for the admitted request;
    recompute-style preemption re-admits through here, so the re-prefill
    starts from a clean h0).  cross_attn rows are zeroed, or — when the
    admitted request carries ``frontend`` patch embeddings (1, T, d_model) —
    filled with the cross K/V projections computed *once* here, never again
    per step (the wave Server recomputes nothing either: it serves zero
    cross K/V, which the zeroed path reproduces exactly).  wdec rows get
    the encoder cross K/V: ``frontend`` frame embeddings (1, enc_len,
    d_model) run through the encoder stack once, then every decoder layer's
    cross projections are written into this slot's rows; without a frontend
    the rows are zeroed (matching the wave Server, which never filled its
    cross cache)."""
    cdt = _compute_dtype(arch)
    enc_out = None
    if frontend is not None and \
            any("wdec" in seg.blocks for seg in arch.pattern):
        enc_out = encode_frontend(params, arch, frontend)[0]     # (T, D)
    out = []
    for si, seg in enumerate(arch.pattern):
        segp = params["segments"][si]
        d = {}
        for bi, kind in enumerate(seg.blocks):
            key = f"b{bi}"
            pool = pools[si][key]
            if kind == "wdec":
                cross = pool["cross"]
                if enc_out is None:
                    newc = jax.tree.map(lambda t: t.at[:, slot_id].set(0.0),
                                        cross)
                else:
                    cfg = B.attn_cfg_for(arch, causal=False, use_rope=False)
                    newc = _scatter_cross_kv(cross, slot_id,
                                             segp[key]["xattn"], cfg,
                                             enc_out.astype(cdt))
                d[key] = {"self": pool["self"], "cross": newc}
            elif kind == "mamba2":
                d[key] = jax.tree.map(lambda t: t.at[:, slot_id].set(0.0),
                                      pool)
            elif kind == "cross_attn":
                if frontend is None:
                    d[key] = jax.tree.map(lambda t: t.at[:, slot_id].set(0.0),
                                          pool)
                else:
                    cfg = B.attn_cfg_for(arch, causal=False, gated=True,
                                         use_rope=False)
                    d[key] = _scatter_cross_kv(pool, slot_id,
                                               segp[key]["attn"], cfg,
                                               frontend[0].astype(cdt))
            else:
                d[key] = pool
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

REMAT_POLICIES = {
    "none": None,
    "full": "everything",
    "selective": "dots",        # save matmul outputs w/o batch dims (MaxText-style)
}


def _constrain(x, act_sharding):
    """Pin the layer-boundary activation sharding (GSPMD loses the batch
    sharding inside checkpointed scan bodies otherwise — production
    frameworks always constrain layer inputs)."""
    if act_sharding is None or x is None:
        return x
    return jax.lax.with_sharding_constraint(x, act_sharding)


def _apply_segment(seg_params, blocks, arch, x, *, seg_cache=None, x0=None,
                   cross_input=None, shared=None, positions=None,
                   block_tables=None, new_lens=None, slot_ids=None,
                   impl="xla",
                   unroll: int = 1, remat: str = "none", act_sharding=None):
    """Scan the segment body over its repeat axis.  ``remat`` applies
    per-layer activation checkpointing inside the scan (the standard
    scan-over-layers + remat pattern — O(1) activation memory in depth)."""
    has_cache = seg_cache is not None

    def body(carry, xs):
        x, aux = carry
        x = _constrain(x, act_sharding)
        p_stack, c_stack = xs if has_cache else (xs, None)
        new_caches = {}
        for i, kind in enumerate(blocks):
            bi = f"b{i}"
            c = c_stack[bi] if has_cache else None
            x, nc, a = B.apply_block(
                p_stack[bi], kind, arch, x, x0=x0, cross_input=cross_input,
                shared=shared, cache=c, positions=positions,
                block_tables=block_tables, new_lens=new_lens,
                slot_ids=slot_ids, impl=impl)
            if has_cache:
                new_caches[bi] = nc
            aux = aux + a
        x = _constrain(x, act_sharding)
        return (x, aux), (new_caches if has_cache else B.ZERO)

    if remat != "none" and not has_cache:
        policy = (None if remat == "full" else
                  jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        body = jax.checkpoint(body, policy=policy)

    xs = (seg_params, seg_cache) if has_cache else seg_params
    (x, aux), ys = jax.lax.scan(body, (x, B.ZERO), xs, unroll=unroll)
    return x, aux, (ys if has_cache else None)


def lm_apply(params: Params, arch: ArchConfig, tokens: Optional[Array] = None, *,
             cache: Optional[list] = None,
             frontend: Optional[Array] = None,
             positions: Optional[Array] = None,
             block_tables: Optional[Array] = None,
             new_lens: Optional[Array] = None,
             slot_ids: Optional[Array] = None,
             impl: str = "xla",
             remat: str = "none",
             act_sharding=None,
             return_hidden: bool = False) -> LMOutput:
    """Forward pass.

    tokens: (B, S) int32 — LM/decoder tokens (None for pure-frontend encoders).
    cache:  per-segment stacked caches; None => training forward.
    frontend: precomputed modality embeddings —
       vlm:   (B, n_img_tokens, d_model) patch embeddings -> cross-attn input
       audio: (B, enc_len, d_model) frame embeddings -> encoder input
    block_tables: (B, max_blocks) int32 — marks ``cache`` as paged block
       pools (init_paged_cache); requires per-sequence ``positions`` (B,).
       ``new_lens`` (B,) marks token rows past it as padding (fixed-shape
       prompt chunks; see layers.paged_attention).
    slot_ids: (B,) int32 — pool rows for the slot-indexed state pools
       (mamba2 state, cross-attn K/V); inactive batch rows point at the
       reserved null row (= slots).  Required alongside block_tables when
       the pattern contains slot-state blocks.
    """
    cdt = _compute_dtype(arch)
    aux_total = B.ZERO

    cross_input = None
    if arch.frontend == "vision" and frontend is not None:
        cross_input = frontend.astype(cdt)
    if arch.frontend == "audio" and frontend is not None:
        cross_input = encode_frontend(params, arch, frontend, impl=impl,
                                      remat=remat, act_sharding=act_sharding)

    x = L.embed(params["embed"], tokens, arch.d_model).astype(cdt)
    if arch.encoder is not None:   # whisper decoder: absolute sinusoidal positions
        if cache is None:
            pe = sinusoidal_positions(x.shape[1], arch.d_model)
        elif block_tables is not None:
            # paged serving: each batch row decodes at its own absolute
            # position, so the PE is per-row (B, S, D)
            pe = jax.vmap(lambda p0: sinusoidal_at(
                p0 + jnp.arange(x.shape[1]), arch.d_model))(positions)
        else:  # decode: offset from the first wdec self-attn cache position
            pos0 = cache[0]["b0"]["self"]["pos"][0]
            pe = sinusoidal_at(pos0 + jnp.arange(x.shape[1]), arch.d_model)
        x = x + pe.astype(cdt)

    if positions is None and cache is None:
        positions = jnp.arange(x.shape[1])

    x = _constrain(x, act_sharding)
    x0 = x  # original embeddings (zamba2 shared-block input)
    new_caches = []
    for si, seg in enumerate(arch.pattern):
        seg_cache = cache[si] if cache is not None else None
        x, aux, nc = _apply_segment(
            params["segments"][si], seg.blocks, arch, x,
            seg_cache=seg_cache, x0=x0, cross_input=cross_input,
            shared=params.get("shared"), positions=positions,
            block_tables=block_tables, new_lens=new_lens, slot_ids=slot_ids,
            impl=impl, remat=remat, act_sharding=act_sharding)
        aux_total = aux_total + aux
        new_caches.append(nc)

    hidden = B.norm_apply(arch, params["final_norm"], x)
    if arch.tie_embeddings:
        logits = L.unembed(params["embed"], hidden)
    else:
        logits = L.dense(params["head"], hidden).astype(jnp.float32)

    return LMOutput(logits, new_caches if cache is not None else None,
                    aux_total, hidden if return_hidden else None)


def mtp_logits(params: Params, arch: ArchConfig, hidden: Array,
               tokens: Array) -> Array:
    """DeepSeek-V3-style multi-token prediction head (depth 1): combine the
    final hidden state at position t with the embedding of token t+1 to
    predict token t+2.  Returns logits (B, S, V) aligned so that
    logits[:, t] predicts tokens[:, t+2]."""
    mtp = params["mtp"]
    cdt = hidden.dtype
    emb_next = L.embed(params["embed"], jnp.roll(tokens, -1, axis=1),
                       arch.d_model).astype(cdt)
    h = L.dense(mtp["proj"], jnp.concatenate(
        [B.norm_apply(arch, mtp["norm"], hidden), emb_next], axis=-1))
    h, _, _ = B.apply_block(mtp["block"], "attn", arch, h,
                            positions=jnp.arange(h.shape[1]))
    return L.unembed(params["embed"], h) if arch.tie_embeddings \
        else L.dense(params["head"], h).astype(jnp.float32)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def lm_loss(logits: Array, labels: Array, vocab: int,
            mask: Optional[Array] = None) -> Array:
    """Cross-entropy with padded-vocab masking (labels < vocab always).

    Vocab-parallel formulation: only reductions touch the (possibly
    `model`-sharded) vocab axis — no gather, so GSPMD lowers to partial
    reductions + tiny (B,S) all-reduces instead of all-gathering the fp32
    logits (Megatron's vocab-parallel cross-entropy)."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    vid = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
    if V > vocab:   # mask padding logits out of the softmax
        logits = jnp.where(vid < vocab, logits, -1e30)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    tgt_mask = vid == labels[..., None]
    tgt = jnp.sum(jnp.where(tgt_mask, logits, 0.0), axis=-1)
    nll = lse - tgt
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
