"""Paper's own models: ViT-B/16 and ResNet-50 with CIFAR-100 heads.

These reproduce the paper's experimental setting (Section IV-A).  Both expose
``components()`` metadata consumed by the ASA cost model (benchmarks) in the
same way the LM archs do.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict
Array = jax.Array


# ---------------------------------------------------------------------------
# ViT
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ViTConfig:
    image_size: int = 32          # CIFAR-100
    patch: int = 4                # 32/4 = 8x8 = 64 patches (paper uses /16 at 224)
    d_model: int = 768
    n_layers: int = 12
    n_heads: int = 12
    d_ff: int = 3072
    n_classes: int = 100
    dtype: str = "float32"

    @property
    def n_patches(self):
        return (self.image_size // self.patch) ** 2


def init_vit(key, cfg: ViTConfig) -> Params:
    dt = jnp.float32 if cfg.dtype == "float32" else jnp.bfloat16
    ks = jax.random.split(key, cfg.n_layers + 4)
    patch_dim = 3 * cfg.patch * cfg.patch
    acfg = L.AttnConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_heads,
                        head_dim=cfg.d_model // cfg.n_heads,
                        use_rope=False, causal=False, bias=True)

    def layer(k):
        kk = jax.random.split(k, 2)
        return {"norm1": L.init_layernorm(cfg.d_model, dt),
                "attn": L.init_attention(kk[0], acfg, dt),
                "norm2": L.init_layernorm(cfg.d_model, dt),
                "mlp": L.init_mlp(kk[1], cfg.d_model, cfg.d_ff, act="gelu",
                                  bias=True, dtype=dt)}

    return {
        "patch_proj": L.init_dense(ks[0], patch_dim, cfg.d_model, bias=True, dtype=dt),
        "cls": L._normal(ks[1], (1, 1, cfg.d_model), dt, 0.02),
        "pos": L._normal(ks[2], (1, cfg.n_patches + 1, cfg.d_model), dt, 0.02),
        "layers": jax.vmap(layer)(jax.random.split(ks[3], cfg.n_layers)),
        "final_norm": L.init_layernorm(cfg.d_model, dt),
        "head": L.init_dense(ks[-1], cfg.d_model, cfg.n_classes, bias=True, dtype=dt),
    }


def vit_apply(params: Params, cfg: ViTConfig, images: Array) -> Array:
    """images: (B, H, W, 3) -> logits (B, n_classes)."""
    Bsz = images.shape[0]
    p = cfg.patch
    g = cfg.image_size // p
    x = images.reshape(Bsz, g, p, g, p, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(Bsz, g * g, p * p * 3)
    x = L.dense(params["patch_proj"], x)
    cls = jnp.broadcast_to(params["cls"], (Bsz, 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"]

    acfg = L.AttnConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                        n_kv_heads=cfg.n_heads,
                        head_dim=cfg.d_model // cfg.n_heads,
                        use_rope=False, causal=False, bias=True)

    def body(x, lp):
        h, _ = L.attention(lp["attn"], acfg, L.layernorm(lp["norm1"], x))
        x = x + h
        x = x + L.mlp(lp["mlp"], L.layernorm(lp["norm2"], x), "gelu")
        return x, 0.0

    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.layernorm(params["final_norm"], x)
    return L.dense(params["head"], x[:, 0]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# ResNet-50 (BN with batch statistics; CIFAR stem)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    stage_sizes: tuple = (3, 4, 6, 3)   # ResNet-50
    width: int = 64
    n_classes: int = 100
    image_size: int = 32


def _init_conv(key, kh, kw, cin, cout) -> Params:
    fan_in = kh * kw * cin
    return {"w": L._normal(key, (kh, kw, cin, cout), jnp.float32,
                           math.sqrt(2.0 / fan_in))}


def _conv(p, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _init_bn(c) -> Params:
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def _init_bottleneck(key, cin, cmid, cout, stride) -> Params:
    ks = jax.random.split(key, 4)
    p = {"conv1": _init_conv(ks[0], 1, 1, cin, cmid), "bn1": _init_bn(cmid),
         "conv2": _init_conv(ks[1], 3, 3, cmid, cmid), "bn2": _init_bn(cmid),
         "conv3": _init_conv(ks[2], 1, 1, cmid, cout), "bn3": _init_bn(cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _init_conv(ks[3], 1, 1, cin, cout)
        p["proj_bn"] = _init_bn(cout)
    return p


def _bottleneck(p, x, stride):
    r = x
    y = jax.nn.relu(_bn(p["bn1"], _conv(p["conv1"], x)))
    y = jax.nn.relu(_bn(p["bn2"], _conv(p["conv2"], y, stride)))
    y = _bn(p["bn3"], _conv(p["conv3"], y))
    if "proj" in p:
        r = _bn(p["proj_bn"], _conv(p["proj"], x, stride))
    return jax.nn.relu(y + r)


def init_resnet(key, cfg: ResNetConfig) -> Params:
    ks = jax.random.split(key, 2 + len(cfg.stage_sizes))
    params = {"stem": _init_conv(ks[0], 3, 3, 3, cfg.width),
              "stem_bn": _init_bn(cfg.width)}
    cin = cfg.width
    for s, n_blocks in enumerate(cfg.stage_sizes):
        cmid = cfg.width * (2 ** s)
        cout = cmid * 4
        bkeys = jax.random.split(ks[1 + s], n_blocks)
        blocks = []
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            blocks.append(_init_bottleneck(bkeys[b], cin, cmid, cout, stride))
            cin = cout
        params[f"stage{s}"] = blocks
    params["head"] = L.init_dense(ks[-1], cin, cfg.n_classes, bias=True)
    return params


def resnet_apply(params: Params, cfg: ResNetConfig, images: Array) -> Array:
    x = jax.nn.relu(_bn(params["stem_bn"], _conv(params["stem"], images)))
    for s, n_blocks in enumerate(cfg.stage_sizes):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            x = _bottleneck(params[f"stage{s}"][b], x, stride)
    x = jnp.mean(x, axis=(1, 2))
    return L.dense(params["head"], x).astype(jnp.float32)
