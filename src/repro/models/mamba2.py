"""Mamba2 block — SSD (state-space duality) form, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic term
that maps onto the MXU + inter-chunk linear recurrence); decode is the O(1)
recurrent update carrying ``(conv_state, ssm_state)``.  The chunked form here
is also the oracle for ``kernels/ssd_scan.py``.

TPU-native sharding note (DESIGN.md §2): projections are kept *separate*
(z/x/B/C/dt + per-stream causal convs) instead of the reference fused
``in_proj``: the fused layout slices a concatenated output dim at boundaries
that do not align with a 16-way `model` shard, forcing GSPMD reshards.  With
separate weights, x/z/dt shard by SSM head over `model`, B/C stay replicated
(they are per-group and tiny), and every SSD einsum keeps the head axis
sharded end-to-end.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict
Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def n_heads(self):
        return self.d_inner // self.head_dim

    @property
    def d_bc(self):
        return self.n_groups * self.d_state


def init_mamba2(key, cfg: Mamba2Config, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    H = cfg.n_heads
    dt = jnp.exp(jax.random.uniform(ks[6], (H,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    conv_scale = 1.0 / math.sqrt(cfg.d_conv)
    return {
        "z_proj": L.init_dense(ks[0], cfg.d_model, cfg.d_inner, dtype=dtype),
        "x_proj": L.init_dense(ks[1], cfg.d_model, cfg.d_inner, dtype=dtype),
        "b_proj": L.init_dense(ks[2], cfg.d_model, cfg.d_bc, dtype=dtype),
        "c_proj": L.init_dense(ks[3], cfg.d_model, cfg.d_bc, dtype=dtype),
        "dt_proj": L.init_dense(ks[4], cfg.d_model, H, dtype=dtype),
        "conv_x": {"w": L._normal(ks[5], (cfg.d_conv, cfg.d_inner), dtype, conv_scale),
                   "b": jnp.zeros((cfg.d_inner,), dtype)},
        "conv_b": {"w": L._normal(jax.random.fold_in(ks[5], 1),
                                  (cfg.d_conv, cfg.d_bc), dtype, conv_scale),
                   "b": jnp.zeros((cfg.d_bc,), dtype)},
        "conv_c": {"w": L._normal(jax.random.fold_in(ks[5], 2),
                                  (cfg.d_conv, cfg.d_bc), dtype, conv_scale),
                   "b": jnp.zeros((cfg.d_bc,), dtype)},
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.init_rmsnorm(cfg.d_inner, dtype),
        "out_proj": L.init_dense(ks[7], cfg.d_inner, cfg.d_model, dtype=dtype,
                                 scale=1.0 / math.sqrt(cfg.d_inner)),
    }


def init_mamba2_cache(cfg: Mamba2Config, batch: int, dtype=jnp.float32) -> Params:
    K = cfg.d_conv - 1
    return {
        "conv_x": jnp.zeros((batch, K, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, K, cfg.d_bc), dtype),
        "conv_c": jnp.zeros((batch, K, cfg.d_bc), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), dtype),
    }


def _causal_conv(u: Array, conv: Params,
                 left: Optional[Array] = None) -> Array:
    """Depthwise causal conv1d + silu. u: (B,S,C); w: (K,C).

    ``left`` (B, K-1, C) supplies the raw inputs *preceding* u — the carried
    conv buffer during chunked prefill.  None means start-of-sequence
    (zero left context, identical to the old zero-padding)."""
    w = conv["w"]
    K = w.shape[0]
    if left is None:
        pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([left.astype(u.dtype), u], axis=1)
    out = sum(pad[:, k: k + u.shape[1], :] * w[k].astype(u.dtype) for k in range(K))
    return jax.nn.silu(out + conv["b"].astype(u.dtype))


def _conv_tail(buf: Array, raw: Array,
               new_lens: Optional[Array] = None) -> Array:
    """Next conv buffer: last (d_conv-1) valid raw inputs of buffer+chunk.

    buf: (B, K, C) carried buffer; raw: (B, S, C) this chunk's raw conv
    inputs; new_lens (B,) marks rows >= new_lens[b] as padding to skip.
    Always yields K rows even when the valid chunk is shorter than K (the
    old buffer supplies the missing left context)."""
    K = buf.shape[1]
    full = jnp.concatenate([buf, raw.astype(buf.dtype)], axis=1)  # (B,K+S,C)
    if new_lens is None:
        return full[:, -K:, :]
    idx = (new_lens[:, None] + jnp.arange(K))[:, :, None]         # (B,K,1)
    return jnp.take_along_axis(full, idx, axis=1)


def _conv_step(u_new: Array, buf: Array, conv: Params) -> tuple[Array, Array]:
    """One-token conv update. u_new: (B,1,C); buf: (B,K-1,C)."""
    w = conv["w"]
    full = jnp.concatenate([buf, u_new.astype(buf.dtype)], axis=1)  # (B,K,C)
    out = sum(full[:, k, :] * w[k].astype(buf.dtype) for k in range(w.shape[0]))
    out = jax.nn.silu(out + conv["b"].astype(buf.dtype))
    return out[:, None, :], full[:, 1:, :]


def _ssd_chunked(cfg: Mamba2Config, x, Bm, Cm, dt_a, h0=None):
    """Chunked SSD scan (pure jnp oracle).

    x: (B,S,H,P); Bm,Cm: (B,S,G,N); dt_a = (dt (B,S,H), a (B,S,H)).
    Returns (y (B,S,H,P) fp32, h_final (B,H,P,N) fp32).
    """
    dt, a = dt_a
    Bsz, S_orig, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.chunk, S_orig)
    if S_orig % Q:  # pad: dt=0, a=0 => decay 1, zero input — state unaffected
        pad = Q - S_orig % Q
        def padf(t):
            return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, Bm, Cm, dt, a = map(padf, (x, Bm, Cm, dt, a))
    S = x.shape[1]
    nc = S // Q
    hpg = H // G

    def rc(t, extra):  # reshape into chunks, chunk axis leading (scan xs)
        return jnp.moveaxis(t.reshape((Bsz, nc, Q) + extra), 1, 0)

    xs_ = (rc(x.astype(jnp.float32), (H, P)),
           rc(Bm.astype(jnp.float32), (G, N)),
           rc(Cm.astype(jnp.float32), (G, N)),
           rc(dt, (H,)), rc(a, (H,)))
    head_group = jnp.arange(H) // hpg
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def body(h, inp):
        """One chunk: intra-chunk quadratic term + carried recurrent state.
        Peak temp is (B,Q,Q,H) for a single chunk — the scan keeps the whole
        sequence's decay tensors from materializing at once."""
        x_c, B_c, C_c, dt_c, a_c = inp                 # (B,Q,...)
        cum = jnp.cumsum(a_c, axis=1)                  # (B,Q,H)
        seg = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,Q,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bign,bjgn->bijg", C_c, B_c)   # (B,Q,Q,G)
        cb = jnp.repeat(cb, hpg, axis=-1)              # g -> h
        scores = cb * decay * dt_c[:, None, :, :]
        y = jnp.einsum("bijh,bjhp->bihp", scores, x_c)
        # inter-chunk: contribution of the carried state
        Ch = C_c[:, :, head_group, :]                  # (B,Q,H,N)
        y = y + jnp.einsum("bqhn,bhpn->bqhp", Ch, h) * jnp.exp(cum)[..., None]
        # state update: h' = decay_chunk * h + sum_j exp(cum_end-cum_j) dt_j B_j x_j
        Bh = B_c[:, :, head_group, :]
        dec_end = jnp.exp(cum[:, -1:, :] - cum)        # (B,Q,H)
        bx = jnp.einsum("bqh,bqhp,bqhn->bhpn", dec_end * dt_c, x_c, Bh)
        h = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + bx
        return h, y

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, ys = jax.lax.scan(body, h0.astype(jnp.float32), xs_)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y[:, :S_orig], h_final


def mamba2(p: Params, cfg: Mamba2Config, x: Array, *,
           cache: Optional[Params] = None,
           new_lens: Optional[Array] = None,
           impl: str = "xla") -> tuple[Array, Optional[Params]]:
    """x: (B,S,D).  With ``cache`` and S==1 runs the recurrent decode path.

    With ``cache`` and S>1 (prefill) the cached conv buffers supply the raw
    left context and the cached SSM state seeds the scan (h0), so a prompt
    may be fed in several chunks and the handoff state is exact at every
    chunk boundary.  ``new_lens`` (B,) marks token rows >= new_lens[b] as
    padding: their dt is zeroed (decay 1, zero input — state untouched) and
    they never enter the carried conv buffer, so fixed-shape prompt chunks
    trace once (see layers.paged_attention for the attention analogue)."""
    Bsz, S, D = x.shape
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    z = L.dense(p["z_proj"], x)
    xr = L.dense(p["x_proj"], x)
    br = L.dense(p["b_proj"], x)
    cr = L.dense(p["c_proj"], x)
    dt_raw = L.dense(p["dt_proj"], x)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    head_group = jnp.arange(H) // (H // G)

    if cache is not None and S == 1:
        xu, conv_x = _conv_step(xr, cache["conv_x"], p["conv_x"])
        bu, conv_b = _conv_step(br, cache["conv_b"], p["conv_b"])
        cu, conv_c = _conv_step(cr, cache["conv_c"], p["conv_c"])
        xs = xu.reshape(Bsz, H, P).astype(jnp.float32)
        Bm = bu.reshape(Bsz, G, N).astype(jnp.float32)
        Cm = cu.reshape(Bsz, G, N).astype(jnp.float32)
        a = jnp.exp(dt[:, 0] * A[None, :])                         # (B,H)
        Bh, Chd = Bm[:, head_group, :], Cm[:, head_group, :]       # (B,H,N)
        h = (cache["ssm"].astype(jnp.float32) * a[:, :, None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt[:, 0], xs, Bh))
        y = jnp.einsum("bhpn,bhn->bhp", h, Chd)
        y = y + p["D"][None, :, None] * xs
        y = y.reshape(Bsz, 1, cfg.d_inner)
        new_cache = {"conv_x": conv_x, "conv_b": conv_b, "conv_c": conv_c,
                     "ssm": h.astype(cache["ssm"].dtype)}
    else:
        left_x = cache["conv_x"] if cache is not None else None
        left_b = cache["conv_b"] if cache is not None else None
        left_c = cache["conv_c"] if cache is not None else None
        xc = _causal_conv(xr, p["conv_x"], left=left_x)
        bc = _causal_conv(br, p["conv_b"], left=left_b)
        cc = _causal_conv(cr, p["conv_c"], left=left_c)
        xs = xc.reshape(Bsz, S, H, P)
        Bm = bc.reshape(Bsz, S, G, N)
        Cm = cc.reshape(Bsz, S, G, N)
        if new_lens is not None:
            # padded tail rows: dt=0 => decay 1, zero input — state untouched
            valid = jnp.arange(S)[None, :] < new_lens[:, None]     # (B,S)
            dt = jnp.where(valid[:, :, None], dt, 0.0)
        a = dt * A[None, None, :]                                  # (B,S,H)
        h0 = cache["ssm"] if cache is not None else None
        if impl == "pallas":
            from repro.kernels import ops as kops
            y, h_final = kops.ssd_scan(cfg, xs, Bm, Cm, dt, a, h0=h0)
        else:
            y, h_final = _ssd_chunked(cfg, xs, Bm, Cm, (dt, a), h0=h0)
        y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(Bsz, S, cfg.d_inner)
        if cache is not None:
            # prefill -> decode handoff: the next conv buffer is the last
            # (d_conv-1) *valid* raw inputs of buffer+chunk — prepending the
            # old buffer left-pads prompts shorter than d_conv-1 with the
            # carried (initially zero) context instead of under-filling
            new_cache = {
                "conv_x": _conv_tail(left_x, xr, new_lens),
                "conv_b": _conv_tail(left_b, br, new_lens),
                "conv_c": _conv_tail(left_c, cr, new_lens),
                "ssm": h_final.astype(cache["ssm"].dtype),
            }
        else:
            new_cache = None

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = L.rmsnorm(p["norm"], y)
    return L.dense(p["out_proj"], y), new_cache


def mamba2_slot(p: Params, cfg: Mamba2Config, x: Array, *,
                pool: Params, slot_ids: Array,
                new_lens: Optional[Array] = None,
                impl: str = "xla") -> tuple[Array, Params]:
    """Serving path over a *slot-indexed state pool* (continuous batching).

    pool: the mamba2 cache tree with a leading (slots+1) row axis shared by
    all in-flight requests — row i holds engine slot i's recurrent state and
    the last row is the reserved null slot (the slot-state analogue of the
    paged-KV null block).  ``slot_ids`` (B,) maps each batch row to its pool
    row; inactive batch rows point at the null slot, so their garbage
    updates scatter into scratch that no live request ever reads.

    Gather rows -> run the exact wave-path recurrence/chunked scan on them
    (decode when S==1 and new_lens is None, chunk-prefill otherwise, with
    the SSM state carried as h0 across chunks) -> scatter updated rows back.
    """
    rows = jax.tree.map(lambda t: t[slot_ids], pool)
    decode = x.shape[1] == 1 and new_lens is None
    y, new_rows = mamba2(p, cfg, x, cache=rows,
                         new_lens=None if decode else new_lens, impl=impl)
    new_pool = jax.tree.map(
        lambda t, n: t.at[slot_ids].set(n.astype(t.dtype)), pool, new_rows)
    return y, new_pool
