"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Queries go through a low-rank down/up projection; keys/values are generated
from a compressed latent ``c_kv`` (kv_lora_rank) plus a shared rotary key
``k_rope``.  Decode caches only ``(c_kv, k_rope)`` — ~(512+64) floats/token
instead of 2*128*128 for vanilla MHA — which is the whole point of MLA.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

Params = dict
Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_head_dim(self):
        return self.qk_nope_head_dim + self.qk_rope_head_dim


def init_mla(key, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    H = cfg.n_heads
    return {
        "wq_a": L.init_dense(ks[0], cfg.d_model, cfg.q_lora_rank, dtype=dtype),
        "q_norm": L.init_rmsnorm(cfg.q_lora_rank, dtype),
        "wq_b": L.init_dense(ks[1], cfg.q_lora_rank, H * cfg.qk_head_dim, dtype=dtype),
        "wkv_a": L.init_dense(ks[2], cfg.d_model,
                              cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype=dtype),
        "kv_norm": L.init_rmsnorm(cfg.kv_lora_rank, dtype),
        "wk_b": L.init_dense(ks[3], cfg.kv_lora_rank, H * cfg.qk_nope_head_dim, dtype=dtype),
        "wv_b": L.init_dense(ks[4], cfg.kv_lora_rank, H * cfg.v_head_dim, dtype=dtype),
        "wo": L.init_dense(ks[5], H * cfg.v_head_dim, cfg.d_model, dtype=dtype,
                           scale=1.0 / math.sqrt(H * cfg.v_head_dim)),
    }


def init_mla_cache(cfg: MLAConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Params:
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def init_paged_mla_cache(cfg: MLAConfig, num_blocks: int, block_size: int,
                         dtype=jnp.bfloat16) -> Params:
    """Physical latent block pools shared by all requests (no batch axis;
    block 0 is the reserved null block).  MLA's whole point — caching only
    (c_kv, k_rope) per token — carries over to paging: a block holds
    block_size latent rows instead of block_size KV head vectors."""
    return {"c_kv": jnp.zeros((num_blocks, block_size, cfg.kv_lora_rank),
                              dtype),
            "k_rope": jnp.zeros((num_blocks, block_size,
                                 cfg.qk_rope_head_dim), dtype)}


def _project_q(p, cfg: MLAConfig, x, positions):
    B, S, _ = x.shape
    q = L.dense(p["wq_b"], L.rmsnorm(p["q_norm"], L.dense(p["wq_a"], x)))
    q = q.reshape(B, S, cfg.n_heads, cfg.qk_head_dim)
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = L.apply_rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


MLA_CHUNK = 512


def _attend(cfg: MLAConfig, q_nope, q_rope, c_kv, k_rope, p, *,
            q_positions, kv_len=None):
    """Latent-space attention: score via up-projected keys, value from c_kv.

    q_nope: (B,S,H,dn)  q_rope: (B,S,H,dr)  c_kv: (B,T,r)  k_rope: (B,T,dr)
    q_positions: (S,) shared across the batch (contiguous cache) or (B,S)
    per-row (paged serving); kv_len: None, scalar, or (B,) per-row.
    Absorbed form: score_nope = (q_nope @ wk_b^T) @ c_kv^T — contracts in the
    rank-r latent space, so no per-token key materialization (decode-fast).
    Long sequences scan over q blocks (logits memory B*H*C*T, not B*H*S*T).
    """
    B, S, H, dn = q_nope.shape
    T = c_kv.shape[1]
    wk = p["wk_b"]["w"].reshape(cfg.kv_lora_rank, H, dn)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk.astype(q_nope.dtype))
    scale = 1.0 / math.sqrt(cfg.qk_head_dim)
    kp = jnp.arange(T)
    ckv = c_kv.astype(q_nope.dtype)
    krope = k_rope.astype(q_rope.dtype)
    qpb = jnp.broadcast_to(q_positions, (B, S)) \
        if q_positions.ndim == 1 else q_positions             # (B, S)
    kvl = None if kv_len is None else jnp.broadcast_to(kv_len, (B,))

    def block(q_lat_b, q_rope_b, pos_b):
        s_nope = jnp.einsum("bshr,btr->bhst", q_lat_b, ckv)
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope_b, krope)
        lg = (s_nope + s_rope).astype(jnp.float32) * scale
        mask = pos_b[:, :, None] >= kp[None, None, :]         # (B, C, T)
        if kvl is not None:
            mask = mask & (kp[None, None, :] < kvl[:, None, None])
        lg = jnp.where(mask[:, None], lg, -1e30)
        pr = jax.nn.softmax(lg, axis=-1).astype(ckv.dtype)
        return jnp.einsum("bhst,btr->bshr", pr, ckv)      # latent context

    if S * T > 1024 * 1024 and S > MLA_CHUNK:
        C = MLA_CHUNK
        pad = (-S) % C
        qlp = jnp.pad(q_lat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qrp = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pp = jnp.pad(qpb, ((0, 0), (0, pad)), constant_values=-1)
        nq = qlp.shape[1] // C
        xs = (jnp.moveaxis(qlp.reshape(B, nq, C, H, -1), 1, 0),
              jnp.moveaxis(qrp.reshape(B, nq, C, H, -1), 1, 0),
              jnp.moveaxis(pp.reshape(B, nq, C), 1, 0))
        _, ys = jax.lax.scan(lambda _, x: (0.0, block(*x)), 0.0, xs)
        ctx_lat = jnp.moveaxis(ys, 0, 1).reshape(B, nq * C, H, -1)[:, :S]
    else:
        ctx_lat = block(q_lat, q_rope, qpb)

    wv = p["wv_b"]["w"].reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
    ctx = jnp.einsum("bshr,rhv->bshv", ctx_lat.astype(q_nope.dtype),
                     wv.astype(q_nope.dtype))
    return ctx.reshape(B, S, H * cfg.v_head_dim)


def mla_paged_attention(p: Params, cfg: MLAConfig, x: Array, *,
                        cache: Params, positions: Array,
                        block_tables: Array,
                        new_lens: Optional[Array] = None
                        ) -> tuple[Array, Params]:
    """Latent attention over block-paged (c_kv, k_rope) pools — the MLA
    analogue of layers.paged_attention, same flat-index scheme: new latents
    scatter at block_tables[b, pos // BS] * BS + pos % BS, out-of-table and
    padded-row writes divert to the null block, and attention runs over the
    gathered logical view with per-sequence causal/length masks.  Masked
    entries contribute exactly-zero probability, so greedy decode is
    token-identical to the contiguous-cache path on the unmasked prefix."""
    B, S, _ = x.shape
    NB, BS, r = cache["c_kv"].shape
    kv = L.dense(p["wkv_a"], x)
    c_kv = L.rmsnorm(p["kv_norm"], kv[..., : cfg.kv_lora_rank])
    k_rope_new = kv[..., cfg.kv_lora_rank:]
    qp, flat = L.paged_flat_indices(positions, S, block_tables, BS,
                                    new_lens=new_lens)
    k_rope_new = L.apply_rope(k_rope_new[:, :, None, :], qp,
                              cfg.rope_theta)[:, :, 0, :]
    flat = flat.reshape(-1)
    cc = cache["c_kv"].reshape(NB * BS, r).at[flat].set(
        c_kv.astype(cache["c_kv"].dtype).reshape(B * S, r)).reshape(NB, BS, r)
    dr = cache["k_rope"].shape[-1]
    cr = cache["k_rope"].reshape(NB * BS, dr).at[flat].set(
        k_rope_new.astype(cache["k_rope"].dtype).reshape(B * S, dr)
        ).reshape(NB, BS, dr)
    T = block_tables.shape[1] * BS
    g_ckv = cc[block_tables].reshape(B, T, r)
    g_rope = cr[block_tables].reshape(B, T, dr)
    q_nope, q_rope = _project_q(p, cfg, x, qp)
    kv_len = positions + (new_lens if new_lens is not None else S)
    ctx = _attend(cfg, q_nope, q_rope, g_ckv, g_rope, p,
                  q_positions=qp, kv_len=kv_len)
    return L.dense(p["wo"], ctx), {"c_kv": cc, "k_rope": cr}


def mla_attention(p: Params, cfg: MLAConfig, x: Array, *,
                  cache: Optional[Params] = None,
                  positions: Optional[Array] = None) -> tuple[Array, Optional[Params]]:
    B, S, _ = x.shape
    kv = L.dense(p["wkv_a"], x)
    c_kv = L.rmsnorm(p["kv_norm"], kv[..., : cfg.kv_lora_rank])
    k_rope_new = kv[..., cfg.kv_lora_rank:]

    if cache is not None:
        pos = cache["pos"]
        positions = pos + jnp.arange(S)
        k_rope_new = L.apply_rope(k_rope_new[:, :, None, :], positions,
                                  cfg.rope_theta)[:, :, 0, :]
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1)
        q_nope, q_rope = _project_q(p, cfg, x, positions)
        ctx = _attend(cfg, q_nope, q_rope, cc, cr, p,
                      q_positions=positions, kv_len=pos + S)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": pos + S}
    else:
        if positions is None:
            positions = jnp.arange(S)
        k_rope_new = L.apply_rope(k_rope_new[:, :, None, :], positions,
                                  cfg.rope_theta)[:, :, 0, :]
        q_nope, q_rope = _project_q(p, cfg, x, positions)
        ctx = _attend(cfg, q_nope, q_rope, c_kv, k_rope_new, p,
                      q_positions=positions)
        new_cache = None
    return L.dense(p["wo"], ctx), new_cache
