"""Input pipeline substrate.

Deterministic synthetic sources (LM token streams, CIFAR-100-like images)
with the production loader features the paper's coordinator needs:
host-sharded loading, restart offsets (checkpoint/restart), background
prefetch, and straggler-aware shard reassignment hooks.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import numpy as np


class SyntheticLM:
    """Deterministic synthetic LM batches: Zipf-ish token stream with
    next-token labels.  step-indexed => restartable from any offset."""

    def __init__(self, vocab: int, seq_len: int, batch: int, *,
                 seed: int = 0, start_step: int = 0):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed
        self.step = start_step

    def skip(self, n: int):
        self.step += n
        return self

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        # zipf-flavored distribution over the real vocab
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class SyntheticImages:
    """CIFAR-100-like labeled images (paper's dataset, synthesized):
    class-conditional gaussian blobs so accuracy is learnable."""

    def __init__(self, n_classes: int = 100, image_size: int = 32,
                 batch: int = 128, *, seed: int = 0, start_step: int = 0):
        self.n_classes, self.image_size, self.batch = n_classes, image_size, batch
        self.seed, self.step = seed, start_step
        rng = np.random.default_rng(seed)
        self.class_means = rng.normal(0, 1.0, (n_classes, 8)).astype(np.float32)

    def skip(self, n: int):
        self.step += n
        return self

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step + 1))
        labels = rng.integers(0, self.n_classes, self.batch).astype(np.int32)
        base = self.class_means[labels]                        # (B, 8)
        proj = np.random.default_rng(self.seed + 7).normal(
            0, 1, (8, self.image_size * self.image_size * 3)).astype(np.float32)
        imgs = (base @ proj).reshape(self.batch, self.image_size,
                                     self.image_size, 3)
        imgs += rng.normal(0, 0.7, imgs.shape).astype(np.float32)
        self.step += 1
        return {"images": imgs.astype(np.float32), "labels": labels}


class HostShardedLoader:
    """Splits the global batch across hosts; reassigns shards away from
    hosts whose heartbeats go stale (straggler mitigation, DESIGN.md §7)."""

    def __init__(self, source_factory: Callable[[int, int], Iterator[dict]],
                 n_hosts: int, host_id: int, *,
                 heartbeat_timeout_s: float = 30.0):
        self.n_hosts, self.host_id = n_hosts, host_id
        self.timeout = heartbeat_timeout_s
        self.heartbeats = {h: time.monotonic() for h in range(n_hosts)}
        self._factory = source_factory
        self._build()

    def _build(self):
        self.assigned = self._live_assignment()
        self.sources = {s: self._factory(s, self.n_hosts)
                        for s in self.assigned}

    def heartbeat(self, host: int, t: Optional[float] = None):
        self.heartbeats[host] = t if t is not None else time.monotonic()

    def _live_assignment(self) -> list[int]:
        now = time.monotonic()
        live = [h for h in range(self.n_hosts)
                if now - self.heartbeats[h] <= self.timeout]
        if self.host_id not in live:
            return []
        idx = live.index(self.host_id)
        # dead hosts' shards are taken over round-robin by live hosts
        return [s for s in range(self.n_hosts) if s % len(live) == idx] \
            if len(live) < self.n_hosts else [self.host_id]

    def __next__(self) -> list[dict]:
        new = self._live_assignment()
        if new != self.assigned:
            self.assigned = new
            self.sources = {s: self._factory(s, self.n_hosts) for s in new}
        return [next(self.sources[s]) for s in self.assigned]


class Prefetcher:
    """Background-thread prefetch queue (overlap host input with device
    compute)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self.q.put(item)
            finally:
                self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
