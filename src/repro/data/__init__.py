from repro.data.pipeline import (SyntheticLM, SyntheticImages, Prefetcher,
                                 HostShardedLoader)
