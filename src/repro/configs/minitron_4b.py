"""minitron-4b [dense] — pruned Nemotron geometry (arXiv:2407.14679):
24 heads (24 % 16 != 0 -> attention mixer replicated under MP, DESIGN.md §5).
long_500k skipped."""
from repro.configs.base import ArchConfig, Segment

ARCH = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    pattern=(Segment(("attn",), 32),),
)
