"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block
(arXiv:2411.15242).  54 Mamba2 layers; one *shared-weight* transformer block
applied every 6 layers (9 applications) on concat(hidden, embeddings), with a
per-application output projection.  Runs long_500k (sub-quadratic)."""
from repro.configs.base import ArchConfig, SSMSpec, Segment

ARCH = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    act="geglu",
    pattern=(Segment(("shared_attn", "mamba2", "mamba2", "mamba2",
                      "mamba2", "mamba2", "mamba2"), 9),),
    ssm=SSMSpec(d_state=64, head_dim=64, expand=2, n_groups=1),
    sub_quadratic=True,
    tie_embeddings=True,
    notes="shared attn block on 2*d_model concat; 9 applications over 54 mamba layers",
)
