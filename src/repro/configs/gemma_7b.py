"""gemma-7b [dense] — GeGLU, head_dim=256 (q_dim 4096 > d_model 3072),
16 heads MHA (arXiv:2403.08295).  long_500k skipped."""
from repro.configs.base import ArchConfig, Segment

ARCH = ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    act="geglu",
    tie_embeddings=True,
    pattern=(Segment(("attn",), 28),),
)
