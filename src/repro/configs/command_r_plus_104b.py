"""command-r-plus-104b [dense] — GQA kv=8, no-bias
(hf:CohereForAI/c4ai-command-r-v01 family).  long_500k skipped."""
from repro.configs.base import ArchConfig, Segment

ARCH = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    pattern=(Segment(("attn",), 64),),
    tie_embeddings=True,
    notes="sequential pre-norm blocks (upstream uses parallel attn+FFN; "
          "sequential kept for substrate uniformity — FLOPs identical)",
)
