"""mamba2-780m [ssm] — attention-free SSD (arXiv:2405.21060).
d_inner=3072, 48 heads x head_dim 64, d_state=128.  Decode carries O(1)
recurrent state; runs long_500k."""
from repro.configs.base import ArchConfig, SSMSpec, Segment

ARCH = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,          # attention-free; SSD heads live in SSMSpec
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    pattern=(Segment(("mamba2",), 48),),
    ssm=SSMSpec(d_state=128, head_dim=64, expand=2, n_groups=1),
    sub_quadratic=True,
    tie_embeddings=True,
)
