"""whisper-medium [audio] — encoder-decoder (arXiv:2212.04356).
Conv frontend STUBBED: input_specs() supplies precomputed frame embeddings
(B, 1500, d_model).  Assigned seq lens apply to the decoder; decode_32k =
decoder self-attn KV 32k + cross-attn KV 1500.  long_500k skipped.

Serving: ContinuousBatchingEngine pages the decoder self-attn KV and holds
each request's encoder cross K/V in slot-state rows — the 1500-frame
encoder runs ONCE at admission on the request's ``frontend`` embeddings
(transformer.admit_slot), so decode steps never touch the encoder."""
from repro.configs.base import ArchConfig, EncoderSpec, Segment

ARCH = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    attn_bias=True,
    tie_embeddings=True,
    pattern=(Segment(("wdec",), 24),),
    encoder=EncoderSpec(n_layers=24, seq_len=1500, d_ff=4096),
    frontend="audio",
)
