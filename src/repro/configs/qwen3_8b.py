"""qwen3-8b [dense] — per-head q/k RMSNorm, GQA kv=8 (hf:Qwen/Qwen3-8B).
long_500k skipped."""
from repro.configs.base import ArchConfig, Segment

ARCH = ArchConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    pattern=(Segment(("attn",), 36),),
)
