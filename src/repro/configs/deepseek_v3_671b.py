"""deepseek-v3-671b [moe] — MLA + 1 shared + 256 routed top-8 experts + MTP
(arXiv:2412.19437).  First 3 layers dense (d_ff 18432); 58 MoE layers with
per-expert d_ff=2048; sigmoid routing renormalized over the selected top-8."""
from repro.configs.base import ArchConfig, MLASpec, MoESpec, Segment

ARCH = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                 # dense-layer FFN (first 3 layers)
    vocab=129280,
    pattern=(Segment(("mla_dense",), 3), Segment(("mla",), 58)),
    moe=MoESpec(n_experts=256, top_k=8, d_ff=2048, router="sigmoid",
                n_shared_experts=1, shared_d_ff=2048, capacity_factor=1.25),
    mla=MLASpec(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                qk_rope_head_dim=64, v_head_dim=128),
    mtp=True,
    notes="MLA latent KV cache (512+64/token); MTP depth-1 head",
)
