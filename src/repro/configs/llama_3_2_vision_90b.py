"""llama-3.2-vision-90b [vlm] — 100-layer backbone: 80 self-attention +
20 gated cross-attention layers (every 5th).  Vision frontend is a STUB:
input_specs() supplies precomputed patch embeddings (B, 1601, d_model).
long_500k skipped (pure full attention)."""
from repro.configs.base import ArchConfig, Segment

ARCH = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    rope_theta=500000.0,
    pattern=(Segment(("attn", "attn", "attn", "attn", "cross_attn"), 20),),
    frontend="vision",
    n_img_tokens=1601,
    notes="tanh-gated cross-attn/MLP on image layers; frontend stubbed",
)
