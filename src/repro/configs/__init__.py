"""Architecture registry: the 10 assigned archs + the paper's own models."""
from __future__ import annotations

import dataclasses

from repro.configs.base import (ArchConfig, EncoderSpec, MLASpec, MoESpec,
                                Segment, ShapeSpec, SHAPES, SSMSpec,
                                shape_applicable)


def _load():
    from repro.configs import (arctic_480b, command_r_plus_104b,
                               deepseek_v3_671b, gemma_7b,
                               llama_3_2_vision_90b, mamba2_780m,
                               minitron_4b, qwen3_8b, whisper_medium,
                               zamba2_2_7b)
    mods = [zamba2_2_7b, arctic_480b, deepseek_v3_671b, llama_3_2_vision_90b,
            command_r_plus_104b, gemma_7b, qwen3_8b, minitron_4b,
            mamba2_780m, whisper_medium]
    return {m.ARCH.name: m.ARCH for m in mods}


ARCHS: dict[str, ArchConfig] = _load()


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def reduce_for_smoke(arch: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small width, few
    layers/experts, tiny vocab — structure preserved."""
    pattern = tuple(Segment(s.blocks, min(s.repeat, 2)) for s in arch.pattern)
    kw = dict(
        name=arch.name + "-smoke",
        d_model=128,
        n_heads=4,
        n_kv_heads=min(arch.n_kv_heads, 4) if arch.n_kv_heads < arch.n_heads else 4,
        head_dim=32 if arch.head_dim else None,
        d_ff=256 if arch.d_ff else 0,
        vocab=512,
        n_layers=sum(len(s.blocks) * min(s.repeat, 2) for s in arch.pattern),
        pattern=pattern,
        dtype="float32",
        param_dtype="float32",
        n_img_tokens=min(arch.n_img_tokens, 16),
    )
    if arch.moe:
        kw["moe"] = dataclasses.replace(
            arch.moe, n_experts=4, top_k=min(arch.moe.top_k, 2), d_ff=64,
            shared_d_ff=64 if arch.moe.n_shared_experts else 0,
            dense_d_ff=64 if arch.moe.dense_d_ff else 0, capacity_factor=2.0)
    if arch.ssm:
        kw["ssm"] = dataclasses.replace(arch.ssm, d_state=16, head_dim=16,
                                        chunk=16)
    if arch.mla:
        kw["mla"] = MLASpec(q_lora_rank=64, kv_lora_rank=32,
                            qk_nope_head_dim=32, qk_rope_head_dim=16,
                            v_head_dim=32)
    if arch.encoder:
        kw["encoder"] = EncoderSpec(n_layers=2, seq_len=24, d_ff=256)
    return dataclasses.replace(arch, **kw)


__all__ = ["ARCHS", "get_arch", "reduce_for_smoke", "SHAPES", "ShapeSpec",
           "ArchConfig", "Segment", "MoESpec", "SSMSpec", "MLASpec",
           "EncoderSpec", "shape_applicable"]
