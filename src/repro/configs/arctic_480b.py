"""arctic-480b [moe] — Snowflake Arctic: dense-MoE hybrid, 128 experts top-2
with a parallel dense residual FFN (hf:Snowflake/snowflake-arctic-base)."""
from repro.configs.base import ArchConfig, MoESpec, Segment

ARCH = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,                # 56 % 16 != 0: attention mixer weights stay
    n_kv_heads=8,              # replicated under MP (DESIGN.md §5)
    d_ff=4864,
    vocab=32000,
    pattern=(Segment(("moe_attn",), 35),),
    moe=MoESpec(n_experts=128, top_k=2, d_ff=4864, dense_d_ff=4864,
                capacity_factor=1.25),
    notes="dense residual FFN in parallel with 128e top-2 MoE",
)
