"""Architecture config schema.

An ``ArchConfig`` fully describes a model in the zoo: geometry, block kinds,
and the *stack pattern* — an ordered list of ``Segment``s, each a group of
block kinds scanned ``repeat`` times.  Scanning over homogeneous groups keeps
HLO size (and dry-run compile time) bounded for 54–100-layer archs.

Block kinds:
  attn        — self-attention (GQA/MQA/qk-norm) + dense MLP
  mla         — multi-head latent attention + (dense | MoE) FFN
  moe_attn    — self-attention + MoE FFN
  mamba2      — SSD block (attention-free)
  shared_attn — zamba2-style *shared-weight* attention block (params shared
                across all applications; per-application output projection)
  cross_attn  — gated cross-attention + MLP (llama-vision)
  enc_attn    — bidirectional self-attention + MLP (encoders)
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Segment:
    blocks: tuple[str, ...]   # block kinds applied in order within the group
    repeat: int               # group is scanned `repeat` times


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden
    router: str = "softmax"
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    dense_d_ff: int = 0            # arctic parallel dense FFN
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class MLASpec:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderSpec:
    n_layers: int
    seq_len: int              # fixed encoder length (whisper: 1500 frames)
    d_ff: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None      # default d_model // n_heads
    act: str = "silu"
    qk_norm: bool = False
    attn_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    pattern: tuple[Segment, ...] = ()
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    mla: Optional[MLASpec] = None
    encoder: Optional[EncoderSpec] = None     # enc-dec archs
    frontend: Optional[str] = None            # "audio" | "vision" stub
    n_img_tokens: int = 1601                  # vlm stub cross-kv length
    mtp: bool = False                         # DeepSeek-V3 multi-token predict
    sub_quadratic: bool = False               # eligible for long_500k
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"             # production default; smoke: fp32
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding table shards
        evenly over model(16) x data(16) (Megatron practice).  Loss masks the
        padding logits."""
        return ((self.vocab + 255) // 256) * 256

    def scaled(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# input shapes assigned to the LM family (see system spec)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k only runs on sub-quadratic archs (SSM/hybrid)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("skipped: pure full-attention arch — a 512k dense-attention "
                       "KV decode requires sub-quadratic attention (DESIGN.md §5)")
    return True, ""
