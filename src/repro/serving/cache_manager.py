"""Unified serving cache manager: paged KV block pools + slot-state pools.

The continuous-batching engine juggles two classes of per-request state,
and this module is the single host-side owner of both:

  * **length-indexed** — attention KV (and MLA's latent ``c_kv/k_rope``)
    grows one entry per token.  It lives in fixed-size physical blocks
    (paged_cache.py: free-list allocator + per-request block tables over
    the pools from models/transformer.init_paged_cache; zamba2's
    weight-shared block pages one pool per application via the
    repeat-stacked axis).  Block 0 is the reserved null block for idle
    slots / padded table tails / overrun writes.

  * **slot-indexed** — mamba2 ``conv_x/conv_b/conv_c/ssm`` state,
    cross-attention K/V and whisper's per-request encoder K/V (the
    ``wdec`` cross pool) are O(1) per request regardless of generated
    length.  They live in pools with one row per engine slot plus a
    trailing reserved **null slot** row (the slot-state analogue of the
    null block): inactive batch rows in a fixed-shape decode step gather
    and scatter against the null row, so their garbage never touches a
    live request's state.  Rows are reset on admission (runtime/steps.
    make_slot_admit_step — mamba2 zeroed, cross K/V computed once from the
    request's frontend embeddings or zeroed), the SSM state is carried as
    ``h0`` across prefill chunks, and recompute-style preemption needs no
    extra handling: re-admission re-zeroes the row and the re-prefill
    replays prompt + generated tokens through it.

Both classes share one device pytree (and one SchedulePlan
paged_cache_specs sharding tree — SSM head axis over `model`, kv-head axis
over `model`), so the jitted paged steps thread a single donated cache.

Prefix sharing (paged_cache.py ``share_prefix``) applies to the
length-indexed class ONLY: a paged attention/latent block's KV at position
i is a pure function of the token prefix, so equal hash chains imply equal
content and blocks can be handed to a second request.  Slot-state rows are
the opposite — mamba2's recurrent state is accumulated *by running
prefill* over every prompt token, so skipping matched tokens would leave
it wrong, and cross-attn / wdec encoder K/V are per-request admission
outputs (frontend-dependent) with no content key.  Constructing a
UnifiedCacheManager with ``share_prefix`` for an arch carrying any
slot-state kind therefore raises up front rather than serving corrupt
state.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.paged_cache import PagedCacheConfig, PagedKVCache

# length-indexed caches, block-paged through per-request tables.  zamba2's
# weight-shared block pages one pool per application (the repeat-stacked
# leading axis), MLA pages its latent (c_kv, k_rope) rows.
PAGEABLE_KINDS = {"attn", "moe_attn", "shared_attn", "mla", "mla_dense",
                  "wdec"}
# O(1)-per-request state, slot-indexed: mamba2 recurrent state, cross-attn
# K/V, and wdec's per-request encoder K/V (wdec carries BOTH classes: paged
# self-attn KV plus the slot-state cross pool filled once at admission).
SLOT_STATE_KINDS = {"mamba2", "cross_attn", "wdec"}
SERVABLE_KINDS = PAGEABLE_KINDS | SLOT_STATE_KINDS


def check_servable(arch: ArchConfig) -> None:
    """Raise when the continuous engine cannot serve this architecture.

    Every block kind in the registry — attention-family, MoE, MLA latent
    attention, mamba2 SSM, gated cross-attention, zamba2's weight-shared
    block and whisper's encoder-decoder — now has a paged or slot-state
    path, so this only fires for a kind the serving cache layer has never
    seen (a guard for future archs, not a supported-subset check)."""
    kinds = {k for seg in arch.pattern for k in seg.blocks}
    unsupported = kinds - SERVABLE_KINDS
    if unsupported:
        raise ValueError(
            f"continuous engine cannot serve {arch.name}: block kinds "
            f"{sorted(unsupported)} have no paged/slot-state serving cache "
            f"(see serving/cache_manager.py)")
    if arch.encoder is not None and "wdec" not in kinds:
        # the admission-time encoder pass lands its K/V in wdec cross pools;
        # an encoder arch without wdec decoder blocks would silently serve
        # raw (un-encoded) frontend projections
        raise ValueError(
            f"continuous engine cannot serve {arch.name}: arch.encoder "
            f"requires wdec decoder blocks to receive the encoder K/V at "
            f"admission")


class UnifiedCacheManager(PagedKVCache):
    """PagedKVCache plus slot-state row bookkeeping.

    The block side (reserve / release / can_fit / table_array) is inherited
    unchanged.  The slot side is deliberately thin: engine slot i *is* pool
    row i, so admission/finish need no allocation — only the null-row
    mapping for inactive batch rows, provided by :meth:`slot_ids_array`.
    """

    def __init__(self, arch: ArchConfig, cfg: PagedCacheConfig, *,
                 dtype=None, mesh=None, specs=None):
        check_servable(arch)
        kinds = {k for seg in arch.pattern for k in seg.blocks}
        self.slot_state_kinds = sorted(kinds & SLOT_STATE_KINDS)
        if self.slot_state_kinds and cfg.slots <= 0:
            raise ValueError(f"{arch.name} carries slot-state caches "
                             f"({self.slot_state_kinds}) — cfg.slots must "
                             f"be the engine slot count")
        if cfg.share_prefix and self.slot_state_kinds:
            raise ValueError(
                f"prefix sharing cannot serve {arch.name}: slot-state rows "
                f"({self.slot_state_kinds}) are per-request — mamba2 "
                f"recurrent state is built by prefilling every prompt token "
                f"(a matched prefix would be skipped, leaving it wrong) and "
                f"cross-attn/wdec K/V are admission-time frontend outputs "
                f"with no content key.  Only purely paged archs "
                f"(attention / MLA block kinds) may share; serve this arch "
                f"with share_prefix=False")
        kw = {} if dtype is None else {"dtype": dtype}
        super().__init__(arch, cfg, mesh=mesh, specs=specs, **kw)

    @property
    def has_slot_state(self) -> bool:
        return bool(self.slot_state_kinds)

    @property
    def null_slot(self) -> int:
        """Reserved scratch row index (= cfg.slots): inactive batch rows
        gather/scatter here, mirroring the null block."""
        return self.cfg.slots

    def slot_ids_array(self, rows: list[Optional[int]]) -> np.ndarray:
        """(B,) int32 pool-row vector: the given slot row (``_Slot.idx``)
        for active batch rows, the null slot row for None (inactive)."""
        return np.asarray([self.null_slot if r is None else r
                           for r in rows], np.int32)

    def stats(self) -> dict:
        """Paged-layer stats plus the slot-state dimension of the unified
        cache (which state classes this arch carries, and how many rows)."""
        out = super().stats()
        out["slot_state_kinds"] = list(self.slot_state_kinds)
        out["slot_rows"] = self.cfg.slots if self.has_slot_state else 0
        return out
