"""Serving metrics: per-request TTFT/TPOT plus engine-level counters.

All timestamps are caller-supplied ``time.perf_counter()`` floats (the
engine owns the clock; tests pass synthetic times).  A request that has not
reached a lifecycle point yet reports ``None`` for the latencies that
depend on it (an in-flight request has no finish time — subtracting a
missing timestamp used to fabricate large negative TTFT/TPOT) and is
skipped by the ``summary()`` means.  ``to_json()`` emits the full report;
``write()`` drops it next to the benchmark outputs.

Cache pressure: the engine samples ``PagedKVCache.utilization`` every step
(``block_utilization_mean/max``) and reports prefix-cache admission
matches (``prefix_hit_rate`` — matched tokens / looked-up context tokens,
0.0 when sharing is off).
"""
from __future__ import annotations

import json
import time
from typing import Optional


def _mean(xs):
    return sum(xs) / len(xs) if xs else 0.0


class ServingMetrics:
    def __init__(self):
        self.submit_t: dict[int, float] = {}
        self.first_token_t: dict[int, float] = {}
        self.finish_t: dict[int, float] = {}
        self.token_counts: dict[int, int] = {}
        self.queue_depth_samples: list[int] = []
        self.occupancy_samples: list[float] = []
        self.block_utilization_samples: list[float] = []
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.preemptions = 0
        self.engine_steps = 0
        self.prefill_chunks = 0
        self.decode_steps = 0

    # -- request lifecycle --------------------------------------------------
    def on_submit(self, rid: int, now: Optional[float] = None):
        self.submit_t[rid] = time.perf_counter() if now is None else now

    def on_first_token(self, rid: int, now: Optional[float] = None):
        # only the first time: a preempted+resumed request keeps its TTFT
        if rid not in self.first_token_t:
            self.first_token_t[rid] = time.perf_counter() if now is None else now

    def on_finish(self, rid: int, n_tokens: int, now: Optional[float] = None):
        self.finish_t[rid] = time.perf_counter() if now is None else now
        self.token_counts[rid] = n_tokens

    def on_preempt(self, rid: int):
        self.preemptions += 1

    def on_prefix_match(self, hit_tokens: int, lookup_tokens: int):
        """One admission-time prefix lookup: ``hit_tokens`` of the
        ``lookup_tokens``-token context were served from cached blocks."""
        self.prefix_hit_tokens += hit_tokens
        self.prefix_lookup_tokens += lookup_tokens

    # -- engine step --------------------------------------------------------
    def on_step(self, queue_depth: int, busy_slots: int, slots: int,
                block_utilization: Optional[float] = None):
        self.engine_steps += 1
        self.queue_depth_samples.append(queue_depth)
        self.occupancy_samples.append(busy_slots / max(slots, 1))
        if block_utilization is not None:
            self.block_utilization_samples.append(block_utilization)

    # -- report -------------------------------------------------------------
    def request_report(self, rid: int) -> dict:
        """Latency report for one request id.  Missing lifecycle points
        yield ``None`` (submitted-not-started has no TTFT; started-not-
        finished has no TPOT) — never a negative latency fabricated from a
        defaulted timestamp."""
        submit = self.submit_t.get(rid)
        first = self.first_token_t.get(rid)
        finish = self.finish_t.get(rid)
        n = self.token_counts.get(rid, 0)
        ttft = None if submit is None or first is None else first - submit
        if first is None or finish is None:
            tpot = None
        else:
            # time-per-output-token after the first
            tpot = (finish - first) / max(n - 1, 1)
        return {"id": rid, "n_tokens": n, "ttft_s": ttft, "tpot_s": tpot}

    def summary(self) -> dict:
        reqs = [self.request_report(r) for r in sorted(self.finish_t)]
        ttfts = [r["ttft_s"] for r in reqs if r["ttft_s"] is not None]
        tpots = [r["tpot_s"] for r in reqs if r["tpot_s"] is not None]
        total_tokens = sum(self.token_counts.values())
        if self.submit_t and self.finish_t:
            span = max(self.finish_t.values()) - min(self.submit_t.values())
        else:
            span = 0.0
        return {
            "requests": reqs,
            "completed": len(self.finish_t),
            "total_tokens": total_tokens,
            "tokens_per_sec": total_tokens / span if span > 0 else 0.0,
            "ttft_mean_s": _mean(ttfts),
            "ttft_max_s": max(ttfts, default=0.0),
            "tpot_mean_s": _mean(tpots),
            "queue_depth_mean": _mean(self.queue_depth_samples),
            "queue_depth_max": max(self.queue_depth_samples, default=0),
            "slot_occupancy_mean": _mean(self.occupancy_samples),
            "block_utilization_mean": _mean(self.block_utilization_samples),
            "block_utilization_max": max(self.block_utilization_samples,
                                         default=0.0),
            "prefix_hit_rate": (self.prefix_hit_tokens
                                / self.prefix_lookup_tokens
                                if self.prefix_lookup_tokens else 0.0),
            "preemptions": self.preemptions,
            "engine_steps": self.engine_steps,
            "prefill_chunks": self.prefill_chunks,
            "decode_steps": self.decode_steps,
        }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.summary(), **extra}, indent=2)

    def write(self, path: str, **extra) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(**extra) + "\n")
