"""Serving metrics: per-request TTFT/TPOT plus engine-level counters.

All timestamps are caller-supplied floats from ONE clock: the engine
stamps every lifecycle point (submit / first token / finish) with its
injectable ``clock``, so a test driving the engine with a synthetic clock
gets coherent TTFT/TPOT end to end — the old split (synthetic submit
times, real ``perf_counter()`` first-token stamps) fabricated bogus
latencies.  A request that has not reached a lifecycle point yet reports
``None`` for the latencies that depend on it (an in-flight request has no
finish time — subtracting a missing timestamp used to fabricate large
negative TTFT/TPOT) and is skipped by the ``summary()`` means.
``summary()`` reports EVERY submitted id — in-flight requests appear with
``None`` latencies and are counted in ``in_flight`` instead of silently
vanishing.  ``to_json()`` emits the full report; ``write()`` drops it next
to the benchmark outputs.

Cache pressure: the engine samples ``PagedKVCache.utilization`` every step
(``block_utilization_mean/max``) and reports prefix-cache admission
matches (``prefix_hit_rate`` — matched tokens / looked-up context tokens,
0.0 when sharing is off).
"""
from __future__ import annotations

import json
import time
from typing import Optional


def _mean(xs):
    return sum(xs) / len(xs) if xs else 0.0


class ServingMetrics:
    def __init__(self):
        self.submit_t: dict[int, float] = {}
        self.first_token_t: dict[int, float] = {}
        self.finish_t: dict[int, float] = {}
        self.token_counts: dict[int, int] = {}
        # engine-lifetime aggregates: the per-id dicts above hold only the
        # LATEST lifecycle of a reused id, so completions/tokens/span must
        # accumulate separately or a resubmitted id silently deflates them
        self.finished_requests = 0
        self.finished_tokens = 0
        self._first_submit_t: Optional[float] = None
        self._last_finish_t: Optional[float] = None
        self.queue_depth_samples: list[int] = []
        self.occupancy_samples: list[float] = []
        self.block_utilization_samples: list[float] = []
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.preemptions = 0
        self.engine_steps = 0
        self.prefill_chunks = 0
        self.decode_steps = 0

    # -- request lifecycle --------------------------------------------------
    def on_submit(self, rid: int, now: Optional[float] = None):
        t = time.perf_counter() if now is None else now
        self.submit_t[rid] = t
        if self._first_submit_t is None or t < self._first_submit_t:
            self._first_submit_t = t
        # a reused id (finished request resubmitted, or a fresh request
        # recycling it) starts a NEW lifecycle: without this, the
        # first-write-wins on_first_token kept the PREVIOUS run's stamp and
        # fabricated a negative TTFT (first < submit).  Preemption-resume
        # never passes through here, so its TTFT preservation is unaffected;
        # the finished_* aggregates keep the old run's contribution.
        self.first_token_t.pop(rid, None)
        self.finish_t.pop(rid, None)
        self.token_counts.pop(rid, None)

    def on_first_token(self, rid: int, now: Optional[float] = None):
        # only the first time: a preempted+resumed request keeps its TTFT
        if rid not in self.first_token_t:
            self.first_token_t[rid] = time.perf_counter() if now is None else now

    def on_finish(self, rid: int, n_tokens: int, now: Optional[float] = None):
        t = time.perf_counter() if now is None else now
        self.finish_t[rid] = t
        self.token_counts[rid] = n_tokens
        self.finished_requests += 1
        self.finished_tokens += n_tokens
        if self._last_finish_t is None or t > self._last_finish_t:
            self._last_finish_t = t

    def on_preempt(self, rid: int):
        self.preemptions += 1

    def on_prefix_match(self, hit_tokens: int, lookup_tokens: int):
        """One admission-time prefix lookup: ``hit_tokens`` of the
        ``lookup_tokens``-token context were served from cached blocks."""
        self.prefix_hit_tokens += hit_tokens
        self.prefix_lookup_tokens += lookup_tokens

    # -- engine step --------------------------------------------------------
    def on_step(self, queue_depth: int, busy_slots: int, slots: int,
                block_utilization: Optional[float] = None):
        self.engine_steps += 1
        self.queue_depth_samples.append(queue_depth)
        self.occupancy_samples.append(busy_slots / max(slots, 1))
        if block_utilization is not None:
            self.block_utilization_samples.append(block_utilization)

    # -- report -------------------------------------------------------------
    def request_report(self, rid: int) -> dict:
        """Latency report for one request id.  Missing lifecycle points
        yield ``None`` (submitted-not-started has no TTFT; started-not-
        finished has no TPOT) — never a negative latency fabricated from a
        defaulted timestamp."""
        submit = self.submit_t.get(rid)
        first = self.first_token_t.get(rid)
        finish = self.finish_t.get(rid)
        n = self.token_counts.get(rid, 0)
        ttft = None if submit is None or first is None else first - submit
        if first is None or finish is None:
            tpot = None
        else:
            # time-per-output-token after the first
            tpot = (finish - first) / max(n - 1, 1)
        return {"id": rid, "n_tokens": n, "ttft_s": ttft, "tpot_s": tpot}

    def summary(self) -> dict:
        # every submitted id, finished or not — submitted-but-unfinished
        # requests used to vanish from the report entirely even though
        # request_report handles them (None latencies)
        all_ids = sorted(set(self.submit_t) | set(self.finish_t))
        reqs = [self.request_report(r) for r in all_ids]
        ttfts = [r["ttft_s"] for r in reqs if r["ttft_s"] is not None]
        tpots = [r["tpot_s"] for r in reqs if r["tpot_s"] is not None]
        # engine-lifetime totals (NOT sums over the per-id dicts, which only
        # hold a reused id's latest lifecycle)
        total_tokens = self.finished_tokens
        if self._first_submit_t is not None and self._last_finish_t is not None:
            span = self._last_finish_t - self._first_submit_t
        else:
            span = 0.0
        return {
            "requests": reqs,
            "completed": self.finished_requests,
            "in_flight": sum(1 for r in self.submit_t
                             if r not in self.finish_t),
            "total_tokens": total_tokens,
            "tokens_per_sec": total_tokens / span if span > 0 else 0.0,
            "ttft_mean_s": _mean(ttfts),
            "ttft_max_s": max(ttfts, default=0.0),
            "tpot_mean_s": _mean(tpots),
            "queue_depth_mean": _mean(self.queue_depth_samples),
            "queue_depth_max": max(self.queue_depth_samples, default=0),
            "slot_occupancy_mean": _mean(self.occupancy_samples),
            "block_utilization_mean": _mean(self.block_utilization_samples),
            "block_utilization_max": max(self.block_utilization_samples,
                                         default=0.0),
            "prefix_hit_rate": (self.prefix_hit_tokens
                                / self.prefix_lookup_tokens
                                if self.prefix_lookup_tokens else 0.0),
            "preemptions": self.preemptions,
            "engine_steps": self.engine_steps,
            "prefill_chunks": self.prefill_chunks,
            "decode_steps": self.decode_steps,
        }

    def to_json(self, **extra) -> str:
        return json.dumps({**self.summary(), **extra}, indent=2)

    def write(self, path: str, **extra) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(**extra) + "\n")
