"""Serving metrics: per-request TTFT/TPOT plus engine-level telemetry.

``ServingMetrics`` is the backward-compatible facade over the telemetry
primitives in serving/telemetry.py — every summary key that existed
before the telemetry layer keeps its name and meaning, and the means are
bit-identical (running totals accumulate in record order, exactly like
``sum(samples)/len(samples)`` over the old unbounded lists).  What
changed underneath:

  * per-step samples (queue depth, slot occupancy, block utilization,
    phase durations, step time) live in fixed-memory ``LogHistogram``s —
    the old ``*_samples`` lists grew one entry per engine step forever;
  * a ``Telemetry`` registry exposes every counter/gauge/histogram to the
    exporters (serving/export.py: Prometheus text + JSONL snapshots);
  * sliding windows turn lifetime aggregates into the *recent-workload*
    signal vector the adaptive scheduler (ROADMAP item 3) needs:
    ``window_signals()`` reports arrival rate, prompt-length mix, prefix
    hit rate, cache pressure, queue depth and decode throughput over the
    trailing ``window_s`` seconds, plus the StepMonitor drift gauge;
  * ``summary()`` distinguishes "no data" from zero: a run with no
    finished requests reports ``None`` latencies/throughput instead of a
    0.0 that reads as infinitely fast (serve_bench skips such rows).

All timestamps are caller-supplied floats from ONE clock: the engine
stamps every lifecycle point (submit / first token / finish) and every
step with its injectable ``clock``, so a test driving the engine with a
synthetic clock gets coherent TTFT/TPOT *and* window expiry end to end.
A request that has not reached a lifecycle point yet reports ``None`` for
the latencies that depend on it and is skipped by the ``summary()``
aggregates.  ``summary()`` reports EVERY submitted id — in-flight
requests appear with ``None`` latencies and are counted in ``in_flight``.

``to_json()`` emits the full report; ``write()`` drops it next to the
benchmark outputs via an atomic temp-file + rename (a crash mid-write
never leaves truncated JSON).

Cache pressure: the engine samples ``PagedKVCache.utilization`` every
step (``block_utilization_mean/max``) and reports prefix-cache admission
matches (``prefix_hit_rate`` — matched tokens / looked-up context tokens,
0.0 when sharing is off).
"""
from __future__ import annotations

import json
import time
from typing import Optional

from repro.serving.export import atomic_write_text
from repro.serving.telemetry import Telemetry, quantile

# engine phases with their own duration histogram + trace track
PHASES = ("admission", "prefix_match", "prefill", "decode", "sample_sync")


def _mean(xs):
    return sum(xs) / len(xs) if xs else None


class ServingMetrics:
    def __init__(self, *, window_s: float = 10.0):
        self.submit_t: dict[int, float] = {}
        self.first_token_t: dict[int, float] = {}
        self.finish_t: dict[int, float] = {}
        self.token_counts: dict[int, int] = {}
        # engine-lifetime aggregates: the per-id dicts above hold only the
        # LATEST lifecycle of a reused id, so completions/tokens/span must
        # accumulate separately or a resubmitted id silently deflates them
        self.finished_requests = 0
        self.finished_tokens = 0
        self._first_submit_t: Optional[float] = None
        self._last_finish_t: Optional[float] = None
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.preemptions = 0
        self.engine_steps = 0
        self.prefill_chunks = 0
        self.decode_steps = 0
        self.finish_reasons: dict[str, int] = {}
        # live references injected by the engine (dicts/callables stay
        # current without a push per step); None when used standalone
        self.scheduler_stats: Optional[dict] = None
        self.cache_stats = None              # () -> dict, engine-injected
        # telemetry registry: per-step streams in fixed-memory histograms,
        # recent-workload signals in sliding windows
        t = self.telemetry = Telemetry(window_s=window_s)
        self.queue_depth = t.histogram("queue_depth", lo=1.0, hi=1e6,
                                       growth=1.3)
        self.slot_occupancy = t.histogram("slot_occupancy", lo=1e-3, hi=2.0)
        self.block_utilization = t.histogram("block_utilization", lo=1e-3,
                                             hi=2.0)
        self.step_time = t.histogram("step_time_s")
        self.phase = {p: t.histogram(f"phase_{p}_s") for p in PHASES}
        self._win_arrivals = t.window("arrivals")          # value=prompt_len
        self._win_finished = t.window("finished_tokens")   # value=n_tokens
        self._win_queue = t.window("queue_depth")
        self._win_occupancy = t.window("slot_occupancy")
        self._win_util = t.window("block_utilization")
        self._win_hit = t.window("prefix_hit_tokens")
        self._win_lookup = t.window("prefix_lookup_tokens")
        self._g_step_ema = t.gauge("step_time_ema_s")
        self._g_step_drift = t.gauge("step_time_drift")
        self._c_replan = t.counter("replan_triggers")
        # newest engine-clock stamp seen: the default "now" for window
        # queries, so summary() is deterministic under synthetic clocks
        self._last_t: Optional[float] = None

    def _stamp(self, now: Optional[float]) -> float:
        # sanctioned fallback for standalone (engine-less) use only: every
        # engine call site passes its injected clock's ``now`` explicitly
        t = time.perf_counter() if now is None else now  # reprolint: disable=clock-injection
        if self._last_t is None or t > self._last_t:
            self._last_t = t
        return t

    # -- request lifecycle --------------------------------------------------
    def on_submit(self, rid: int, now: Optional[float] = None,
                  prompt_len: Optional[int] = None):
        t = self._stamp(now)
        self.submit_t[rid] = t
        if self._first_submit_t is None or t < self._first_submit_t:
            self._first_submit_t = t
        # a reused id (finished request resubmitted, or a fresh request
        # recycling it) starts a NEW lifecycle: without this, the
        # first-write-wins on_first_token kept the PREVIOUS run's stamp and
        # fabricated a negative TTFT (first < submit).  Preemption-resume
        # never passes through here, so its TTFT preservation is unaffected;
        # the finished_* aggregates keep the old run's contribution.
        self.first_token_t.pop(rid, None)
        self.finish_t.pop(rid, None)
        self.token_counts.pop(rid, None)
        self._win_arrivals.record(t, 0.0 if prompt_len is None
                                  else float(prompt_len))

    def on_first_token(self, rid: int, now: Optional[float] = None):
        # only the first time: a preempted+resumed request keeps its TTFT
        if rid not in self.first_token_t:
            self.first_token_t[rid] = self._stamp(now)

    def on_finish(self, rid: int, n_tokens: int,
                  now: Optional[float] = None,
                  reason: Optional[str] = None):
        t = self._stamp(now)
        self.finish_t[rid] = t
        self.token_counts[rid] = n_tokens
        self.finished_requests += 1
        self.finished_tokens += n_tokens
        if reason is not None:
            self.finish_reasons[reason] = \
                self.finish_reasons.get(reason, 0) + 1
        if self._last_finish_t is None or t > self._last_finish_t:
            self._last_finish_t = t
        self._win_finished.record(t, float(n_tokens))

    def on_preempt(self, rid: int):
        self.preemptions += 1

    def on_prefix_match(self, hit_tokens: int, lookup_tokens: int,
                        now: Optional[float] = None):
        """One admission-time prefix lookup: ``hit_tokens`` of the
        ``lookup_tokens``-token context were served from cached blocks."""
        self.prefix_hit_tokens += hit_tokens
        self.prefix_lookup_tokens += lookup_tokens
        t = self._stamp(now)
        self._win_hit.record(t, float(hit_tokens))
        self._win_lookup.record(t, float(lookup_tokens))

    # -- engine step --------------------------------------------------------
    def on_step(self, queue_depth: int, busy_slots: int, slots: int,
                block_utilization: Optional[float] = None,
                now: Optional[float] = None):
        t = self._stamp(now)
        self.engine_steps += 1
        self.queue_depth.record(queue_depth)
        occ = busy_slots / max(slots, 1)
        self.slot_occupancy.record(occ)
        self._win_queue.record(t, float(queue_depth))
        self._win_occupancy.record(t, occ)
        if block_utilization is not None:
            self.block_utilization.record(block_utilization)
            self._win_util.record(t, block_utilization)

    def on_phase(self, name: str, dur_s: float):
        """One engine phase execution (only phases that did work — the
        per-phase breakdown measures time spent *doing*, so zero-work
        dispatch overhead never dilutes the distributions)."""
        self.phase[name].record(dur_s)

    def on_step_time(self, dur_s: float, ema: Optional[float] = None,
                     drift: Optional[float] = None,
                     triggered: bool = False):
        """Wall time of one full engine step plus the StepMonitor's view:
        EMA, current drift fraction vs baseline, and whether this step
        tripped the re-profile trigger the adaptive scheduler subscribes
        to (core/profiler.StepMonitor)."""
        self.step_time.record(dur_s)
        self._g_step_ema.set(ema)
        self._g_step_drift.set(drift)
        if triggered:
            self._c_replan.inc()

    # -- report -------------------------------------------------------------
    def request_report(self, rid: int) -> dict:
        """Latency report for one request id.  Missing lifecycle points
        yield ``None`` (submitted-not-started has no TTFT; started-not-
        finished has no TPOT) — never a negative latency fabricated from a
        defaulted timestamp."""
        submit = self.submit_t.get(rid)
        first = self.first_token_t.get(rid)
        finish = self.finish_t.get(rid)
        n = self.token_counts.get(rid, 0)
        ttft = None if submit is None or first is None else first - submit
        if first is None or finish is None:
            tpot = None
        else:
            # time-per-output-token after the first
            tpot = (finish - first) / max(n - 1, 1)
        return {"id": rid, "n_tokens": n, "ttft_s": ttft, "tpot_s": tpot}

    def window_signals(self, now: Optional[float] = None) -> dict:
        """The adaptive scheduler's input vector, over the trailing
        ``window_s`` seconds of engine time: arrival rate, prompt-length
        mix, prefix hit rate, cache/queue pressure, decode throughput and
        the step-time drift gauge.  ``now`` defaults to the newest stamp
        seen, so the vector is deterministic under synthetic clocks."""
        t = self._last_t if now is None else now
        if t is None:                  # nothing recorded yet
            t = 0.0
        w = self._win_arrivals
        plens = w.values(t)
        lookup = self._win_lookup.total(t)
        return {
            "window_s": self.telemetry.window_s,
            "t": t,
            "arrival_rate_hz": w.rate(t),
            "prompt_len_mean": _mean(plens),
            "prompt_len_p50": quantile(plens, 0.5),
            "prompt_len_p95": quantile(plens, 0.95),
            "prompt_len_max": max(plens, default=None),
            "prefix_hit_rate": (self._win_hit.total(t) / lookup
                                if lookup else None),
            "block_pressure_mean": self._win_util.mean(t),
            "block_pressure_max": self._win_util.vmax(t),
            "queue_depth_mean": self._win_queue.mean(t),
            "slot_occupancy_mean": self._win_occupancy.mean(t),
            "tokens_per_sec": self._win_finished.total(t)
            / self.telemetry.window_s,
            "finished_per_sec": self._win_finished.rate(t),
            "step_time_ema_s": self._g_step_ema.value,
            "step_time_drift": self._g_step_drift.value,
            "replan_triggers": self._c_replan.value,
        }

    def summary(self) -> dict:
        # every submitted id, finished or not — submitted-but-unfinished
        # requests used to vanish from the report entirely even though
        # request_report handles them (None latencies)
        all_ids = sorted(set(self.submit_t) | set(self.finish_t))
        reqs = [self.request_report(r) for r in all_ids]
        ttfts = [r["ttft_s"] for r in reqs if r["ttft_s"] is not None]
        tpots = [r["tpot_s"] for r in reqs if r["tpot_s"] is not None]
        # engine-lifetime totals (NOT sums over the per-id dicts, which only
        # hold a reused id's latest lifecycle)
        total_tokens = self.finished_tokens
        if self._first_submit_t is not None and self._last_finish_t is not None:
            span = self._last_finish_t - self._first_submit_t
        else:
            span = 0.0
        out = {
            "requests": reqs,
            "completed": self.finished_requests,
            "in_flight": sum(1 for r in self.submit_t
                             if r not in self.finish_t),
            "total_tokens": total_tokens,
            # None (not 0.0) when nothing finished: a rate of zero reads as
            # "measured and terrible", absence reads as "no data" — and an
            # empty run's 0.0 TTFT used to read as perfect latency
            "tokens_per_sec": total_tokens / span if span > 0 else None,
            "ttft_mean_s": _mean(ttfts),
            "ttft_p50_s": quantile(ttfts, 0.5),
            "ttft_p95_s": quantile(ttfts, 0.95),
            "ttft_p99_s": quantile(ttfts, 0.99),
            "ttft_max_s": max(ttfts, default=None),
            "tpot_mean_s": _mean(tpots),
            "tpot_p50_s": quantile(tpots, 0.5),
            "tpot_p95_s": quantile(tpots, 0.95),
            "tpot_p99_s": quantile(tpots, 0.99),
            "queue_depth_mean": self.queue_depth.mean,
            "queue_depth_max": self.queue_depth.vmax,
            "slot_occupancy_mean": self.slot_occupancy.mean,
            "block_utilization_mean": self.block_utilization.mean,
            "block_utilization_max": self.block_utilization.vmax,
            "prefix_hit_rate": (self.prefix_hit_tokens
                                / self.prefix_lookup_tokens
                                if self.prefix_lookup_tokens else 0.0),
            "preemptions": self.preemptions,
            "engine_steps": self.engine_steps,
            "prefill_chunks": self.prefill_chunks,
            "decode_steps": self.decode_steps,
            "finish_reasons": dict(self.finish_reasons),
            "phases": {p: h.summary() for p, h in self.phase.items()
                       if h.count},
            "step_time": self.step_time.summary(),
            "window": self.window_signals(),
        }
        if self.scheduler_stats is not None:
            out["scheduler"] = dict(self.scheduler_stats)
        if self.cache_stats is not None:
            out["cache"] = self.cache_stats()
        return out

    def snapshot(self, now: Optional[float] = None) -> dict:
        """Compact periodic snapshot (one JSONL line): the windowed signal
        vector plus lifetime counters — no per-request list."""
        snap = {
            "completed": self.finished_requests,
            "in_flight": sum(1 for r in self.submit_t
                             if r not in self.finish_t),
            "total_tokens": self.finished_tokens,
            "preemptions": self.preemptions,
            "engine_steps": self.engine_steps,
            "window": self.window_signals(now),
        }
        if self.scheduler_stats is not None:
            snap["scheduler"] = dict(self.scheduler_stats)
        return snap

    def to_json(self, **extra) -> str:
        return json.dumps({**self.summary(), **extra}, indent=2)

    def write(self, path: str, **extra) -> None:
        """Atomic write (temp file + rename): a crash mid-write leaves the
        previous report intact, never truncated JSON next to bench
        results."""
        atomic_write_text(path, self.to_json(**extra) + "\n")

    # -- benchmark support --------------------------------------------------
    def adopt_step_stats(self, other: "ServingMetrics") -> None:
        """Take over another collector's engine-level step statistics
        (histograms, windows, counters, phase/step timing) while keeping
        this collector's request lifecycle dicts.  serve_bench uses this
        to rebuild TTFT/TPOT from trace *arrival* times without losing the
        real run's measured engine counters."""
        self.telemetry = other.telemetry
        self.queue_depth = other.queue_depth
        self.slot_occupancy = other.slot_occupancy
        self.block_utilization = other.block_utilization
        self.step_time = other.step_time
        self.phase = other.phase
        self._win_queue = other._win_queue
        self._win_occupancy = other._win_occupancy
        self._win_util = other._win_util
        self._win_hit = other._win_hit
        self._win_lookup = other._win_lookup
        self._g_step_ema = other._g_step_ema
        self._g_step_drift = other._g_step_drift
        self._c_replan = other._c_replan
        self.preemptions = other.preemptions
        self.engine_steps = other.engine_steps
        self.prefill_chunks = other.prefill_chunks
        self.decode_steps = other.decode_steps
        self.finish_reasons = dict(other.finish_reasons)
        self.prefix_hit_tokens = other.prefix_hit_tokens
        self.prefix_lookup_tokens = other.prefix_lookup_tokens
        self.scheduler_stats = other.scheduler_stats
        self.cache_stats = other.cache_stats
