"""Span-based tracer emitting Chrome trace-event JSON (Perfetto-loadable).

The engine owns at most ONE tracer (``ContinuousBatchingEngine(tracer=
ChromeTracer())``); when it owns none, tracing costs nothing — every
emission site is behind an ``if tracer is not None`` and no clock reads,
dict builds or list appends happen.  A serve run with ``--trace-out``
(launch/serve.py) drops the JSON next to the metrics; open it at
https://ui.perfetto.dev (or chrome://tracing) to see:

  * one named track (``tid``) per engine phase — admission, prefix-match,
    prefill chunk, decode step, sample host-sync — carrying balanced
    B/E duration spans stamped from the engine's own clock values (the
    same floats the phase histograms record, so trace and metrics never
    disagree);
  * an async ``request`` track per request id: a ``b``/``e`` lifecycle
    span from submit to finish (finish_reason in the end event's args)
    with ``n`` instant annotations for admitted / first_token / preempt /
    resume — preemption shows up as the request going back to the queue
    mid-span, exactly how the scheduler experienced it;
  * counter tracks (``ph: "C"``) for queue depth and block-pool
    utilization sampled once per engine step.

Timestamps are microseconds relative to the first event (Chrome's ``ts``
convention); events are sorted by ``ts`` on export so the emitted JSON is
monotonic regardless of emission order within a step.

``validate_chrome_trace`` is the schema gate used by tests and the CI
smoke job: required keys per event, monotonic ``ts``, balanced B/E per
phase track, balanced b/e per request id.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.serving.export import atomic_write_text

# fixed track layout: one tid per engine phase, one shared tid for the
# async request-lifecycle spans (async events nest by id, not tid)
PHASE_TRACKS = {"admission": 1, "prefix_match": 2, "prefill": 3,
                "decode": 4, "sample_sync": 5}
REQUEST_TRACK = 10
COUNTER_TRACK = 0


class ChromeTracer:
    """Collects Chrome trace events; write() drops them atomically.

    All timestamps are caller-supplied floats from one clock (the
    engine's) — the tracer never reads a clock itself, so a synthetic
    test clock produces a fully deterministic trace.
    """

    def __init__(self, *, pid: int = 0, process_name: str = "serving-engine"):
        self.pid = pid
        self.process_name = process_name
        self.events: list[dict] = []
        self._origin: Optional[float] = None

    def _ts(self, t: float) -> float:
        if self._origin is None:
            self._origin = t
        return (t - self._origin) * 1e6        # seconds -> microseconds

    # -- phase spans --------------------------------------------------------
    def phase(self, name: str, t0: float, t1: float, **args) -> None:
        """One balanced B/E duration span on the phase's own track."""
        tid = PHASE_TRACKS[name]
        b = {"name": name, "ph": "B", "ts": self._ts(t0),
             "pid": self.pid, "tid": tid}
        if args:
            b["args"] = args
        self.events.append(b)
        self.events.append({"name": name, "ph": "E", "ts": self._ts(t1),
                            "pid": self.pid, "tid": tid})

    # -- counters -----------------------------------------------------------
    def counter(self, name: str, t: float, value: float) -> None:
        self.events.append({"name": name, "ph": "C", "ts": self._ts(t),
                            "pid": self.pid, "tid": COUNTER_TRACK,
                            "args": {name: value}})

    # -- per-request lifecycle spans (async, keyed by request id) -----------
    def _req_event(self, ph: str, rid: int, name: str, t: float,
                   args: Optional[dict]) -> None:
        ev = {"name": name, "cat": "request", "ph": ph, "id": rid,
              "ts": self._ts(t), "pid": self.pid, "tid": REQUEST_TRACK}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def request_begin(self, rid: int, t: float, **args) -> None:
        self._req_event("b", rid, f"request {rid}", t, args or None)

    def request_instant(self, rid: int, name: str, t: float, **args) -> None:
        self._req_event("n", rid, name, t, args or None)

    def request_end(self, rid: int, t: float, **args) -> None:
        self._req_event("e", rid, f"request {rid}", t, args or None)

    # -- export -------------------------------------------------------------
    def _metadata(self) -> list[dict]:
        meta = [{"name": "process_name", "ph": "M", "ts": 0.0,
                 "pid": self.pid, "tid": 0,
                 "args": {"name": self.process_name}}]
        tracks = dict(PHASE_TRACKS)
        tracks["requests"] = REQUEST_TRACK
        for name, tid in tracks.items():
            meta.append({"name": "thread_name", "ph": "M", "ts": 0.0,
                         "pid": self.pid, "tid": tid,
                         "args": {"name": name}})
        return meta

    def to_dict(self) -> dict:
        """Chrome trace JSON object.  Events are stably sorted by ts, so
        a B emitted before its same-ts E stays ordered."""
        return {"traceEvents": self._metadata()
                + sorted(self.events, key=lambda e: e["ts"]),
                "displayTimeUnit": "ms"}

    def write(self, path: str) -> dict:
        """Atomically write the trace JSON; returns the written object."""
        obj = self.to_dict()
        atomic_write_text(path, json.dumps(obj) + "\n")
        return obj


def validate_chrome_trace(trace: dict) -> dict:
    """Schema gate for an emitted trace (tests + CI smoke job).

    Checks: top-level shape, required keys per event, known phase types,
    monotonic ``ts`` over the event list, balanced B/E per (pid, tid)
    with matching names, balanced b/e per async (cat, id).  Returns
    summary stats; raises ValueError on any violation.
    """
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be an object with 'traceEvents'")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    known_ph = {"B", "E", "X", "C", "M", "b", "e", "n", "i"}
    last_ts = None
    open_spans: dict[tuple, list[str]] = {}    # (pid, tid) -> [names]
    open_async: dict[tuple, int] = {}          # (cat, id) -> depth
    n_spans = n_async = 0
    for i, ev in enumerate(events):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing required key "
                                 f"{key!r}: {ev!r}")
        ph = ev["ph"]
        if ph not in known_ph:
            raise ValueError(f"event {i} has unknown ph {ph!r}")
        if ph == "M":
            continue                           # metadata carries no timing
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} has bad ts {ts!r}")
        if last_ts is not None and ts < last_ts:
            raise ValueError(f"event {i} ts {ts} < previous {last_ts} — "
                             f"trace is not time-sorted")
        last_ts = ts
        if ph == "B":
            open_spans.setdefault((ev["pid"], ev["tid"]), []) \
                .append(ev["name"])
            n_spans += 1
        elif ph == "E":
            stack = open_spans.get((ev["pid"], ev["tid"]))
            if not stack:
                raise ValueError(f"event {i}: E with no open B on tid "
                                 f"{ev['tid']}")
            top = stack.pop()
            if top != ev["name"]:
                raise ValueError(f"event {i}: E {ev['name']!r} closes "
                                 f"B {top!r}")
        elif ph in ("b", "e", "n"):
            if "cat" not in ev or "id" not in ev:
                raise ValueError(f"event {i}: async {ph!r} needs cat + id")
            key = (ev["cat"], ev["id"])
            if ph == "b":
                open_async[key] = open_async.get(key, 0) + 1
                n_async += 1
            elif ph == "e":
                if open_async.get(key, 0) < 1:
                    raise ValueError(f"event {i}: async end with no open "
                                     f"begin for {key}")
                open_async[key] -= 1
            elif open_async.get(key, 0) < 1:
                raise ValueError(f"event {i}: async instant outside any "
                                 f"open span for {key}")
    dangling = [k for k, v in open_spans.items() if v]
    if dangling:
        raise ValueError(f"unbalanced B/E spans left open on {dangling}")
    dangling = [k for k, v in open_async.items() if v]
    if dangling:
        raise ValueError(f"unclosed async request spans: {dangling}")
    return {"n_events": len(events), "n_phase_spans": n_spans,
            "n_request_spans": n_async}
