"""The prefix hash chain shared by the paged cache and the cluster router.

One scheme, two consumers:

  * ``PagedKVCache`` (serving/paged_cache.py) keys its content index with
    these chain keys — a *full* block's key commits to the entire token
    prefix up to and including that block, so equal keys imply
    bitwise-equal KV;
  * the cluster router's prefix-affinity index (serving/cluster/affinity.py)
    maps the same keys to *replicas*, so a prompt is routed to the worker
    whose paged cache already holds the blocks those keys name.

Keeping both sides on literally the same function is what makes affinity
routing meaningful: the router's longest-prefix key for a prompt is, by
construction, the key the chosen worker's cache will look up at admission.

This module is stdlib-only (no jax of its own): the router and frontend
processes — which never touch a device — use it for pure host-side key
arithmetic.

A chain key is the nested tuple ``(prev_key, chunk)`` where ``chunk`` is
one ``block_size``-token tuple and ``prev_key`` is the previous block's
key (``None`` at the chain head).  The nesting is an incremental-hashing
optimization: extending a chain by one block hashes only the new chunk,
never the whole prefix.
"""
from __future__ import annotations

from typing import Optional

ChainKey = tuple  # (prev: Optional[ChainKey], chunk: tuple[int, ...])


def chain_keys(tokens, block_size: int, start: int = 0,
               n_blocks: Optional[int] = None,
               prev: Optional[ChainKey] = None) -> list[ChainKey]:
    """Chain keys for the full blocks ``[start, n_blocks)`` of ``tokens``,
    extending ``prev`` (the key of block ``start - 1``; ``None`` at the
    chain head).  ``n_blocks`` defaults to every full block of ``tokens``.
    """
    if n_blocks is None:
        n_blocks = len(tokens) // block_size
    keys = []
    for i in range(start, n_blocks):
        chunk = tuple(int(t) for t in tokens[i * block_size:
                                             (i + 1) * block_size])
        prev = (prev, chunk)
        keys.append(prev)
    return keys
