"""Paged KV cache: fixed-size blocks, refcounted free-list allocation,
block tables, and cross-request shared-prefix block reuse.

The device side is a *physical block pool* per attention layer
(models/transformer.init_paged_cache — shape (repeat, num_blocks,
block_size, Hkv, head_dim), no batch axis).  This module is the host side:
which physical blocks belong to which request, how many are free, and —
with ``share_prefix`` enabled — which blocks hold which *content*.

Block 0 is the reserved **null block**: it is never allocated, idle batch
slots point every block-table entry at it, and the padded tail of short
tables also maps there, so stray writes land in a scratch page that no
live request ever reads (layers.paged_attention masks it out).

Prefix sharing (à la vLLM's prefix caching): every *full* block a request
has written can be registered in a content index keyed by a hash chain
over its ``block_size``-token chunks (a block's key commits to the entire
token prefix up to and including it, so equal keys imply bitwise-equal KV
for position-independent attention caches).  A later request whose context
starts with the same chain is handed the same physical blocks at admission
— reference counts go up, its prefill starts at the matched boundary, and
no KV is recomputed.  When the last request drops a registered block, it
is not freed: it retires into an LRU pool of unreferenced-but-cached
blocks, reusable on a future hash hit and evicted (oldest first) only when
``reserve`` would otherwise report OOM.  Slot-state rows (mamba2 / cross-
attn / wdec encoder K/V) are per-request and never shared — see
serving/cache_manager.py, which rejects ``share_prefix`` for those archs.

Layout respects the ASA plan: ContinuousBatchingEngine device_puts the
pools with NamedShardings built from SchedulePlan.paged_cache_specs()
(kv-head axis over `model` — see core/sharding.py).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.serving.prefix_hash import chain_keys

NULL_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Physical blocks needed to hold n_tokens."""
    return -(-n_tokens // block_size)


class BlockAllocator:
    """Refcounted free-list allocator over physical block ids 1..num_blocks-1.

    Allocation is all-or-nothing (returns None instead of a partial grant)
    so a request under cache pressure either fits or triggers preemption —
    it never strands half-allocated pages.  Every allocated block carries a
    reference count (fresh allocations start at 1); ``incref`` lets the
    prefix index and later requests share a block, and a block returns to
    the free list only when its count reaches 0.  Double-free and
    foreign-block frees raise: the invariants the serving tests pin down.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need at least the null block + one real block")
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> low ids first
        self._ref: dict[int, int] = {}                    # block -> refcount
        # opt-in sanitizer hook (analysis/sanitizer.CacheSanitizer): records
        # allocation sites and raises rich reports on invalid transitions.
        # None in production — every notification sits behind one attribute
        # check, so the hot path pays nothing when disabled
        self.observer = None

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._ref)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._ref[b] = 1
        if self.observer is not None:
            self.observer.on_alloc(blocks)
        return blocks

    def incref(self, block: int) -> int:
        if block == NULL_BLOCK or block not in self._ref:
            if self.observer is not None:
                self.observer.on_invalid_incref(block)  # raises with sites
            if block == NULL_BLOCK:
                raise ValueError("cannot reference the null block")
            raise ValueError(f"incref on unallocated block {block}")
        self._ref[block] += 1
        if self.observer is not None:
            self.observer.on_incref(block, self._ref[block])
        return self._ref[block]

    def decref(self, block: int) -> int:
        """Drop one reference; at 0 the block returns to the free list.
        Returns the remaining count."""
        if block == NULL_BLOCK or block not in self._ref:
            if self.observer is not None:
                self.observer.on_invalid_free(block)    # raises with sites
            if block == NULL_BLOCK:
                raise ValueError("cannot free the null block")
            raise ValueError(f"double free / foreign block {block}")
        self._ref[block] -= 1
        remaining = self._ref[block]
        if remaining == 0:
            del self._ref[block]
            self._free.append(block)
        if self.observer is not None:
            self.observer.on_decref(block, remaining)
        return remaining

    def free(self, blocks: list[int]) -> None:
        """Drop one reference per block (legacy bulk API).  A block shared
        with other holders merely decrements; only the last holder's free
        returns it to the free list."""
        for b in blocks:
            self.decref(b)


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    block_size: int
    num_blocks: int            # physical, including the reserved null block
    max_blocks_per_seq: int    # block-table width (= ceil(max_len / bs))
    slots: int = 0             # slot-state pool rows (0: attn-only arch)
    share_prefix: bool = False  # cross-request full-block prefix reuse


class PagedKVCache:
    """Device block pools + allocator + per-request block tables.

    With ``cfg.slots`` > 0 the device pytree also carries slot-indexed state
    pools for O(1)-per-request caches; serving/cache_manager.py layers the
    slot-row bookkeeping on top of this class.

    With ``cfg.share_prefix`` the host side additionally keeps the content
    index (hash chain -> physical block), per-block reference counts beyond
    1, and the LRU pool of unreferenced-but-cached blocks described in the
    module docstring.  The device pools are untouched: sharing is pure
    block-table indirection, invisible to the jitted steps."""

    def __init__(self, arch: ArchConfig, cfg: PagedCacheConfig, *,
                 dtype=jnp.bfloat16, mesh=None, specs=None):
        self.arch, self.cfg = arch, cfg
        pools = T.init_paged_cache(arch, cfg.num_blocks, cfg.block_size,
                                   dtype, slots=cfg.slots)
        if mesh is not None and specs is not None:
            ns = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
            pools = jax.device_put(pools, ns)
        self.pools = pools
        self._init_host_state()

    @classmethod
    def host_only(cls, cfg: PagedCacheConfig) -> "PagedKVCache":
        """Construct the host-side bookkeeping alone: allocator, block
        tables, prefix index, LRU — no device pools, no arch, no jax.
        This is the exact object the engine's control plane mutates, which
        is what analysis/schedcheck.py model-checks: the allocator /
        table / index / LRU transition logic is the *real* code, only the
        device pools (pure data, irrelevant to control flow) are absent.
        Accessing ``pools`` / ``pool_bytes`` / ``arch`` on a host-only
        cache raises."""
        self = cls.__new__(cls)
        self.arch, self.cfg = None, cfg
        self.pools = None
        self._init_host_state()
        return self

    def _init_host_state(self) -> None:
        cfg = self.cfg
        self.allocator = BlockAllocator(cfg.num_blocks)
        self.tables: dict[int, list[int]] = {}   # request id -> physical blocks
        # -- prefix-sharing state (inert unless cfg.share_prefix) -----------
        # chain key -> block holding that full chunk; key = (prev_key, chunk)
        # so it commits to the whole token prefix, not just one block's tokens
        self._hash_to_block: dict[tuple, int] = {}
        self._block_to_hash: dict[int, tuple] = {}
        # unreferenced-but-cached blocks, oldest first; each holds exactly
        # one reference (the index's) until eviction or a new hash hit
        self._lru: OrderedDict[int, None] = OrderedDict()
        # rid -> (full blocks committed, chain key of the last one) so each
        # commit extends the chain instead of rehashing it from block 0
        self._committed: dict[int, tuple[int, Optional[tuple]]] = {}
        # counters surfaced through ServingMetrics / serve_bench
        self.prefix_hit_tokens = 0
        self.prefix_lookup_tokens = 0
        self.prefix_evictions = 0

    # -- prefix index (keys from serving/prefix_hash.py — the cluster
    #    router's affinity index uses the same scheme, which is what lets
    #    it predict which replica holds a prompt's blocks) ------------------
    def match_prefix(self, tokens) -> list[int]:
        """Longest chain of cached full blocks covering a prefix of
        ``tokens`` — capped at len(tokens)-1 so at least one token is left
        to prefill (the engine must run the model once to sample the first
        output token).  No side effects."""
        if not self.cfg.share_prefix:
            return []
        bs = self.cfg.block_size
        limit = max(len(tokens) - 1, 0) // bs
        blocks = []
        for key in chain_keys(tokens, bs, 0, limit):
            b = self._hash_to_block.get(key)
            if b is None:
                break
            blocks.append(b)
        return blocks

    def assign_prefix(self, rid: int, tokens) -> int:
        """Hand request ``rid`` the cached blocks matching its context
        prefix: refcounts bump, matched blocks leave the LRU, and the
        request's table starts populated.  Returns the number of matched
        tokens (the engine starts prefill there).  Must run before the
        first ``reserve`` for rid."""
        if not self.cfg.share_prefix:
            return 0
        if rid in self.tables:
            raise ValueError(f"request {rid} already holds blocks — "
                             f"assign_prefix must precede reserve")
        blocks = self.match_prefix(tokens)
        self.prefix_lookup_tokens += len(tokens)
        if not blocks:
            return 0
        for b in blocks:
            self.allocator.incref(b)
            self._lru.pop(b, None)
        self.tables[rid] = list(blocks)
        self._committed[rid] = (len(blocks), self._block_to_hash[blocks[-1]])
        n = len(blocks) * self.cfg.block_size
        self.prefix_hit_tokens += n
        return n

    def commit_prefix(self, rid: int, tokens, n_resident: int) -> None:
        """Register rid's freshly written full blocks in the content index
        (first writer wins on duplicate content).  ``tokens`` is the
        request context, of which ``n_resident`` are resident in the cache.
        The index holds one reference per registered block, so a released
        block retires into the LRU instead of being freed."""
        if not self.cfg.share_prefix:
            return
        table = self.tables.get(rid)
        if table is None:
            return
        n_full = min(n_resident // self.cfg.block_size, len(table))
        start, prev = self._committed.get(rid, (0, None))
        if n_full <= start:
            return
        keys = chain_keys(tokens, self.cfg.block_size, start, n_full, prev)
        for i, key in zip(range(start, n_full), keys):
            b = table[i]
            if b in self._block_to_hash or key in self._hash_to_block:
                continue                       # already indexed / duplicate
            self._hash_to_block[key] = b
            self._block_to_hash[b] = key
            self.allocator.incref(b)
        self._committed[rid] = (n_full, keys[-1])

    def _evict_for(self, need: int) -> None:
        """Evict unreferenced cached blocks (oldest first) until ``need``
        blocks are free or the LRU is empty.  Referenced blocks are never
        in the LRU, so live requests are untouched."""
        while self.allocator.num_free < need and self._lru:
            b, _ = self._lru.popitem(last=False)
            key = self._block_to_hash.pop(b)
            del self._hash_to_block[key]
            self.allocator.decref(b)           # index's ref: 1 -> 0 -> free
            self.prefix_evictions += 1

    @property
    def num_cached(self) -> int:
        """Unreferenced-but-cached blocks reclaimable by eviction."""
        return len(self._lru)

    def prefix_stats(self) -> dict:
        hit = self.prefix_hit_tokens
        lookup = self.prefix_lookup_tokens
        return {"hit_tokens": hit, "lookup_tokens": lookup,
                "hit_rate": hit / lookup if lookup else 0.0,
                "cached_blocks": self.num_cached,
                "indexed_blocks": len(self._block_to_hash),
                "evictions": self.prefix_evictions}

    # -- allocation ---------------------------------------------------------
    def reserve(self, rid: int, n_tokens: int) -> bool:
        """Grow request rid's table to cover n_tokens total; False on OOM
        (state unchanged — caller preempts or defers admission).  Cached
        LRU blocks are evicted before OOM is reported."""
        have = len(self.tables.get(rid, ()))
        need = blocks_for(n_tokens, self.cfg.block_size) - have
        if need <= 0:
            return True
        if need > self.allocator.num_free:
            self._evict_for(need)
        got = self.allocator.alloc(need)
        if got is None:
            return False
        self.tables.setdefault(rid, []).extend(got)
        return True

    def release(self, rid: int) -> None:
        """Drop rid's reference on every block in its table.  A block whose
        only remaining holder is the content index retires into the LRU
        (reusable on a future prefix hit); an unindexed block at refcount 0
        is freed outright.  Retirement is tail-first: eviction pops the LRU
        oldest-first, and evicting a chain's *head* would break match_prefix
        at block 0 while its still-cached tail sat unmatchable — sacrificing
        the tail first keeps the matchable head resident longest."""
        blocks = self.tables.pop(rid, None)
        self._committed.pop(rid, None)
        if not blocks:
            return
        for b in reversed(blocks):
            remaining = self.allocator.decref(b)
            if remaining == 1 and b in self._block_to_hash:
                # the survivor is the index's ref (an LRU block is always at
                # refcount 1, so b cannot already be resident) — insert at
                # the MRU end
                self._lru[b] = None

    def can_fit(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.cfg.block_size) \
            <= self.allocator.num_free + len(self._lru)

    def can_fit_request(self, tokens) -> bool:
        """Admission check for a full context: new blocks needed after
        prefix matching vs free + evictable (matched blocks are neither)."""
        matched = self.match_prefix(tokens)
        need = blocks_for(len(tokens), self.cfg.block_size) - len(matched)
        evictable = len(self._lru) - sum(1 for b in matched if b in self._lru)
        return need <= self.allocator.num_free + evictable

    @property
    def pool_bytes(self) -> int:
        """Device memory resident in the cache pools (all leaves of the
        pytree, block pools and slot-state rows alike) — a telemetry
        gauge, set once at engine construction."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.pools))

    def stats(self) -> dict:
        """JSON-able cache-layer stats for the telemetry exporters:
        allocator occupancy, geometry, and the prefix-index counters."""
        return {"num_blocks": self.cfg.num_blocks,
                "block_size": self.cfg.block_size,
                "num_free": self.allocator.num_free,
                "num_used": self.allocator.num_used,
                "utilization": self.utilization,
                "pool_bytes": self.pool_bytes,
                "prefix": self.prefix_stats() if self.cfg.share_prefix
                else None}

    @property
    def utilization(self) -> float:
        """Live cache pressure: blocks held by running requests / usable.
        Unreferenced LRU-retired prefix-cache blocks are excluded — they
        are reclaimable on demand, and counting them would make the
        block_utilization metrics climb toward 1.0 under sharing even with
        the pool mostly evictable."""
        usable = self.cfg.num_blocks - 1
        return (self.allocator.num_used - len(self._lru)) / max(usable, 1)

    # -- snapshot (ROADMAP item 4 groundwork; schedcheck canonicalizes
    #    exactly this structure) --------------------------------------------
    @staticmethod
    def _flat_key(key: Optional[tuple]) -> tuple:
        """Chain key -> the flat token prefix it commits to.  The nested
        (prev, chunk) form is an incremental-hashing optimization; the flat
        prefix is the canonical, serializable equivalent."""
        out: list[int] = []
        while key is not None:
            key, chunk = key
            out[:0] = chunk
        return tuple(out)

    def _nest_key(self, flat) -> Optional[tuple]:
        """Inverse of ``_flat_key``: fold a flat token prefix back into the
        (prev, chunk) chain form, one chunk per block_size tokens."""
        bs = self.cfg.block_size
        prev: Optional[tuple] = None
        for i in range(0, len(flat), bs):
            prev = (prev, tuple(int(t) for t in flat[i:i + bs]))
        return prev

    def host_state_dict(self) -> dict:
        """JSON-able snapshot of every host-side structure: allocator
        free list (order is behavioral — pop order decides physical block
        reuse), refcounts, block tables, prefix index (as flat token
        prefixes), LRU residency order, per-request commit cursors, and
        the prefix counters.  Device pools are *not* included — KV bytes
        are recomputable from tokens (recompute-preemption relies on the
        same property)."""
        alloc = self.allocator
        return {
            "free_list": list(alloc._free),
            "refcounts": [[b, alloc._ref[b]] for b in sorted(alloc._ref)],
            "tables": [[rid, list(bs)]
                       for rid, bs in sorted(self.tables.items())],
            "prefix_index": [[list(self._flat_key(k)), b]
                             for k, b in sorted(self._hash_to_block.items(),
                                                key=lambda kv: kv[1])],
            "lru": list(self._lru),
            "committed": [[rid, n, None if key is None
                           else list(self._flat_key(key))]
                          for rid, (n, key) in sorted(self._committed.items())],
            "counters": {"prefix_hit_tokens": self.prefix_hit_tokens,
                         "prefix_lookup_tokens": self.prefix_lookup_tokens,
                         "prefix_evictions": self.prefix_evictions},
        }

    def load_host_state_dict(self, state: dict) -> None:
        """Restore from ``host_state_dict()`` output (same cfg geometry).
        Coerces ints so npz/JSON round-trips (which widen to int64 / lists)
        restore bit-identical host state."""
        alloc = self.allocator
        alloc._free = [int(b) for b in state["free_list"]]
        alloc._ref = {int(b): int(rc) for b, rc in state["refcounts"]}
        self.tables = {int(rid): [int(b) for b in bs]
                       for rid, bs in state["tables"]}
        self._hash_to_block = {}
        self._block_to_hash = {}
        for flat, b in state["prefix_index"]:
            key = self._nest_key(flat)
            self._hash_to_block[key] = int(b)
            self._block_to_hash[int(b)] = key
        self._lru = OrderedDict((int(b), None) for b in state["lru"])
        self._committed = {
            int(rid): (int(n), None if flat is None else self._nest_key(flat))
            for rid, n, flat in state["committed"]}
        c = state["counters"]
        self.prefix_hit_tokens = int(c["prefix_hit_tokens"])
        self.prefix_lookup_tokens = int(c["prefix_lookup_tokens"])
        self.prefix_evictions = int(c["prefix_evictions"])

    # -- device-side views --------------------------------------------------
    def table_row(self, rid: Optional[int]) -> np.ndarray:
        """(max_blocks_per_seq,) int32, padded with the null block.  rid=None
        (idle slot) is an all-null row."""
        row = np.full((self.cfg.max_blocks_per_seq,), NULL_BLOCK, np.int32)
        if rid is not None:
            blocks = self.tables[rid]
            row[: len(blocks)] = blocks
        return row

    def table_array(self, rids: list[Optional[int]]) -> np.ndarray:
        """(B, max_blocks_per_seq) int32 block tables for a slot vector."""
        return np.stack([self.table_row(r) for r in rids])
