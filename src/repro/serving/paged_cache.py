"""Paged KV cache: fixed-size blocks, free-list allocation, block tables.

The device side is a *physical block pool* per attention layer
(models/transformer.init_paged_cache — shape (repeat, num_blocks,
block_size, Hkv, head_dim), no batch axis).  This module is the host side:
which physical blocks belong to which request, and how many are free.

Block 0 is the reserved **null block**: it is never allocated, idle batch
slots point every block-table entry at it, and the padded tail of short
tables also maps there, so stray writes land in a scratch page that no
live request ever reads (layers.paged_attention masks it out).

Layout respects the ASA plan: ContinuousBatchingEngine device_puts the
pools with NamedShardings built from SchedulePlan.paged_cache_specs()
(kv-head axis over `model` — see core/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig
from repro.models import transformer as T

NULL_BLOCK = 0


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Physical blocks needed to hold n_tokens."""
    return -(-n_tokens // block_size)


class BlockAllocator:
    """Free-list allocator over physical block ids 1..num_blocks-1.

    Allocation is all-or-nothing (returns None instead of a partial grant)
    so a request under cache pressure either fits or triggers preemption —
    it never strands half-allocated pages.  Double-free and foreign-block
    frees raise: the invariants the serving tests pin down.
    """

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least the null block + one real block"
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() -> low ids first
        self._used: set[int] = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> Optional[list[int]]:
        if n < 0:
            raise ValueError(n)
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._used.update(blocks)
        return blocks

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("cannot free the null block")
            if b not in self._used:
                raise ValueError(f"double free / foreign block {b}")
            self._used.remove(b)
            self._free.append(b)


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    block_size: int
    num_blocks: int            # physical, including the reserved null block
    max_blocks_per_seq: int    # block-table width (= ceil(max_len / bs))
    slots: int = 0             # slot-state pool rows (0: attn-only arch)


class PagedKVCache:
    """Device block pools + allocator + per-request block tables.

    With ``cfg.slots`` > 0 the device pytree also carries slot-indexed state
    pools for O(1)-per-request caches; serving/cache_manager.py layers the
    slot-row bookkeeping on top of this class."""

    def __init__(self, arch: ArchConfig, cfg: PagedCacheConfig, *,
                 dtype=jnp.bfloat16, mesh=None, specs=None):
        self.arch, self.cfg = arch, cfg
        pools = T.init_paged_cache(arch, cfg.num_blocks, cfg.block_size,
                                   dtype, slots=cfg.slots)
        if mesh is not None and specs is not None:
            ns = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
            pools = jax.device_put(pools, ns)
        self.pools = pools
        self.allocator = BlockAllocator(cfg.num_blocks)
        self.tables: dict[int, list[int]] = {}   # request id -> physical blocks

    # -- allocation ---------------------------------------------------------
    def reserve(self, rid: int, n_tokens: int) -> bool:
        """Grow request rid's table to cover n_tokens total; False on OOM
        (state unchanged — caller preempts or defers admission)."""
        have = len(self.tables.get(rid, ()))
        need = blocks_for(n_tokens, self.cfg.block_size) - have
        if need <= 0:
            return True
        got = self.allocator.alloc(need)
        if got is None:
            return False
        self.tables.setdefault(rid, []).extend(got)
        return True

    def release(self, rid: int) -> None:
        blocks = self.tables.pop(rid, None)
        if blocks:
            self.allocator.free(blocks)

    def can_fit(self, n_tokens: int) -> bool:
        return blocks_for(n_tokens, self.cfg.block_size) <= self.allocator.num_free

    @property
    def utilization(self) -> float:
        usable = self.cfg.num_blocks - 1
        return self.allocator.num_used / max(usable, 1)

    # -- device-side views --------------------------------------------------
    def table_row(self, rid: Optional[int]) -> np.ndarray:
        """(max_blocks_per_seq,) int32, padded with the null block.  rid=None
        (idle slot) is an all-null row."""
        row = np.full((self.cfg.max_blocks_per_seq,), NULL_BLOCK, np.int32)
        if rid is not None:
            blocks = self.tables[rid]
            row[: len(blocks)] = blocks
        return row

    def table_array(self, rids: list[Optional[int]]) -> np.ndarray:
        """(B, max_blocks_per_seq) int32 block tables for a slot vector."""
        return np.stack([self.table_row(r) for r in rids])
