"""Request admission scheduler for the continuous-batching engine.

The scheduler is duck-typed over a small request protocol — ``id``,
``prompt``, ``max_new_tokens``, ``priority``, ``out_tokens`` and the
bookkeeping slots ``_sched_seq`` / ``_charged_footprint``.  Since the v2
API split (input-only ``Request`` vs engine-internal generation state),
the engine queues its internal per-request records here, never the
caller's Request objects.

Policy:
  * priority classes — lower ``priority`` value is served first;
  * FCFS inside a class — ties break on arrival sequence, and a preempted
    request re-enters with its *original* sequence number, so it goes back
    to the head of its class rather than the tail;
  * max-tokens budgeting — admission is refused while the worst-case token
    footprint of running requests (prompt + max_new_tokens each, capped at
    ``footprint_cap`` — the engine's max_len truncation — so a long-prompt
    request is charged what it can actually consume) would exceed
    ``max_tokens_in_flight``;
  * preemption — under cache pressure the engine asks for a victim: the
    request with the largest resident cache footprint (tokens in cache,
    ``len(r.context())``) in the lowest priority class, which frees the
    most blocks per preemption.  Footprint, not generated-token count: a
    long-prompt request mid-prefill has zero output tokens but may hold
    more blocks than any decoding request.

Telemetry: ``stats`` is a live dict of scheduler-level counters
(submitted / admitted / budget_refusals / preemptions / released).  The
engine hands the dict to ``ServingMetrics`` once at construction, so the
summary's ``scheduler`` section and the Prometheus/JSONL exporters stay
current without a per-step push.  ``budget_refusals`` in particular is an
adaptive-scheduler input: it counts admission attempts blocked by the
token budget while work was queued — the signal that the budget, not the
cache, is the bottleneck.
"""
from __future__ import annotations

import heapq
from typing import Optional


class RequestScheduler:
    def __init__(self, *, max_tokens_in_flight: Optional[int] = None,
                 footprint_cap: Optional[int] = None):
        self.max_tokens_in_flight = max_tokens_in_flight
        self.footprint_cap = footprint_cap     # engine sets this to max_len
        self._heap: list = []                  # (priority, seq, Request)
        # plain int, not itertools.count: snapshotable (state_dict) and
        # bounded by #unique submits (preemption re-enqueue keeps its seq)
        self._next_seq = 0
        self._in_flight_tokens = 0
        # live telemetry counters (ServingMetrics holds a reference)
        self.stats: dict[str, int] = {"submitted": 0, "admitted": 0,
                                      "budget_refusals": 0,
                                      "preemptions": 0, "released": 0}

    # -- queue --------------------------------------------------------------
    def check_submittable(self, req) -> None:
        """Raise if ``req`` could NEVER be admitted (footprint over the
        whole budget) — pure check, no state change, so the engine can vet
        a batch before enqueueing any of it."""
        if (self.max_tokens_in_flight is not None
                and self._footprint(req) > self.max_tokens_in_flight):
            raise ValueError(f"request {req.id} exceeds the token budget "
                             f"({self._footprint(req)} > "
                             f"{self.max_tokens_in_flight}) — it could never "
                             f"be admitted")

    def submit(self, req) -> None:
        self.check_submittable(req)
        self._enqueue(req)
        self.stats["submitted"] += 1

    def _enqueue(self, req) -> None:
        if getattr(req, "_sched_seq", None) is None:
            req._sched_seq = self._next_seq    # preserved across preemption
            self._next_seq += 1
        heapq.heappush(self._heap, (req.priority, req._sched_seq, req))

    def remove(self, req) -> bool:
        """Drop a *queued* request (cancellation before admission).  True
        iff it was in the queue.  Queued requests hold no budget charge —
        that happens at admission — so removal is pure queue surgery."""
        kept = [e for e in self._heap if e[2] is not req]
        if len(kept) == len(self._heap):
            return False
        self._heap = kept
        heapq.heapify(self._heap)
        return True

    @property
    def queue_depth(self) -> int:
        return len(self._heap)

    def peek(self):
        return self._heap[0][2] if self._heap else None

    # -- admission ----------------------------------------------------------
    def _footprint(self, req) -> int:
        """Worst-case resident tokens — capped at footprint_cap because the
        engine truncates every request there (engine._target_total): an
        uncapped estimate over-charged the budget and could stall admission
        of requests the cache can in fact hold."""
        fp = len(req.prompt) + req.max_new_tokens
        return fp if self.footprint_cap is None else min(fp,
                                                         self.footprint_cap)

    def next_admission(self):
        """Pop the next request iff the token budget admits it, else None.
        (Head-of-line blocking within the budget is deliberate: skipping
        ahead would starve large requests.)"""
        if not self._heap:
            return None
        req = self._heap[0][2]
        if (self.max_tokens_in_flight is not None
                and self._in_flight_tokens + self._footprint(req)
                > self.max_tokens_in_flight):
            # queued work refused on budget, not cache: the signal that the
            # token budget is the bottleneck (telemetry, ROADMAP item 3)
            self.stats["budget_refusals"] += 1
            return None
        heapq.heappop(self._heap)
        # remember the exact charge: if footprint_cap changes while this
        # request is in flight (scheduler reused across engines), releasing
        # a re-computed footprint would leak budget forever
        req._charged_footprint = self._footprint(req)
        self._in_flight_tokens += req._charged_footprint
        self.stats["admitted"] += 1
        return req

    def on_finish(self, req) -> None:
        self._release_budget(req)
        self.stats["released"] += 1

    def _release_budget(self, req) -> None:
        charged = getattr(req, "_charged_footprint", None)
        self._in_flight_tokens -= (self._footprint(req) if charged is None
                                   else charged)
        req._charged_footprint = None

    # -- snapshot (ROADMAP item 4 groundwork; schedcheck canonicalizes
    #    exactly this structure) ---------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able snapshot of the scheduler's control state.  Queued
        requests are recorded by id (the engine owns the request objects
        and snapshots them separately); ``load_state_dict`` re-marries
        them.  The heap is stored in sorted (priority, seq) order — a
        canonical form, since heap layout is an implementation detail."""
        return {
            "max_tokens_in_flight": self.max_tokens_in_flight,
            "footprint_cap": self.footprint_cap,
            "next_seq": self._next_seq,
            "in_flight_tokens": self._in_flight_tokens,
            "queue": [[prio, seq, req.id]
                      for prio, seq, req in sorted(
                          self._heap, key=lambda e: e[:2])],
            "stats": dict(self.stats),
        }

    def load_state_dict(self, state: dict, requests_by_id: dict) -> None:
        """Restore from ``state_dict()`` output.  ``requests_by_id`` maps
        request id -> live request object for every queued entry."""
        self.max_tokens_in_flight = state["max_tokens_in_flight"]
        self.footprint_cap = state["footprint_cap"]
        self._next_seq = int(state["next_seq"])
        self._in_flight_tokens = int(state["in_flight_tokens"])
        self._heap = []
        for prio, seq, rid in state["queue"]:
            req = requests_by_id[rid]
            req._sched_seq = int(seq)
            self._heap.append((int(prio), int(seq), req))
        heapq.heapify(self._heap)
        self.stats.update({k: int(v) for k, v in state["stats"].items()})

    # -- preemption ---------------------------------------------------------
    def pick_preemption_victim(self, running: list):
        """Largest-resident-footprint request in the lowest priority class,
        or None.  len(context()) = prompt + generated = tokens in cache, so
        this frees the most blocks per preemption; ranking by generated
        tokens alone put a long-prompt mid-prefill request (0 output
        tokens, many resident blocks) last."""
        if not running:
            return None
        # len(prompt) + len(out_tokens) == len(context()) without the O(n)
        # concatenation — this runs per candidate on the pressure hot path
        return max(running, key=lambda r: (r.priority,
                                           len(r.prompt) + len(r.out_tokens),
                                           r._sched_seq))

    def preempt(self, req) -> None:
        """Return a running request to the queue (recompute-style: its
        generated tokens stay on the request and are re-prefilled).

        Only ``preemptions`` counts here: routing through on_finish() +
        submit() — as this used to — inflated both ``released`` and
        ``submitted`` by one per preemption, so the exported lifecycle
        counters overstated client submissions AND completions whenever
        the engine ran under cache pressure.  The budget charge is still
        released (the request no longer holds cache) and the request
        re-enters with its original seq (head of its priority class)."""
        self._release_budget(req)
        self._enqueue(req)
        self.stats["preemptions"] += 1
