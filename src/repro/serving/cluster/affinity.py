"""Prefix-affinity routing index for the cluster router.

The point: PR 4's cross-request prefix cache gives ~0.85 hit rates on
shared-system-prompt traffic *within one engine*.  Naive round-robin
across replicas shatters that — each replica sees 1/N of the requests
sharing a prefix and re-prefills the prefix independently.  This index
routes a prompt to the replica that already committed the blocks its
prefix hashes to, keeping the aggregate hit rate at the single-process
value.

It is a *router-local shadow* of the workers' paged-cache content
indexes, keyed by literally the same chain keys
(serving/prefix_hash.chain_keys — see that module for why sharing the
function matters).  The shadow is optimistic: it records which replica
a prompt's full blocks were *sent to*, not whether the worker's cache
still holds them (eviction is invisible up here).  A stale entry costs
one cache miss on a well-chosen replica — strictly no worse than the
least-loaded fallback — so optimism is safe.

``route`` returns the replica holding the *longest* matching prefix
among live replicas.  No match ⇒ the caller falls back to least-loaded.
The map is LRU-capped (OrderedDict, move-to-end on hit) so a long-lived
router cannot grow without bound; capacity evicts the coldest prefix
keys first, mirroring the workers' own LRU block eviction.

No jax in this module: routing is pure host-side bookkeeping (the
router process never builds a mesh or compiles a step).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence

from repro.serving.prefix_hash import chain_keys


class PrefixAffinity:
    def __init__(self, block_size: int, *, max_keys: int = 65536):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1 (got {block_size})")
        if max_keys < 1:
            raise ValueError(f"max_keys must be >= 1 (got {max_keys})")
        self.block_size = block_size
        self.max_keys = max_keys
        self._owner: OrderedDict = OrderedDict()   # chain key -> replica id
        self.stats = {"routed_affinity": 0, "routed_fallback": 0,
                      "keys_evicted": 0}

    def __len__(self) -> int:
        return len(self._owner)

    def route(self, tokens: Sequence[int], live: Sequence[int]) \
            -> tuple[Optional[int], int]:
        """-> (replica or None, matched_blocks).  The replica owning the
        longest full-block prefix of ``tokens`` among ``live`` replicas;
        ``None`` when no prefix key maps to a live replica (caller falls
        back to least-loaded).  Matching walks the chain from the end —
        same longest-prefix semantics as ``PagedKVCache.match_prefix`` —
        and skips keys owned by dead replicas rather than stopping, since
        a shorter prefix on a live replica still beats a cold start."""
        live_set = set(live)
        best: tuple[Optional[int], int] = (None, 0)
        for n, key in enumerate(chain_keys(tokens, self.block_size), 1):
            owner = self._owner.get(key)
            if owner in live_set:
                best = (owner, n)
                self._owner.move_to_end(key)       # LRU touch
        if best[0] is not None:
            self.stats["routed_affinity"] += 1
        else:
            self.stats["routed_fallback"] += 1
        return best

    def commit(self, tokens: Sequence[int], replica: int) -> int:
        """Record that ``tokens``' full-block prefix keys now live on
        ``replica`` (called when a request is routed there — by the time
        a later request matches, the worker has prefilled and committed
        the blocks).  Later commits overwrite earlier owners: the newest
        copy is the one most likely still resident.  Returns the number
        of keys recorded."""
        keys = chain_keys(tokens, self.block_size)
        for key in keys:
            self._owner[key] = replica
            self._owner.move_to_end(key)
        while len(self._owner) > self.max_keys:
            self._owner.popitem(last=False)
            self.stats["keys_evicted"] += 1
        return len(keys)

    def drop_replica(self, replica: int) -> int:
        """Forget every key owned by a dead replica; returns how many.
        (``route`` already skips dead owners — this reclaims the space
        and lets colder live entries survive the LRU cap.)"""
        dead = [k for k, r in self._owner.items() if r == replica]
        for k in dead:
            del self._owner[k]
        return len(dead)
