"""Subprocess launcher for engine replica workers.

Spawns N copies of ``python -m repro.serving.cluster.worker``, each with
its own environment: ``XLA_FLAGS --xla_force_host_platform_device_count``
is set *per child* (replacing any inherited forced count) so every
replica owns its own mesh slice — the parent router process never
imports jax and is unaffected.  Workers dial back to the router's
listening socket; ``accept_workers`` pairs each accepted connection with
its ``ready`` message so the router gets handles in replica order no
matter the connect order.

Teardown discipline (the CI cluster job SIGTERMs the router and asserts
no orphans): ``stop()`` broadcasts ``shutdown`` on any still-open
transports, waits ``grace`` seconds for voluntary exit, then escalates
terminate -> kill.  ``WorkerProcesses`` is a context manager and its
``__exit__`` always reaps, so an exception between spawn and accept
cannot leak children.

No jax in this module (subprocess/socket plumbing only) — the children
are the ones that pay device-runtime startup.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from typing import Optional

from repro.serving.cluster.protocol import (ClusterError, MessageStream,
                                            ProtocolError)


def worker_command(*, connect: str, replica_id: int, arch: str,
                   smoke: bool = False, slots: int = 4, max_len: int = 256,
                   block_size: int = 16, num_blocks: Optional[int] = None,
                   prefill_chunk: int = 64, share_prefix: bool = False,
                   metrics_window: float = 10.0) -> list[str]:
    cmd = [sys.executable, "-m", "repro.serving.cluster.worker",
           "--connect", connect, "--replica-id", str(replica_id),
           "--arch", arch, "--slots", str(slots),
           "--max-len", str(max_len), "--block-size", str(block_size),
           "--prefill-chunk", str(prefill_chunk),
           "--metrics-window", str(metrics_window)]
    if smoke:
        cmd.append("--smoke")
    if num_blocks is not None:
        cmd += ["--num-blocks", str(num_blocks)]
    if share_prefix:
        cmd.append("--share-prefix")
    return cmd


def worker_env(devices_per_worker: int = 1) -> dict:
    """Child environment with the per-worker mesh slice applied.  Any
    inherited forced host-device count is *replaced*, not appended —
    XLA honors the last occurrence, but a stale flag would make the
    intent unreadable in ps output."""
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith("--xla_force_host_platform_device_count")]
    if devices_per_worker > 1:
        flags.append(f"--xla_force_host_platform_device_count="
                     f"{devices_per_worker}")
    env["XLA_FLAGS"] = " ".join(flags)
    if not env["XLA_FLAGS"]:
        del env["XLA_FLAGS"]
    return env


class WorkerProcesses:
    """Owns the worker subprocesses of one cluster."""

    def __init__(self, procs: list[subprocess.Popen]):
        self.procs = procs

    @classmethod
    def spawn(cls, n_replicas: int, *, connect: str, arch: str,
              devices_per_worker: int = 1,
              **worker_kwargs) -> "WorkerProcesses":
        env = worker_env(devices_per_worker)
        procs = []
        try:
            for i in range(n_replicas):
                cmd = worker_command(connect=connect, replica_id=i,
                                     arch=arch, **worker_kwargs)
                procs.append(subprocess.Popen(cmd, env=env))
        except Exception:
            cls(procs).stop(grace=2.0)
            raise
        return cls(procs)

    @property
    def pids(self) -> list[int]:
        return [p.pid for p in self.procs]

    def poll_dead(self) -> list[int]:
        """Indices of workers whose process has exited."""
        return [i for i, p in enumerate(self.procs) if p.poll() is not None]

    def stop(self, *, streams: Optional[list] = None,
             grace: float = 5.0) -> list[int]:
        """Reap every worker: polite shutdown message (when transports are
        provided), then wait, then terminate, then kill.  Returns exit
        codes.  Never raises — teardown must always finish."""
        if streams:
            for s in streams:
                try:
                    s.send({"type": "shutdown"})
                except Exception:
                    pass
        for p in self.procs:
            try:
                p.wait(timeout=grace)
            except subprocess.TimeoutExpired:
                p.terminate()
        for p in self.procs:
            try:
                p.wait(timeout=grace)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
        return [p.returncode for p in self.procs]

    def __enter__(self) -> "WorkerProcesses":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


def listen_socket(host: str = "127.0.0.1", port: int = 0) -> socket.socket:
    """Router-side listening socket (port 0 = ephemeral; read the bound
    port off ``.getsockname()``)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(16)
    return srv


def accept_workers(srv: socket.socket, n: int, *, timeout: float = 120.0,
                   procs: Optional[WorkerProcesses] = None) \
        -> dict[int, tuple[MessageStream, dict]]:
    """Accept ``n`` worker connections and pair each with its ``ready``
    message -> {replica_id: (stream, ready_msg)}.  The generous default
    timeout covers first-run jit compilation in the children.  Raises
    ClusterError if a worker process dies before connecting (checked
    between accepts via ``procs``) or the timeout lapses."""
    srv.settimeout(1.0)
    deadline = timeout
    by_replica: dict[int, tuple[MessageStream, dict]] = {}
    while len(by_replica) < n:
        if procs is not None and procs.poll_dead():
            raise ClusterError(f"worker(s) {procs.poll_dead()} exited "
                               f"before connecting")
        try:
            conn, _ = srv.accept()
        except socket.timeout:
            deadline -= 1.0
            if deadline <= 0:
                raise ClusterError(
                    f"timed out waiting for workers "
                    f"({len(by_replica)}/{n} connected)") from None
            continue
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        stream = MessageStream(conn)
        ready = _wait_ready(stream)
        rid = int(ready["replica"])
        if rid in by_replica:
            raise ProtocolError(f"two workers claimed replica id {rid}")
        by_replica[rid] = (stream, ready)
    return by_replica


def _wait_ready(stream: MessageStream, timeout: float = 30.0) -> dict:
    waited = 0.0
    while waited < timeout:
        msgs = stream.poll(0.5)
        if msgs:
            if msgs[0].get("type") != "ready":
                raise ProtocolError(f"worker's first message was "
                                    f"{msgs[0].get('type')!r}, not ready")
            return msgs[0]
        waited += 0.5
    raise ClusterError("worker connected but never sent ready")
