"""Engine replica worker: one ``ContinuousBatchingEngine`` behind the
cluster wire protocol.

Run as a subprocess by the launcher (``python -m
repro.serving.cluster.worker --connect host:port --replica-id N ...``),
or driven in-process by tests (``EngineWorker`` over an
``InProcTransport`` — same message handling, no sockets, no forks).

The process model: each worker owns its own mesh slice via a per-process
``XLA_FLAGS --xla_force_host_platform_device_count`` (set by the
launcher, or by ``--devices`` here *before* jax is imported — which is
why every jax import in this module is deferred into functions).
Replicas are pure data-parallel and never communicate with each other;
``jax.distributed.initialize`` wiring exists behind ``--distributed``
for real multi-host meshes, and single-machine CI never takes that
branch, so no collectives are needed.

Parity contract: params come from ``T.init_lm(PRNGKey(0), arch)`` — the
same deterministic init on every replica — and sampling keys are
``fold_in(seed, absolute_position)``, so a request produces bit-identical
tokens on ANY replica.  The CI cluster job asserts cluster outputs ==
single-process outputs token for token.

The pump loop is single-threaded and clock-free: it alternates between
draining the transport (poll timeout 0 while the engine has work, a
short idle wait otherwise) and stepping the engine; per-token ``token``
messages fire from the engine's ``on_token`` hook mid-step, ``finish``
messages flush from ``engine.completed`` after each step.  Heartbeats
need no timer here — any ``ping`` is answered on the next loop
iteration, and the router counts any message (tokens included) as proof
of life.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys

from repro.serving.cluster.protocol import (ConnectionClosed, MessageStream,
                                            ProtocolError,
                                            sampling_from_wire)

IDLE_POLL_S = 0.05          # transport wait when the engine is idle


class EngineWorker:
    """Protocol adapter around one engine.  ``transport`` is anything
    with send/poll (MessageStream in the subprocess, InProcTransport in
    tests)."""

    def __init__(self, engine, transport, replica_id: int):
        self.engine = engine
        self.transport = transport
        self.replica = replica_id
        self._draining = False
        self._drained_sent = False
        self._shutdown = False
        self._n_flushed = 0              # engine.completed flush cursor
        prev = engine.on_token

        def tap(rid: int, tok: int) -> None:
            if prev is not None:
                prev(rid, tok)
            self.transport.send({"type": "token", "rid": rid, "token": tok})

        engine.on_token = tap

    # -- outbound ------------------------------------------------------
    def _flush_completed(self) -> None:
        done = self.engine.completed
        while self._n_flushed < len(done):
            o = done[self._n_flushed]
            self._n_flushed += 1
            self.transport.send({
                "type": "finish", "rid": o.request_id,
                "token_ids": list(o.token_ids),
                "finish_reason": o.finish_reason,
                "prompt_len": o.prompt_len, "ttft_s": o.ttft_s,
                "tpot_s": o.tpot_s, "logprobs": o.logprobs})

    def _stats(self) -> dict:
        from repro.serving.export import prometheus_text
        eng = self.engine
        return {
            "outstanding_tokens": eng.outstanding_tokens(),
            "in_flight": sum(s.busy for s in eng.slots),
            "queued": eng.scheduler.queue_depth,
            "completed": len(eng.completed),
            # lifetime counters, not windowed: the cluster bench sums these
            # across replicas for an exact aggregate hit rate
            "prefix_hits": eng.metrics.prefix_hit_tokens,
            "prefix_lookups": eng.metrics.prefix_lookup_tokens,
            "window": eng.metrics.window_signals(),
            "prom": prometheus_text(
                eng.metrics, labels={"replica": str(self.replica)}),
        }

    # -- inbound -------------------------------------------------------
    def _handle(self, m: dict) -> None:
        t = m.get("type")
        if t == "submit":
            self._handle_submit(m)
        elif t == "cancel":
            self.engine.cancel(int(m["rid"]),
                               reason=m.get("reason", "cancelled"))
        elif t == "ping":
            self.transport.send({"type": "pong", "seq": m.get("seq"),
                                 "stats": self._stats()})
        elif t == "stats":
            self.transport.send({"type": "stats", "stats": self._stats()})
        elif t == "drain":
            self._draining = True
        elif t == "shutdown":
            self._shutdown = True
        else:
            raise ProtocolError(f"unexpected message type {t!r} from router")

    def _handle_submit(self, m: dict) -> None:
        from repro.serving.engine import Request
        rid = int(m["rid"])
        if self._draining:
            self.transport.send({"type": "error", "rid": rid,
                                 "error": "draining",
                                 "message": "worker is draining"})
            return
        try:
            req = Request(id=rid,
                          prompt=[int(x) for x in m["prompt"]],
                          max_new_tokens=int(m["max_new_tokens"]),
                          priority=int(m.get("priority", 0)),
                          sampling=sampling_from_wire(m.get("sampling", {})))
            self.engine.submit(req)
        except (TypeError, ValueError) as e:
            # reject-at-submit surfaces as a typed error upstream; the rid
            # is finished-with-error, never silently dropped.  TypeError
            # matters as much as ValueError: wrong-typed wire JSON
            # ("temperature": null -> float(None)) must reject the one
            # request, never crash the replica process
            self.transport.send({"type": "error", "rid": rid,
                                 "error": "rejected", "message": str(e)})

    # -- loop ----------------------------------------------------------
    def pump(self, idle_poll: float = IDLE_POLL_S) -> bool:
        """One loop iteration: drain the transport, step the engine,
        flush finishes.  False once the worker should exit (shutdown
        message or router gone).  Tests drive this directly."""
        if self._shutdown:
            return False
        timeout = 0.0 if self.engine.has_work else idle_poll
        try:
            msgs = self.transport.poll(timeout)
        except ConnectionClosed:
            return False                 # router is gone: exit, don't orphan
        for m in msgs:
            self._handle(m)
        if self._shutdown:
            return False
        if self.engine.has_work:
            self.engine.step()
        try:
            self._flush_completed()
            if self._draining and not self.engine.has_work \
                    and not self._drained_sent:
                self._drained_sent = True
                self.transport.send({"type": "drained"})
        except ConnectionClosed:
            return False
        return True

    def serve_forever(self) -> None:
        while self.pump():
            pass


# ---------------------------------------------------------------------------
# subprocess entry point
# ---------------------------------------------------------------------------

def _apply_device_flags(devices: int) -> None:
    """Force the host-platform device count for THIS process.  Must run
    before jax is imported — which is why main() defers every jax import
    and the launcher prefers setting XLA_FLAGS in the child env."""
    if "jax" in sys.modules:
        raise RuntimeError("--devices must be applied before jax is "
                           "imported; launch the worker as a fresh process")
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={devices}".strip()


def build_engine(args):
    """Arch + params + mesh + engine for one replica (jax imports live
    here, after any XLA_FLAGS mutation)."""
    import jax

    from repro.configs import get_arch, reduce_for_smoke
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.serving import ContinuousBatchingEngine, ServingMetrics

    if args.distributed:
        # real multi-host wiring — never taken on single-machine CI, so
        # the data-parallel replicas there need no collective backend
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes, process_id=args.process_id)
    arch = get_arch(args.arch)
    if args.smoke:
        arch = reduce_for_smoke(arch)
    params = T.init_lm(jax.random.PRNGKey(0), arch)   # identical per replica
    mesh = make_host_mesh()
    return ContinuousBatchingEngine(
        arch, params, mesh, slots=args.slots, max_len=args.max_len,
        block_size=args.block_size, num_blocks=args.num_blocks,
        prefill_chunk=args.prefill_chunk, share_prefix=args.share_prefix,
        metrics=ServingMetrics(window_s=args.metrics_window))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--connect", required=True,
                    help="router address host:port")
    ap.add_argument("--replica-id", type=int, required=True)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--share-prefix", action="store_true")
    ap.add_argument("--metrics-window", type=float, default=10.0)
    ap.add_argument("--devices", type=int, default=None,
                    help="force this process's host-platform device count "
                         "(the launcher normally sets XLA_FLAGS instead)")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize (real multi-host "
                         "meshes only; single-machine clusters never need "
                         "collectives)")
    ap.add_argument("--coordinator", default="127.0.0.1:12345")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.devices is not None:
        _apply_device_flags(args.devices)

    import jax                                       # after XLA_FLAGS

    engine = build_engine(args)
    host, port = args.connect.rsplit(":", 1)
    sock = socket.create_connection((host, int(port)))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    stream = MessageStream(sock)
    stream.send({"type": "ready", "replica": args.replica_id,
                 "pid": os.getpid(), "devices": jax.device_count(),
                 "max_len": args.max_len})
    worker = EngineWorker(engine, stream, args.replica_id)
    try:
        worker.serve_forever()
    finally:
        stream.close()


if __name__ == "__main__":
    main()
