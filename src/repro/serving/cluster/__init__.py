"""Multi-process serving cluster: engine replica workers, a
prefix-affinity router, and an HTTP/SSE streaming frontend.

Topology (see docs/SERVING.md for the full picture):

    client --HTTP/SSE--> frontend --(in-proc)--> Router
                                       | NDJSON over localhost TCP
                            +----------+----------+
                            v                     v
                      worker 0 (subprocess)  worker 1 (subprocess)
                      ContinuousBatchingEngine each, own mesh slice

Replicas are pure data-parallel: workers never communicate with each
other, so single-machine CI needs no collectives.  Determinism
(``fold_in(seed, position)`` sampling keys, identical ``PRNGKey(0)``
params) makes any replica produce bit-identical tokens for a request —
cluster-vs-single-process parity is a hard assertion.

Import layering: this package root, ``protocol``, ``affinity``,
``router`` and ``frontend`` use no jax themselves — the router/frontend
process pays the parent package's jax *import* (Python always executes
``repro.serving.__init__``) but never builds a mesh, loads params or
compiles a step; only ``worker`` (lazily, inside functions) and the
subprocesses it runs touch devices.
"""
from repro.serving.cluster.protocol import (ClusterError, ConnectionClosed,
                                            ProtocolError, ReplicaDeadError,
                                            SubmitRejectedError,
                                            InProcTransport, MessageStream,
                                            encode_message)
from repro.serving.cluster.affinity import PrefixAffinity
from repro.serving.cluster.router import ReplicaHandle, Router
from repro.serving.cluster.launcher import WorkerProcesses
from repro.serving.cluster.frontend import ClusterHTTPServer

__all__ = [
    "ClusterError", "ConnectionClosed", "ProtocolError", "ReplicaDeadError",
    "SubmitRejectedError", "InProcTransport", "MessageStream",
    "encode_message", "PrefixAffinity", "ReplicaHandle", "Router",
    "WorkerProcesses", "ClusterHTTPServer",
]
