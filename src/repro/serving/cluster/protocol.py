"""Wire protocol between the cluster router and its engine workers.

Framing is newline-delimited JSON (NDJSON): one message per line, UTF-8,
compact separators, no newlines inside a message.  JSON because every
payload (token ids, sampling params, stats dicts, Prometheus text) is
already JSON-able in this codebase; newline framing because it needs no
length prefix, is trivially inspectable with ``nc``/``socat``, and a
partial line at EOF is unambiguously a truncated message.

Message types (full field tables in docs/SERVING.md):

  router -> worker:
    submit    rid, prompt, max_new_tokens, priority, sampling{...}
    cancel    rid, [reason]
    stats     (request one unsolicited stats message back)
    ping      seq                     (heartbeat probe)
    drain     (finish in-flight work, then report ``drained``)
    shutdown  (exit the serve loop; process exits 0)

  worker -> router:
    ready     replica, pid, devices   (sent once, first message)
    token     rid, token, [logprob]   (one per sampled token, in order)
    finish    rid, token_ids, finish_reason, prompt_len, ttft_s, tpot_s,
              [logprobs]
    error     rid, error, message     (submit-time rejection; rid is dead)
    pong      seq, stats{...}         (heartbeat reply + piggybacked stats)
    stats     stats{...}
    drained   (drain complete; engine idle)

The ``stats`` dict carries the worker's load/telemetry vector upstream:
``outstanding_tokens`` (the router's least-loaded fallback metric),
``in_flight``, ``queued``, ``completed``, ``window`` (the engine's
``window_signals()`` vector) and ``prom`` (Prometheus text rendered with
a ``replica`` label, concatenated by the frontend's /metrics).

Two transports implement the same ``send``/``poll`` surface:
``MessageStream`` wraps a real socket (non-blocking reads via ``select``,
bounded-blocking writes via ``sendall`` under a send timeout);
``InProcTransport`` is a deque pair for tests that run router and worker
in one process with no sockets at all.
"""
from __future__ import annotations

import json
import select
import socket
from collections import deque
from typing import Optional


class ClusterError(Exception):
    """Base for cluster-level failures surfaced to callers."""


class ProtocolError(ClusterError):
    """Malformed or unexpected message on the wire."""


class ConnectionClosed(ClusterError):
    """The peer closed its end of the transport."""


class ReplicaDeadError(ClusterError):
    """The replica owning a request died (heartbeat timeout or EOF)
    before the request finished.  In-flight requests on a dead replica
    fail with this — zero-loss restore stays ROADMAP item 4."""

    def __init__(self, replica: int, message: str = ""):
        self.replica = replica
        super().__init__(message or f"replica {replica} died")


class SubmitRejectedError(ClusterError):
    """The worker's engine rejected the request at submit (validation or
    budget) — the rid is finished-with-error, never silently dropped."""


def encode_message(msg: dict) -> bytes:
    """One NDJSON frame.  Compact separators keep token messages — the
    high-rate path — under ~50 bytes."""
    line = json.dumps(msg, separators=(",", ":"))
    if "\n" in line:
        raise ProtocolError("message contains a newline after encoding")
    return line.encode("utf-8") + b"\n"


def decode_message(line: bytes) -> dict:
    try:
        msg = json.loads(line)
    except ValueError as e:
        raise ProtocolError(f"undecodable frame {line[:80]!r}: {e}") from None
    if not isinstance(msg, dict) or "type" not in msg:
        raise ProtocolError(f"frame is not a typed message: {line[:80]!r}")
    return msg


#: sendall bound.  A healthy peer drains its socket buffer in
#: milliseconds; a send still blocked after this long means the peer is
#: wedged (e.g. itself stuck in a blocking write back at us), and the
#: only safe escalation is ConnectionClosed so the caller marks the
#: replica dead instead of holding its lock forever.
SEND_TIMEOUT_S = 30.0


class MessageStream:
    """NDJSON messages over a connected socket.

    ``send`` is bounded-blocking (sendall under ``send_timeout`` — the
    writer is either the router's lock-held submit path or the worker's
    pump loop, both of which want backpressure, not buffering; but the
    router's submit holds the router lock, which the poll thread also
    needs, so an unbounded sendall against a wedged peer would deadlock
    the whole cluster).  A timed-out send raises ``ConnectionClosed``:
    the frame may be half-written, so the connection is unusable and the
    caller's mark-dead path is the correct escalation.  ``poll`` drains
    whatever is readable within ``timeout`` seconds and returns complete
    messages; a partial trailing line stays buffered for the next poll.
    EOF raises ``ConnectionClosed`` from the *next* poll after any
    buffered complete messages have been delivered — no message is lost
    to a close.
    """

    def __init__(self, sock: socket.socket,
                 send_timeout: float = SEND_TIMEOUT_S):
        self._sock = sock
        self._send_timeout = send_timeout
        self._rbuf = b""
        self._eof = False
        self._pending: deque = deque()

    def fileno(self) -> int:
        return self._sock.fileno()

    def send(self, msg: dict) -> None:
        data = encode_message(msg)
        try:
            self._sock.settimeout(self._send_timeout)
            try:
                self._sock.sendall(data)
            finally:
                self._sock.settimeout(None)
        except socket.timeout:
            raise ConnectionClosed(
                f"send timed out after {self._send_timeout:.0f}s "
                f"(peer wedged, frame possibly half-written)") from None
        except OSError as e:
            raise ConnectionClosed(f"send failed: {e}") from None

    def _drain_socket(self, timeout: float) -> None:
        while True:
            try:
                r, _, _ = select.select([self._sock], [], [], timeout)
            except OSError as e:
                raise ConnectionClosed(f"select failed: {e}") from None
            if not r:
                return
            try:
                chunk = self._sock.recv(65536)
            except OSError as e:
                raise ConnectionClosed(f"recv failed: {e}") from None
            if not chunk:
                self._eof = True
                return
            self._rbuf += chunk
            # keep draining without blocking: more may already be queued
            timeout = 0.0

    def poll(self, timeout: float = 0.0) -> list[dict]:
        """Complete messages received within ``timeout`` seconds (possibly
        none).  Raises ConnectionClosed once the peer is gone AND every
        buffered message has been returned."""
        if not self._eof:
            self._drain_socket(timeout)
        while b"\n" in self._rbuf:
            line, self._rbuf = self._rbuf.split(b"\n", 1)
            if line:                      # tolerate keepalive blank lines
                self._pending.append(decode_message(line))
        out = list(self._pending)
        self._pending.clear()
        if not out and self._eof:
            raise ConnectionClosed("peer closed the connection")
        return out

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


class InProcTransport:
    """In-process transport half: messages ``send``-ed here appear in the
    paired half's ``poll``.  Built by ``pair()``; used by router unit
    tests (scripted fake workers) and the in-process parity test (real
    engines, no subprocesses).  ``close()`` makes the *peer* see
    ConnectionClosed — same semantics as a socket shutdown."""

    def __init__(self):
        self._inbox: deque = deque()
        self._peer: Optional[InProcTransport] = None
        self._closed = False

    @classmethod
    def pair(cls) -> tuple["InProcTransport", "InProcTransport"]:
        a, b = cls(), cls()
        a._peer, b._peer = b, a
        return a, b

    def send(self, msg: dict) -> None:
        if self._peer is None or self._peer._closed:
            raise ConnectionClosed("peer closed the transport")
        # encode/decode round-trip so tests exercise the same JSON
        # constraints (tuples become lists, keys become strings) as sockets
        self._peer._inbox.append(decode_message(encode_message(msg)[:-1]))

    def poll(self, timeout: float = 0.0) -> list[dict]:
        out = list(self._inbox)
        self._inbox.clear()
        if not out and (self._closed
                        or self._peer is None or self._peer._closed):
            raise ConnectionClosed("peer closed the transport")
        return out

    def close(self) -> None:
        self._closed = True


def sampling_to_wire(sp) -> dict:
    """SamplingParams -> JSON-able dict (tuples become lists on the wire;
    ``sampling_from_wire`` restores them)."""
    return {"temperature": sp.temperature, "top_k": sp.top_k,
            "top_p": sp.top_p, "seed": sp.seed,
            "stop_token_ids": list(sp.stop_token_ids),
            "stop": list(sp.stop), "logprobs": sp.logprobs}


def _wire_seq(d: dict, key: str) -> tuple:
    """A list-valued wire field as a tuple.  A bare string is rejected
    rather than iterated: ``"stop": "END"`` would otherwise silently
    become per-character stops ("E", "N", "D")."""
    v = d.get(key, ())
    if isinstance(v, (str, bytes)):
        raise ValueError(f"{key!r} must be a list, not a bare string "
                         f"({v!r})")
    return tuple(v)


def sampling_from_wire(d: dict):
    """Inverse of ``sampling_to_wire``.  Imported lazily so this module
    stays importable without pulling serving.sampling's jax import into
    a process that only routes (the router never calls this).

    Raises ValueError OR TypeError on wrong-typed fields (float(None),
    int("x"), ...) — callers that must survive arbitrary wire input
    (worker submit handling) catch both."""
    from repro.serving.sampling import SamplingParams
    return SamplingParams(
        temperature=float(d.get("temperature", 0.0)),
        top_k=int(d.get("top_k", 0)),
        top_p=float(d.get("top_p", 1.0)),
        seed=None if d.get("seed") is None else int(d["seed"]),
        stop_token_ids=tuple(int(t) for t in _wire_seq(d, "stop_token_ids")),
        stop=_wire_seq(d, "stop"),
        logprobs=bool(d.get("logprobs", False)))
