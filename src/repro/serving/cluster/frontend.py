"""HTTP/SSE frontend for the serving cluster — stdlib ``http.server``
only, matching the repo's no-new-deps stance.

Endpoints:

  POST /v1/generate   JSON body: ``prompt`` (list of token ids, required),
                      ``max_new_tokens``, ``priority``, the SamplingParams
                      fields (``temperature`` / ``top_k`` / ``top_p`` /
                      ``seed`` / ``stop_token_ids`` / ``stop`` /
                      ``logprobs`` — top-level or nested under a
                      ``sampling`` object) and ``stream``.
                      stream=false -> one JSON response;
                      stream=true  -> ``text/event-stream``: one
                      ``data: {"text": ...}`` event per released text
                      chunk, then a final ``data: {"done": true, ...}``
                      event with the trimmed token_ids / text /
                      finish_reason.
  GET  /metrics       aggregated Prometheus text: router-level series +
                      each replica's self-reported exposition (labeled
                      ``{replica="i"}``), via Router.prometheus_text.
  GET  /healthz       200 + per-replica states while any replica is
                      live; 503 once none are.

Stop strings are enforced HERE, at the detokenized boundary — the
engine/worker stay token-level.  Every generated token is decoded
(serving/detok) and fed through a ``StopStringMatcher`` whose buffered
emission guarantees a partial stop-string suffix is never streamed; on a
match the frontend cancels the request through the router (reason
"stop"), trims the matched text, and truncates ``token_ids`` to the
tokens that contributed text before the match.  Cancellation races are
benign: if the request finished on its own before the cancel landed, the
frontend still reports finish_reason "stop" and the trimmed output —
what the client observes is determined by the match, not the race.

Handler threads never poll the router — they park on a per-request
``queue.Queue`` fed by router callbacks (cheap, called under the router
lock) while the owning process's router thread does the transport work.

No jax in this module, like the rest of the router process.
"""
from __future__ import annotations

import json
import queue
import socketserver
from http.server import BaseHTTPRequestHandler, HTTPServer
from typing import Optional

from repro.serving.cluster.protocol import ClusterError
from repro.serving.cluster.router import Router
from repro.serving.detok import (Detokenizer, StopStringMatcher,
                                 default_detokenizer)

#: handler-side wait for the next router event before giving up on a
#: request (covers first-run jit compile in a cold worker)
EVENT_TIMEOUT_S = 300.0

SAMPLING_FIELDS = ("temperature", "top_k", "top_p", "seed",
                   "stop_token_ids", "stop", "logprobs")


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _is_int(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


#: per-field (predicate, description) — enforced at the HTTP boundary so
#: wrong-typed JSON is a 400 here, never a forwarded submit that a
#: worker has to reject (or, pre-fix, crash on)
_SAMPLING_CHECKS = {
    "temperature": (_is_num, "a number"),
    "top_p": (_is_num, "a number"),
    "top_k": (_is_int, "an int"),
    "seed": (lambda v: v is None or _is_int(v), "an int or null"),
    "logprobs": (lambda v: isinstance(v, bool), "a bool"),
    "stop_token_ids": (lambda v: isinstance(v, list)
                       and all(_is_int(t) for t in v), "a list of ints"),
    "stop": (lambda v: isinstance(v, list)
             and all(isinstance(s, str) and s for s in v),
             "a list of non-empty strings (a bare string would match "
             "per-character)"),
}


class _RequestSink:
    """Bridges router callbacks (router-thread side) to the handler
    thread: every event is one (kind, payload) tuple on a Queue."""

    def __init__(self):
        self.q: queue.Queue = queue.Queue()

    def on_token(self, rid: int, token: int, logprob) -> None:
        self.q.put(("token", token))

    def on_finish(self, msg: dict) -> None:
        self.q.put(("finish", msg))

    def on_error(self, exc: Exception) -> None:
        self.q.put(("error", exc))


def _parse_generate_body(body: dict) -> tuple[list[int], int, int, dict,
                                              bool, tuple]:
    prompt = body.get("prompt")
    if not isinstance(prompt, list) or not prompt \
            or not all(isinstance(t, int) for t in prompt):
        raise ValueError("'prompt' must be a non-empty list of token ids")
    max_new = body.get("max_new_tokens", 16)
    if not isinstance(max_new, int) or max_new < 1:
        raise ValueError("'max_new_tokens' must be an int >= 1")
    priority = body.get("priority", 0)
    if not isinstance(priority, int):
        raise ValueError("'priority' must be an int")
    # sampling fields are accepted at the body top level or nested under
    # a "sampling" object (the nested form wins on conflict)
    nested = body.get("sampling", {})
    if not isinstance(nested, dict):
        raise ValueError("'sampling' must be a JSON object")
    sampling = {k: body[k] for k in SAMPLING_FIELDS if k in body}
    sampling.update({k: nested[k] for k in SAMPLING_FIELDS if k in nested})
    for k, (ok, want) in _SAMPLING_CHECKS.items():
        if k in sampling and not ok(sampling[k]):
            raise ValueError(f"{k!r} must be {want} (got {sampling[k]!r})")
    stops = tuple(sampling.pop("stop", ()))
    stream = bool(body.get("stream", False))
    return prompt, max_new, priority, sampling, stream, stops


class _Handler(BaseHTTPRequestHandler):
    # set by make_handler(); class-level so http.server can instantiate
    router: Router = None
    detok: Detokenizer = None

    def log_message(self, fmt, *args):      # silence per-request stderr spam
        pass

    # -- plumbing ------------------------------------------------------
    def _json(self, code: int, obj: dict) -> None:
        payload = json.dumps(obj).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def _sse_start(self) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.end_headers()

    def _sse_event(self, obj: dict) -> None:
        self.wfile.write(b"data: " + json.dumps(obj).encode("utf-8")
                         + b"\n\n")
        self.wfile.flush()

    # -- GET -----------------------------------------------------------
    def do_GET(self):
        if self.path == "/healthz":
            states = self.router.replica_states()
            live = sum(1 for s in states.values() if s["state"] == "live")
            self._json(200 if live else 503,
                       {"status": "ok" if live else "no live replicas",
                        "replicas": {str(k): v["state"]
                                     for k, v in states.items()},
                        "pending": self.router.pending_count})
        elif self.path == "/metrics":
            text = self.router.prometheus_text().encode("utf-8")
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(text)))
            self.end_headers()
            self.wfile.write(text)
        else:
            self._json(404, {"error": f"no such path {self.path!r}"})

    # -- POST /v1/generate ---------------------------------------------
    def do_POST(self):
        if self.path != "/v1/generate":
            self._json(404, {"error": f"no such path {self.path!r}"})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            if not isinstance(body, dict):
                raise ValueError("body must be a JSON object")
            prompt, max_new, priority, sampling, stream, stops = \
                _parse_generate_body(body)
            matcher = StopStringMatcher(stops)     # validates stop strings
        except ValueError as e:
            self._json(400, {"error": str(e)})
            return

        sink = _RequestSink()
        try:
            rid = self.router.submit(prompt, max_new, priority=priority,
                                     sampling=sampling,
                                     on_token=sink.on_token,
                                     on_finish=sink.on_finish,
                                     on_error=sink.on_error)
        except (ClusterError, ValueError) as e:
            self._json(503 if isinstance(e, ClusterError) else 400,
                       {"error": str(e)})
            return
        self._consume(rid, sink, matcher, stream)

    def _consume(self, rid: int, sink: _RequestSink,
                 matcher: StopStringMatcher, stream: bool) -> None:
        """Drain the request's event queue to completion, running the
        detok/stop-string pipeline; emits SSE along the way when
        ``stream``.  A client disconnect mid-response (BrokenPipe /
        ConnectionReset on a write) cancels the request upstream so the
        engine does not generate the rest as wasted work — the
        disconnect-cancellation behavior documented on engine.cancel."""
        try:
            self._consume_events(rid, sink, matcher, stream)
        except OSError:
            self.router.cancel(rid, reason="disconnect")

    def _consume_events(self, rid: int, sink: _RequestSink,
                        matcher: StopStringMatcher, stream: bool) -> None:
        if stream:
            self._sse_start()
        tokens: list[int] = []
        tok_text_len: list[int] = []   # decoded length per token (for trim)
        emitted: list[str] = []        # text released by the matcher
        finish: Optional[dict] = None
        error: Optional[Exception] = None
        cancelled = False
        while True:
            try:
                kind, payload = sink.q.get(timeout=EVENT_TIMEOUT_S)
            except queue.Empty:
                error = ClusterError(f"no event for {EVENT_TIMEOUT_S:.0f}s "
                                     f"(rid {rid})")
                break
            if kind == "token":
                tokens.append(payload)
                text = self.detok.decode(payload)
                tok_text_len.append(len(text))
                safe = matcher.feed(text)
                if safe:
                    emitted.append(safe)
                    if stream:
                        self._sse_event({"text": safe})
                if matcher.matched is not None and not cancelled:
                    cancelled = True
                    self.router.cancel(rid, reason="stop")
            elif kind == "finish":
                finish = payload
                break
            else:
                error = payload
                break
        if error is not None:
            obj = {"error": str(error), "rid": rid}
            if stream:
                self._sse_event({"done": True, **obj})
            else:
                self._json(502, obj)
            return
        if matcher.matched is None:
            tail = matcher.flush()             # held-back text, no match
            if tail:
                emitted.append(tail)
                if stream:
                    self._sse_event({"text": tail})
        text = "".join(emitted)
        if matcher.matched is not None:
            # keep exactly the tokens that contributed text before the
            # match (the boundary token is kept: its text is split)
            keep, acc = 0, 0
            for ln in tok_text_len:
                if acc >= len(text):
                    break
                keep, acc = keep + 1, acc + ln
            token_ids = tokens[:keep]
            reason = "stop"
        else:
            token_ids = list(finish.get("token_ids", tokens))
            reason = finish.get("finish_reason", "length")
        done = {"done": True, "rid": rid, "token_ids": token_ids,
                "finish_reason": reason, "text": text,
                "matched_stop": matcher.matched,
                "prompt_len": finish.get("prompt_len"),
                "ttft_s": finish.get("ttft_s"),
                "tpot_s": finish.get("tpot_s"),
                "logprobs": finish.get("logprobs")}
        if stream:
            self._sse_event(done)
        else:
            done.pop("done")
            self._json(200, done)


class ClusterHTTPServer(socketserver.ThreadingMixIn, HTTPServer):
    """One HTTP server bound to a Router.  ``port=0`` binds an ephemeral
    port (read ``.server_address``).  Runs on the caller's thread via
    ``serve_forever()``; launch/serve_cluster.py puts it on a daemon
    thread next to the router poll loop."""

    daemon_threads = True

    def handle_error(self, request, client_address):
        # a client that disconnects mid-stream is routine (the handler
        # already cancelled its rid); only real bugs deserve a traceback
        import sys
        exc = sys.exc_info()[1]
        if isinstance(exc, (BrokenPipeError, ConnectionResetError)):
            return
        super().handle_error(request, client_address)

    def __init__(self, router: Router, *, host: str = "127.0.0.1",
                 port: int = 0, detokenizer: Optional[Detokenizer] = None):
        handler = type("BoundHandler", (_Handler,), {
            "router": router,
            "detok": detokenizer or default_detokenizer()})
        super().__init__((host, port), handler)
        self.router = router

    @property
    def url(self) -> str:
        host, port = self.server_address[:2]
        return f"http://{host}:{port}"
