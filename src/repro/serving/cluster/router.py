"""The cluster router: owns replica connections, admission and placement.

One Router instance runs in the frontend process.  It holds a
``ReplicaHandle`` per worker (transport + liveness + in-flight set),
routes each submitted request to exactly one replica, relays streamed
tokens to per-request callbacks, and polices health with heartbeats.

Placement policy (tested without real workers in tests/test_cluster.py):

  1. **prefix affinity** — the replica owning the longest chain-key
     prefix of the prompt (serving/cluster/affinity.py), so shared-prefix
     traffic keeps hitting the paged cache that already holds its blocks;
  2. **least-loaded fallback** — no affinity match ⇒ the live replica
     with the smallest *router-local* outstanding-token estimate:
     ``min(len(prompt) + max_new_tokens, max_len)`` charged at submit,
     decremented per relayed token, cleared at finish/error.  The
     estimate is deliberately local rather than read from worker ``pong``
     stats: stats age (heartbeat-interval granularity) and a burst of
     submits between two pongs would all land on the same replica.
     Worker-reported stats are kept for /metrics and healthz, not for
     placement arithmetic.

Health: a heartbeat ``ping`` goes to every live replica each
``heartbeat_interval``; *any* received message refreshes ``last_seen``
(token traffic is proof of life — a saturated worker must not need to
answer pings to stay alive).  ``last_seen`` older than
``heartbeat_timeout`` — or EOF on the transport, or a protocol
violation (``poll`` contains the ``ProtocolError`` that ``_dispatch``
raises — a malformed worker message kills that replica, never the poll
thread) — marks the replica dead: an absorbing state.  Its in-flight rids fail with
``ReplicaDeadError`` through their error callbacks, its affinity keys
drop, and the router keeps serving on the survivors (full zero-loss
restore stays ROADMAP item 4).

Invariants (docs/INVARIANTS.md section 10): every submitted rid is owned
by exactly one live replica until it leaves through exactly one of
finish / error / cancel; ``last_seen`` is monotone per replica; dead is
absorbing; a dead replica is never routed to.

Threading: the public surface (submit / cancel / poll / stats /
prometheus_text / drain / broadcast_shutdown) is serialized by one lock,
so an HTTP handler thread can submit while the router thread polls.
Sends happen with the lock held, which is safe only because
``MessageStream.send`` is bounded by its send timeout: a wedged worker
(blocked writing tokens at us while we block writing submits at it)
escalates to ConnectionClosed -> ``_mark_dead`` instead of holding the
lock — and thereby the poll thread — forever.
Callbacks fire with the lock held — they must be cheap and non-reentrant
(the HTTP frontend's just enqueue to a per-request Queue).

No jax in this module: routing never touches a device.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.serving.cluster.affinity import PrefixAffinity
from repro.serving.cluster.protocol import (ClusterError, ConnectionClosed,
                                            ProtocolError, ReplicaDeadError,
                                            SubmitRejectedError)

TokenCallback = Callable[[int, int, Optional[float]], None]
FinishCallback = Callable[[dict], None]
ErrorCallback = Callable[[Exception], None]


@dataclass
class ReplicaHandle:
    """Router-side state for one worker replica."""
    replica: int
    transport: object                      # MessageStream or InProcTransport
    state: str = "live"                    # "live" | "dead"
    last_seen: float = 0.0
    pid: Optional[int] = None
    max_len: int = 512                     # from the worker's ready message
    in_flight: set = field(default_factory=set)        # rids owned here
    last_stats: dict = field(default_factory=dict)     # newest pong stats
    prom_text: str = ""                    # newest per-replica /metrics text

    @property
    def alive(self) -> bool:
        return self.state == "live"


@dataclass
class _Pending:
    replica: int
    est_tokens: int                        # remaining worst-case tokens
    on_token: Optional[TokenCallback]
    on_finish: Optional[FinishCallback]
    on_error: Optional[ErrorCallback]


class Router:
    def __init__(self, handles: list[ReplicaHandle], *, block_size: int = 16,
                 heartbeat_interval: float = 1.0,
                 heartbeat_timeout: float = 10.0,
                 affinity_max_keys: int = 65536,
                 clock: Callable[[], float] = time.monotonic):  # reprolint: disable=clock-injection
        if not handles:
            raise ValueError("router needs at least one replica handle")
        self._handles = {h.replica: h for h in handles}
        if len(self._handles) != len(handles):
            raise ValueError("duplicate replica ids")
        self.affinity = PrefixAffinity(block_size,
                                       max_keys=affinity_max_keys)
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self._clock = clock
        self._lock = threading.RLock()
        self._pending: dict[int, _Pending] = {}
        self._next_rid = 0
        self._ping_seq = 0
        self._last_ping = clock()
        now = clock()
        for h in handles:
            h.last_seen = max(h.last_seen, now)
        self.stats = {"submitted": 0, "finished": 0, "errors": 0,
                      "cancelled": 0, "replicas_lost": 0}

    # -- placement -----------------------------------------------------
    def _live(self) -> list[ReplicaHandle]:
        return [h for h in self._handles.values() if h.alive]

    def _place(self, prompt) -> tuple[ReplicaHandle, int]:
        live = self._live()
        if not live:
            raise ClusterError("no live replicas")
        replica, matched = self.affinity.route(prompt,
                                               [h.replica for h in live])
        if replica is not None:
            return self._handles[replica], matched
        loads = {h.replica: sum(self._pending[r].est_tokens
                                for r in h.in_flight) for h in live}
        # deterministic tiebreak on replica id: unit tests pin placement
        best = min(live, key=lambda h: (loads[h.replica], h.replica))
        return best, 0

    # -- public surface ------------------------------------------------
    def submit(self, prompt, max_new_tokens: int, *, priority: int = 0,
               sampling: Optional[dict] = None,
               on_token: Optional[TokenCallback] = None,
               on_finish: Optional[FinishCallback] = None,
               on_error: Optional[ErrorCallback] = None) -> int:
        """Route one request; returns the cluster-assigned rid.  Raises
        ClusterError when no replica is live.  ``sampling`` is the wire
        dict (protocol.sampling_to_wire) — the router never imports
        SamplingParams."""
        prompt = [int(t) for t in prompt]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1 "
                             f"(got {max_new_tokens})")
        with self._lock:
            handle, _ = self._place(prompt)
            rid = self._next_rid
            self._next_rid += 1
            est = min(len(prompt) + max_new_tokens, handle.max_len) \
                - len(prompt)
            self._pending[rid] = _Pending(
                replica=handle.replica, est_tokens=max(est, 0),
                on_token=on_token, on_finish=on_finish, on_error=on_error)
            handle.in_flight.add(rid)
            msg = {"type": "submit", "rid": rid, "prompt": prompt,
                   "max_new_tokens": int(max_new_tokens),
                   "priority": int(priority),
                   "sampling": sampling or {}}
            try:
                handle.transport.send(msg)
            except ConnectionClosed:
                self._mark_dead(handle, "send failed")
                raise ClusterError(
                    f"replica {handle.replica} died at submit") from None
            # register the prompt's blocks as living on this replica —
            # optimistic, but a later shared-prefix request should follow
            # this one even before its prefill commits
            self.affinity.commit(prompt, handle.replica)
            self.stats["submitted"] += 1
            return rid

    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Forward a cancel for an in-flight rid; True iff it was in
        flight here.  The rid stays pending until the worker's ``finish``
        (reason echoed back) arrives — cancel is a request, not a local
        state transition, so token/finish relays stay ordered."""
        with self._lock:
            p = self._pending.get(rid)
            if p is None:
                return False
            handle = self._handles[p.replica]
            if handle.alive:
                try:
                    handle.transport.send({"type": "cancel", "rid": rid,
                                           "reason": reason})
                except ConnectionClosed:
                    self._mark_dead(handle, "send failed")
            self.stats["cancelled"] += 1
            return True

    def poll(self, timeout: float = 0.0) -> int:
        """Drain every live replica's transport, dispatch callbacks, send
        due heartbeats and reap timed-out replicas.  Returns the number of
        messages handled.  The router thread calls this in a loop; unit
        tests call it directly under an injected clock."""
        handled = 0
        with self._lock:
            per = timeout / max(len(self._live()), 1)
            for h in list(self._handles.values()):
                if not h.alive:
                    continue
                try:
                    msgs = h.transport.poll(per)
                except ConnectionClosed:
                    self._mark_dead(h, "connection closed")
                    continue
                if msgs:
                    h.last_seen = max(h.last_seen, self._clock())
                for m in msgs:
                    try:
                        self._dispatch(h, m)
                    except ProtocolError as e:
                        # one malformed worker message must never kill
                        # the (only) poll thread: the offending replica
                        # dies, survivors keep serving
                        self._mark_dead(h, str(e))
                        break
                    handled += 1
            self._heartbeat()
        return handled

    def drain(self) -> None:
        """Ask every live replica to finish in-flight work.  Poll until
        ``pending_count`` reaches zero to complete the drain."""
        with self._lock:
            for h in self._live():
                try:
                    h.transport.send({"type": "drain"})
                except ConnectionClosed:
                    self._mark_dead(h, "send failed")

    def request_stats(self) -> None:
        """Ask every live replica for a fresh stats snapshot; replies land
        in ``replica_states()[i]["stats"]`` on subsequent polls.  The
        cluster benchmark uses this to read exact lifetime counters after
        a drain instead of settling for heartbeat-aged pong stats."""
        with self._lock:
            for h in self._live():
                try:
                    h.transport.send({"type": "stats"})
                except ConnectionClosed:
                    self._mark_dead(h, "send failed")

    def broadcast_shutdown(self) -> None:
        with self._lock:
            for h in self._live():
                try:
                    h.transport.send({"type": "shutdown"})
                except ConnectionClosed:
                    self._mark_dead(h, "send failed")

    @property
    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)

    def replica_states(self) -> dict[int, dict]:
        with self._lock:
            return {h.replica: {"state": h.state, "pid": h.pid,
                                "in_flight": len(h.in_flight),
                                "last_seen": h.last_seen,
                                "stats": dict(h.last_stats)}
                    for h in self._handles.values()}

    def aggregate_stats(self) -> dict:
        with self._lock:
            live = self._live()
            return {"router": dict(self.stats),
                    "affinity": dict(self.affinity.stats),
                    "replicas_live": len(live),
                    "replicas_total": len(self._handles),
                    "pending": len(self._pending)}

    def prometheus_text(self, namespace: str = "repro_serving") -> str:
        """Cluster /metrics payload: hand-rendered router-level series
        followed by each replica's latest self-reported exposition text
        (already labeled ``{replica="i"}`` by the worker).  Parses back
        through export.parse_prometheus_text — pinned in tests."""
        with self._lock:
            lines = []
            counters = {"requests_routed_total": self.stats["submitted"],
                        "requests_finished_total": self.stats["finished"],
                        "requests_errored_total": self.stats["errors"],
                        "replicas_lost_total": self.stats["replicas_lost"],
                        "affinity_routed_total":
                            self.affinity.stats["routed_affinity"],
                        "fallback_routed_total":
                            self.affinity.stats["routed_fallback"]}
            for name, v in counters.items():
                full = f"{namespace}_router_{name}"
                lines.append(f"# TYPE {full} counter")
                lines.append(f"{full} {int(v)}")
            gauges = {"replicas_live": len(self._live()),
                      "requests_pending": len(self._pending),
                      "affinity_keys": len(self.affinity)}
            for name, v in gauges.items():
                full = f"{namespace}_router_{name}"
                lines.append(f"# TYPE {full} gauge")
                lines.append(f"{full} {int(v)}")
            parts = ["\n".join(lines) + "\n"]
            parts.extend(h.prom_text for h in self._handles.values()
                         if h.prom_text)
            return "\n".join(parts)

    # -- message handling ----------------------------------------------
    def _dispatch(self, h: ReplicaHandle, m: dict) -> None:
        t = m.get("type")
        if t == "token":
            p = self._pending.get(int(m["rid"]))
            if p is not None:
                p.est_tokens = max(p.est_tokens - 1, 0)
                if p.on_token is not None:
                    p.on_token(int(m["rid"]), int(m["token"]),
                               m.get("logprob"))
        elif t == "finish":
            rid = int(m["rid"])
            p = self._pending.pop(rid, None)
            h.in_flight.discard(rid)
            if p is not None:
                self.stats["finished"] += 1
                if p.on_finish is not None:
                    p.on_finish(m)
        elif t == "error":
            rid = int(m["rid"])
            p = self._pending.pop(rid, None)
            h.in_flight.discard(rid)
            if p is not None:
                self.stats["errors"] += 1
                if p.on_error is not None:
                    p.on_error(SubmitRejectedError(
                        m.get("message", m.get("error", "rejected"))))
        elif t == "pong" or t == "stats":
            h.last_stats = dict(m.get("stats", {}))
            if "prom" in h.last_stats:
                h.prom_text = h.last_stats.pop("prom")
        elif t == "ready" or t == "drained":
            pass                  # liveness already refreshed by receipt
        else:
            raise ProtocolError(f"unexpected message type {t!r} from "
                                f"replica {h.replica}")

    # -- health --------------------------------------------------------
    def _heartbeat(self) -> None:
        now = self._clock()
        if now - self._last_ping >= self.heartbeat_interval:
            self._last_ping = now
            self._ping_seq += 1
            for h in self._live():
                try:
                    h.transport.send({"type": "ping",
                                      "seq": self._ping_seq})
                except ConnectionClosed:
                    self._mark_dead(h, "send failed")
        for h in self._live():
            if now - h.last_seen > self.heartbeat_timeout:
                self._mark_dead(h, f"no message for "
                                   f"{now - h.last_seen:.1f}s")

    def _mark_dead(self, h: ReplicaHandle, why: str) -> None:
        """Absorbing transition live -> dead.  Every in-flight rid on the
        replica fails with ReplicaDeadError; its affinity keys drop so no
        future request is routed at a ghost."""
        if not h.alive:
            return
        h.state = "dead"
        self.stats["replicas_lost"] += 1
        self.affinity.drop_replica(h.replica)
        err = ReplicaDeadError(h.replica, f"replica {h.replica} died "
                                          f"({why})")
        for rid in sorted(h.in_flight):
            p = self._pending.pop(rid, None)
            if p is not None:
                self.stats["errors"] += 1
                if p.on_error is not None:
                    p.on_error(err)
        h.in_flight.clear()
        try:
            h.transport.close()
        except Exception:
            pass
