"""Metric exporters: atomic file writes, Prometheus text exposition, and
a periodic JSONL snapshot writer.

Three consumers are served:

  * humans / dashboards — ``prometheus_text(metrics)`` renders every
    counter, gauge and log-bucketed histogram registered in the metrics'
    ``Telemetry`` in the Prometheus text exposition format (histograms as
    cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``);
    ``parse_prometheus_text`` is the minimal round-trip parser the tests
    and the CI smoke job use to prove the output is well-formed;
  * offline analysis — ``SnapshotWriter`` appends one compact JSON line
    per ``every_s`` seconds of engine time (windowed signal vector +
    lifetime counters), rewriting the whole file through an atomic
    rename, so a crash mid-write can never leave a truncated line;
  * everything that writes JSON next to benchmark results —
    ``atomic_write_text`` is the shared temp-file + ``os.replace``
    primitive (``ServingMetrics.write`` and the tracer use it too: a
    crash mid-write leaves the previous file intact, never half a JSON).

All timestamps are engine-clock floats passed in by the caller; nothing
here reads a clock, so snapshot cadence is test-drivable.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Optional


def atomic_write_text(path, text: str) -> None:
    """Write ``text`` to ``path`` atomically: temp file in the same
    directory, flush + fsync, then ``os.replace``.  Readers see either
    the old file or the complete new one, never a truncated mix."""
    path = os.fspath(path)
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        # THE sanctioned raw write: this helper is what the atomic-write
        # rule tells everyone else to call (temp file, fsync, os.replace)
        with os.fdopen(fd, "w") as f:  # reprolint: disable=atomic-write
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _labels(labels: Optional[dict]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_prom_name(k)}="{v}"' for k, v in labels.items())
    return "{" + inner + "}"


def _num(x) -> str:
    if x is None:
        return "NaN"                  # Prometheus-legal "no observation"
    if x == float("inf"):
        return "+Inf"
    return repr(float(x))


def prometheus_text(metrics, *, namespace: str = "repro_serving",
                    labels: Optional[dict] = None) -> str:
    """Render a ServingMetrics (or anything with a ``.telemetry`` registry
    and a ``.summary()``) as Prometheus text exposition format."""
    tele = metrics.telemetry
    s = metrics.summary()
    lab = _labels(labels)
    lines: list[str] = []

    def emit(name, kind, value, help_txt, extra_labels=None):
        full = f"{namespace}_{_prom_name(name)}"
        lines.append(f"# HELP {full} {help_txt}")
        lines.append(f"# TYPE {full} {kind}")
        lines.append(f"{full}{_labels({**(labels or {}), **(extra_labels or {})})}"
                     f" {_num(value)}")

    # lifetime counters kept directly on the facade
    emit("requests_completed_total", "counter", s["completed"],
         "finished requests (engine lifetime)")
    emit("tokens_generated_total", "counter", s["total_tokens"],
         "generated tokens over finished requests")
    emit("preemptions_total", "counter", s["preemptions"],
         "recompute-preemptions")
    emit("engine_steps_total", "counter", s["engine_steps"], "engine steps")
    emit("prefill_chunks_total", "counter", s["prefill_chunks"],
         "prefill chunks executed")
    emit("decode_steps_total", "counter", s["decode_steps"],
         "batched decode steps executed")
    emit("requests_in_flight", "gauge", s["in_flight"],
         "submitted-but-unfinished requests")
    emit("prefix_hit_rate", "gauge", s["prefix_hit_rate"],
         "prefix-cache matched/looked-up tokens (lifetime)")
    # registry counters / gauges (scheduler refusals, re-plan triggers,
    # step-time EMA, ...)
    for name, c in sorted(tele.counters.items()):
        emit(f"{name}_total", "counter", c.value, f"telemetry counter {name}")
    for name, g in sorted(tele.gauges.items()):
        emit(name, "gauge", g.value, f"telemetry gauge {name}")
    # log-bucketed histograms -> cumulative le buckets + _sum/_count
    for name, h in sorted(tele.histograms.items()):
        full = f"{namespace}_{_prom_name(name)}"
        lines.append(f"# HELP {full} log-bucketed histogram {name}")
        lines.append(f"# TYPE {full} histogram")
        for le, cum in h.nonzero_buckets():
            l_ = _labels({**(labels or {}), "le": _num(le)})
            lines.append(f"{full}_bucket{l_} {cum}")
        inf = _labels({**(labels or {}), "le": "+Inf"})
        lines.append(f"{full}_bucket{inf} {h.count}")
        lines.append(f"{full}_sum{lab} {_num(h.total)}")
        lines.append(f"{full}_count{lab} {h.count}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+"
    r"([-+]?(?:[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?|Inf|NaN))$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="([^"]*)"')


def parse_prometheus_text(text: str) -> dict:
    """Minimal exposition-format parser for validation: returns
    ``{metric_name: [(labels_dict, value_str)]}``, raising ValueError on
    any line that is neither a comment nor a well-formed sample."""
    out: dict[str, list] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno} is not a valid Prometheus "
                             f"sample: {line!r}")
        name, rawlabels, value = m.groups()
        labels = dict(_LABEL_RE.findall(rawlabels or ""))
        out.setdefault(name, []).append((labels, value))
    if not out:
        raise ValueError("no samples found")
    return out


# ---------------------------------------------------------------------------
# periodic JSONL snapshots
# ---------------------------------------------------------------------------

class SnapshotWriter:
    """Periodic JSONL snapshot stream with atomic whole-file rename.

    ``maybe_write(metrics, now)`` is called once per engine step (cheap:
    one float compare when the cadence hasn't elapsed); every ``every_s``
    seconds of engine-clock time it appends one compact snapshot line —
    the windowed signal vector plus lifetime counters — and atomically
    rewrites the file, so the on-disk JSONL is always complete and
    parseable even if the process dies mid-run.
    """

    def __init__(self, path, every_s: float = 1.0):
        if every_s <= 0:
            raise ValueError(f"every_s must be > 0 (got {every_s})")
        self.path, self.every_s = os.fspath(path), every_s
        self._lines: list[str] = []
        self._last: Optional[float] = None

    @property
    def n_snapshots(self) -> int:
        return len(self._lines)

    def maybe_write(self, metrics, now: float) -> bool:
        if self._last is not None and now - self._last < self.every_s:
            return False
        self.write(metrics, now)
        return True

    def write(self, metrics, now: Optional[float] = None) -> None:
        """Unconditional snapshot (also used as the final flush; ``now``
        defaults to the newest engine-clock stamp the metrics saw)."""
        if now is not None:
            self._last = now
        self._lines.append(json.dumps(metrics.snapshot(now)))
        atomic_write_text(self.path, "\n".join(self._lines) + "\n")
