"""Per-request sampling for the continuous-batching engine (API v2).

Two pieces:

``SamplingParams``
    The per-request decode controls — temperature / top-k / top-p, an
    explicit seed, stop token ids and a logprobs flag — validated once at
    ``engine.submit`` so a malformed request never reaches a jitted step.
    ``temperature=0`` (the default) is exact greedy argmax.

``make_sampler(vocab)``
    A single *batched* sample function, fused as the tail of the jitted
    paged prefill/decode steps (runtime/steps.py): every batch row carries
    its own ``(temperature, top_k, top_p, seed, position)``, so one traced
    shape serves arbitrary per-request parameter mixes — greedy rows ride
    in the same step as nucleus-sampled rows, and idle slots are just
    greedy rows whose output the engine discards.  Fusing the sampler on
    device also means only a ``(B,)`` token vector (not ``(B, vocab)``
    logits) crosses back to the host per step.

Determinism is load-bearing, not cosmetic.  The sampling key for a token
is ``fold_in(PRNGKey(seed), absolute_position)`` — a pure function of the
request's seed and the token's absolute position in the sequence
(``len(prompt) + k`` for the k-th generated token), with **no** dependence
on batch row, engine step count, or scheduling history.  A
recompute-preempted request therefore re-generates bit-identical tokens
when its context is re-prefilled: the resumed request reaches the same
absolute positions with the same logits (greedy-parity infrastructure) and
the same keys.  That in turn is what keeps the prefix-cache hash chain
stable — a preempted ``share_prefix`` request can only re-match its own
retired blocks if the tokens it regenerates are identical to the ones it
committed.

Masking semantics (property-tested in tests/test_serving.py):
  * top-k keeps the k highest-scoring tokens (ties at the k-th value are
    all kept); ``top_k=0`` disables the filter;
  * top-p keeps the smallest probability-sorted prefix of the vocabulary
    whose cumulative mass reaches ``top_p`` — the kept mass is always
    >= top_p and the candidate set is never empty (the argmax survives
    any ``top_p > 0``);
  * ``temperature == 0`` bypasses both masks and the Gumbel draw entirely
    and lowers to ``argmax`` over the raw float32 logits, bit-for-bit the
    greedy path the serving goldens pin.
"""
from __future__ import annotations

import dataclasses
import numbers
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["SamplingParams", "GREEDY", "make_sampler",
           "apply_top_k", "apply_top_p"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode controls, validated at ``engine.submit``.

    temperature    0.0 => exact greedy argmax (top_k/top_p/seed ignored);
                   > 0 scales logits before the top-k/top-p masks.
    top_k          keep only the k highest logits (0 disables).
    top_p          nucleus sampling: keep the smallest probability-sorted
                   set with cumulative mass >= top_p (1.0 disables).
    seed           RNG seed for this request's token stream.  ``None`` lets
                   the engine derive one from the request id — still fully
                   deterministic (and preemption-stable), but distinct
                   requests get distinct streams by default.
    stop_token_ids sampling any of these ids finishes the request with
                   ``finish_reason="stop"``.  The stop token IS the last
                   entry of ``RequestOutput.token_ids`` — it was genuinely
                   sampled, and keeping it makes recompute-preemption and
                   prefix-cache commits see the true context.
    stop           stop *strings*, matched over decoded text.  The engine
                   itself never looks at these — it has no detokenizer and
                   stays token-level — the frontend boundary
                   (serving/detok.StopStringMatcher, used by the cluster
                   HTTP/SSE server) matches them incrementally and cancels
                   the request, trimming the matched text.  Carried here so
                   one params object describes the whole request and rides
                   the wire protocol unchanged.
    logprobs       when True the ``RequestOutput`` carries one logprob per
                   generated token, under the distribution it was actually
                   sampled from (post-mask, post-temperature; the raw
                   softmax for greedy rows).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop_token_ids: tuple = ()
    stop: tuple = ()
    logprobs: bool = False

    @property
    def is_greedy(self) -> bool:
        """True when this request lowers to exact argmax (temperature 0).
        Telemetry uses it to annotate decode steps with their batch
        composition (greedy vs stochastic rows) — the all-greedy case is
        the fast path that skips the vocab sorts and Gumbel draw."""
        return self.temperature == 0

    def validate(self, vocab: Optional[int] = None) -> None:
        """Raise ValueError on any parameter a jitted step can't honor.
        numbers.Integral/Real so numpy scalars (np.int32 stop ids sliced
        from a prompt array, np.float32 temperature) are accepted."""
        t = self.temperature
        if not isinstance(t, numbers.Real) or t != t or t < 0 \
                or t == float("inf"):
            raise ValueError(f"temperature must be a finite float >= 0 "
                             f"(got {t!r})")
        if not isinstance(self.top_k, numbers.Integral) or self.top_k < 0:
            raise ValueError(f"top_k must be an int >= 0, 0 disabling the "
                             f"filter (got {self.top_k!r})")
        if vocab is not None and self.top_k > vocab:
            raise ValueError(f"top_k ({self.top_k}) exceeds the vocabulary "
                             f"({vocab})")
        p = self.top_p
        if not isinstance(p, numbers.Real) or not 0.0 < p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1] (got {p!r})")
        if self.seed is not None \
                and not (isinstance(self.seed, numbers.Integral)
                         and 0 <= self.seed < 2 ** 32):
            raise ValueError(f"seed must be None or an int in [0, 2**32) "
                             f"(got {self.seed!r})")
        for s in self.stop_token_ids:
            if not isinstance(s, numbers.Integral):
                raise ValueError(f"stop token id {s!r} is not an integer")
            if s < 0 or (vocab is not None and s >= vocab):
                raise ValueError(f"stop token id {int(s)} outside the "
                                 f"vocabulary [0, {vocab})")
        for s in self.stop:
            if not isinstance(s, str) or not s:
                raise ValueError(f"stop strings must be non-empty strings "
                                 f"(got {s!r})")


GREEDY = SamplingParams()


def apply_top_k(logits, top_k):
    """Mask all but the per-row ``top_k`` highest logits to -inf.

    ``logits`` (B, V) float; ``top_k`` (B,) int32, 0 = keep everything.
    Ties at the k-th value are all kept (the mask is a value threshold,
    not an index cut), so the candidate set never loses probability mass
    to an arbitrary tiebreak.
    """
    v = logits.shape[-1]
    k = jnp.where(top_k > 0, top_k, v)
    desc = -jnp.sort(-logits, axis=-1)
    kth = jnp.take_along_axis(desc, jnp.clip(k - 1, 0, v - 1)[:, None],
                              axis=-1)
    return jnp.where(logits >= kth, logits, -jnp.inf)


def apply_top_p(logits, top_p):
    """Nucleus mask: per row, keep the smallest probability-sorted prefix
    of the vocabulary whose cumulative softmax mass reaches ``top_p``.

    ``logits`` (B, V) float (may already hold -inf from top-k); ``top_p``
    (B,) float in (0, 1].  Kept mass is always >= top_p; the set is never
    empty (the first sorted token has zero exclusive mass, which is
    < top_p for any top_p > 0).  Ties at the threshold probability are
    all kept.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    desc = -jnp.sort(-probs, axis=-1)
    cum = jnp.cumsum(desc, axis=-1)
    # keep sorted slot j iff the mass strictly before it is < top_p
    keep = (cum - desc) < top_p[:, None]
    thr = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1, keepdims=True)
    return jnp.where(probs >= thr, logits, -jnp.inf)


def make_sampler(vocab: int):
    """-> sample(logits (B, V'), temperature (B,), top_k (B,) i32,
    top_p (B,), seeds (B,) u32, positions (B,) i32)
    -> (tokens (B,) i32, logprobs (B,) f32)

    Pure function meant to be closed over by the jitted paged steps
    (``runtime.steps.make_paged_{prefill,decode}_step(..., sampler=...)``).
    Rows with ``temperature == 0`` lower exactly to
    ``argmax(float32(logits[:vocab]))`` — bit parity with the greedy
    goldens; stochastic rows apply top-k then top-p and draw one
    Gumbel-argmax sample with key
    ``fold_in(PRNGKey(seed), position)`` (``positions`` is the absolute
    sequence position of the token being *produced*).  The returned
    logprob is the chosen token's log-probability under the distribution
    it was sampled from.
    """
    def sample(logits, temperature, top_k, top_p, seeds, positions):
        lg = logits[:, :vocab].astype(jnp.float32)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        stochastic = temperature > 0.0

        def greedy_only(_):
            logp = jnp.take_along_axis(jax.nn.log_softmax(lg, axis=-1),
                                       greedy[:, None], axis=-1)[:, 0]
            return greedy, logp

        def mixed(_):
            # greedy rows run the stochastic math on t=1 (result discarded
            # via the final where) — dividing by ~0 would poison softmax
            # with NaNs
            t = jnp.where(stochastic, temperature, 1.0).astype(jnp.float32)
            masked = apply_top_p(apply_top_k(lg / t[:, None], top_k), top_p)

            def draw(seed, pos):
                key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
                return jax.random.gumbel(key, (vocab,), jnp.float32)

            noise = jax.vmap(draw)(seeds, positions)
            sampled = jnp.argmax(masked + noise, axis=-1).astype(jnp.int32)
            tok = jnp.where(stochastic, sampled, greedy)
            dist = jnp.where(stochastic[:, None],
                             jax.nn.log_softmax(masked, axis=-1),
                             jax.nn.log_softmax(lg, axis=-1))
            logp = jnp.take_along_axis(dist, tok[:, None], axis=-1)[:, 0]
            return tok, logp

        # an all-greedy batch (the default workload) must not pay the two
        # full-vocab sorts + Gumbel draw every step just to discard them —
        # cond executes one branch, and greedy rows take identical values
        # through either (the mixed branch `where`s them back to argmax)
        return jax.lax.cond(jnp.any(stochastic), mixed, greedy_only, None)

    return sample
