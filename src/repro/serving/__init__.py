"""Continuous-batching serving subsystem.

Layers (bottom up):
  paged_cache.py  block-pool KV cache: free-list allocator + per-request
                  block tables over the device pools from
                  models/transformer.init_paged_cache, laid out with the
                  ASA plan's paged_cache_specs sharding.
  scheduler.py    admission scheduler: FCFS within priority classes,
                  max-tokens-in-flight budgeting, preemption victim choice.
  metrics.py      per-request TTFT/TPOT + queue depth / slot occupancy /
                  tokens-per-second counters, emitted as JSON.
  engine.py       the continuous-batching engine: per-slot decode positions,
                  admission into freed slots every step, chunked prefill
                  interleaved with decode.

The wave-synchronized Server (runtime/server.py) remains as the comparison
baseline and as the path for architectures whose caches are not
length-indexed (SSM / cross-attention states).
"""
from repro.serving.engine import ContinuousBatchingEngine, Request
from repro.serving.metrics import ServingMetrics
from repro.serving.paged_cache import BlockAllocator, PagedKVCache
from repro.serving.scheduler import RequestScheduler

__all__ = ["ContinuousBatchingEngine", "Request", "ServingMetrics",
           "BlockAllocator", "PagedKVCache", "RequestScheduler"]
