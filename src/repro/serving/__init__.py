"""Continuous-batching serving subsystem.

Layers (bottom up):
  paged_cache.py    block-pool KV cache: refcounted free-list allocator +
                    per-request block tables over the device pools from
                    models/transformer.init_paged_cache, plus cross-request
                    shared-prefix block reuse (content-hash chain index
                    over full blocks, LRU pool of unreferenced-but-cached
                    blocks evicted before OOM).
  cache_manager.py  the unified cache manager: the paged block pools plus
                    slot-indexed state pools (mamba2 conv/SSM state,
                    cross-attention K/V — one row per engine slot + a
                    reserved null row), behind one interface and one
                    device pytree laid out with the ASA plan's
                    paged_cache_specs sharding.
  scheduler.py      admission scheduler: FCFS within priority classes,
                    max-tokens-in-flight budgeting, preemption victim choice.
  metrics.py        per-request TTFT/TPOT + queue depth / slot occupancy /
                    tokens-per-second counters, emitted as JSON.
  engine.py         the continuous-batching engine: per-slot decode
                    positions, admission into freed slots every step,
                    chunked prefill interleaved with decode; serves every
                    architecture in the zoo — attention-only, MoE, MLA
                    latent attention, pure-SSM, hybrid, cross-attention,
                    zamba2's weight-shared block and whisper's
                    encoder-decoder.  ``share_prefix=True`` (purely paged
                    archs only — slot-state rows are per-request and
                    excluded) reuses cached blocks across requests with a
                    shared prompt prefix and starts prefill at the matched
                    boundary.

The wave-synchronized Server was retired: runtime/server.py is now a thin
deprecation shim that delegates to this engine (greedy parity with the
pre-shim wave implementation is pinned in tests/goldens_serving.json).
"""
from repro.serving.cache_manager import (PAGEABLE_KINDS, SLOT_STATE_KINDS,
                                         UnifiedCacheManager)
from repro.serving.engine import ContinuousBatchingEngine, Request
from repro.serving.metrics import ServingMetrics
from repro.serving.paged_cache import BlockAllocator, PagedKVCache
from repro.serving.scheduler import RequestScheduler

__all__ = ["ContinuousBatchingEngine", "Request", "ServingMetrics",
           "BlockAllocator", "PagedKVCache", "UnifiedCacheManager",
           "RequestScheduler", "PAGEABLE_KINDS", "SLOT_STATE_KINDS"]
