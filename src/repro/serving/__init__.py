"""Continuous-batching serving subsystem — Generation API v2.

The public surface is three typed objects plus the engine:

  ``SamplingParams``  per-request decode controls (temperature / top_k /
                      top_p / seed / stop_token_ids / logprobs), validated
                      at ``submit``; ``temperature=0`` (default) is exact
                      greedy argmax.
  ``Request``         input-only: id, prompt, max_new_tokens, priority,
                      sampling, optional modality frontend.  The engine
                      never mutates it.
  ``RequestOutput``   the result: ``token_ids``, ``finish_reason``
                      ("stop" | "length"), optional per-token ``logprobs``,
                      TTFT/TPOT latency joined from ``ServingMetrics``.
  ``ContinuousBatchingEngine``
                      ``submit()`` + ``step()`` for manual control,
                      ``generate(requests)`` submit-and-drain,
                      ``stream(requests)`` yielding (request_id, token)
                      pairs, and an ``on_token`` callback.

Migrating from v1: results used to leak out by mutating
``Request.out_tokens`` in place and setting ``Request.done``; read
``RequestOutput.token_ids`` / ``finish_reason`` from ``engine.completed``
(or the return of ``generate()``) instead.  ``Request`` no longer carries
``out_tokens`` / ``done`` at all, so a finished Request object may be
resubmitted verbatim.  Greedy decode needs no changes beyond that: the
default ``SamplingParams()`` is temperature-0 argmax, token-for-token
identical to v1.

Layers (bottom up):
  paged_cache.py    block-pool KV cache: refcounted free-list allocator +
                    per-request block tables over the device pools from
                    models/transformer.init_paged_cache, plus cross-request
                    shared-prefix block reuse (content-hash chain index
                    over full blocks, LRU pool of unreferenced-but-cached
                    blocks evicted before OOM).
  cache_manager.py  the unified cache manager: the paged block pools plus
                    slot-indexed state pools (mamba2 conv/SSM state,
                    cross-attention K/V — one row per engine slot + a
                    reserved null row), behind one interface and one
                    device pytree laid out with the ASA plan's
                    paged_cache_specs sharding.
  scheduler.py      admission scheduler: FCFS within priority classes,
                    max-tokens-in-flight budgeting, preemption victim choice.
  sampling.py       SamplingParams + the batched per-slot sampler fused
                    into the jitted paged steps: per-row temperature /
                    top-k / top-p / seed arrays, Gumbel categorical on
                    device, keys derived as fold_in(seed, absolute
                    position) so recompute-preemption regenerates
                    identical tokens (which keeps prefix-cache hash
                    chains re-matchable).
  telemetry.py      metric primitives: counters, gauges, log-bucketed
                    histograms (O(1) record, fixed memory, exact p50/p95/
                    p99 within the bucket growth factor) and sliding
                    windows over caller-supplied engine-clock timestamps.
  tracing.py        ChromeTracer: span-based tracing to Chrome trace-event
                    JSON (load in Perfetto / chrome://tracing) — one track
                    per engine phase plus async per-request lifecycle
                    spans; zero cost when the engine runs without one.
  export.py         exporters: Prometheus text exposition of the whole
                    registry, atomic file writes, and the periodic JSONL
                    snapshot writer that streams the windowed signal
                    vector.
  metrics.py        ServingMetrics — the facade over telemetry.py:
                    per-request TTFT/TPOT percentiles, per-phase duration
                    histograms, windowed workload signals
                    (``window_signals()`` — the adaptive scheduler's
                    input), emitted as JSON; one injectable engine clock
                    stamps every lifecycle point.
  engine.py         the continuous-batching engine: per-slot decode
                    positions, admission into freed slots every step,
                    chunked prefill interleaved with decode; serves every
                    architecture in the zoo — attention-only, MoE, MLA
                    latent attention, pure-SSM, hybrid, cross-attention,
                    zamba2's weight-shared block and whisper's
                    encoder-decoder.  ``share_prefix=True`` (purely paged
                    archs only — slot-state rows are per-request and
                    excluded) reuses cached blocks across requests with a
                    shared prompt prefix and starts prefill at the matched
                    boundary.

  prefix_hash.py    the content-hash chain-key scheme (shared with the
                    cluster router's prefix-affinity index — one function,
                    two consumers, so router keys == cache keys by
                    construction).
  detok.py          the detokenization boundary: token-id -> text pieces
                    plus incremental stop-*string* matching with buffered
                    emission (used by the cluster HTTP/SSE frontend; the
                    engine itself stays token-level).
  cluster/          the multi-process serving cluster: engine replica
                    workers behind an NDJSON wire protocol, the
                    prefix-affinity router with replica health, and the
                    stdlib HTTP/SSE frontend (launched via
                    repro.launch.serve_cluster).  Imported explicitly as
                    ``repro.serving.cluster`` — not re-exported here.

The wave-synchronized Server was retired: runtime/server.py is now a thin
deprecation shim that delegates to this engine (greedy parity with the
pre-shim wave implementation is pinned in tests/goldens_serving.json).
"""
from repro.serving.cache_manager import (PAGEABLE_KINDS, SLOT_STATE_KINDS,
                                         UnifiedCacheManager)
from repro.serving.detok import (Detokenizer, StopStringMatcher,
                                 default_detokenizer)
from repro.serving.engine import (ContinuousBatchingEngine, Request,
                                  RequestOutput)
from repro.serving.prefix_hash import chain_keys
from repro.serving.export import (SnapshotWriter, atomic_write_text,
                                  prometheus_text)
from repro.serving.metrics import ServingMetrics
from repro.serving.paged_cache import BlockAllocator, PagedKVCache
from repro.serving.sampling import GREEDY, SamplingParams
from repro.serving.scheduler import RequestScheduler
from repro.serving.telemetry import (Counter, Gauge, LogHistogram,
                                     SlidingWindow, Telemetry)
from repro.serving.tracing import ChromeTracer, validate_chrome_trace

__all__ = ["ContinuousBatchingEngine", "Request", "RequestOutput",
           "SamplingParams", "GREEDY", "ServingMetrics", "BlockAllocator",
           "PagedKVCache", "UnifiedCacheManager", "RequestScheduler",
           "PAGEABLE_KINDS", "SLOT_STATE_KINDS",
           "Counter", "Gauge", "LogHistogram", "SlidingWindow", "Telemetry",
           "ChromeTracer", "validate_chrome_trace",
           "SnapshotWriter", "atomic_write_text", "prometheus_text",
           "Detokenizer", "StopStringMatcher", "default_detokenizer",
           "chain_keys"]
