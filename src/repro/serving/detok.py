"""Detokenization boundary: token-id streams -> text, and incremental
stop-*string* matching with buffered emission.

The engine is token-level end to end — ``SamplingParams.stop_token_ids``
finishes a request the step a stop id is sampled, because the check is a
set lookup on the sampled id.  Stop *strings* are different: a stop string
may span several tokens, start mid-token, or share a prefix with text the
client should receive, so it can only be matched over *decoded text*.
That matching lives at the frontend boundary (serving/cluster/frontend.py
for the HTTP/SSE server), built from the two pieces here:

``Detokenizer``
    Anything with ``decode(token_id) -> str``.  The repo carries no real
    tokenizer vocabulary, so ``default_detokenizer()`` maps every id to a
    deterministic word-like piece (``"t<id> "``) — enough for stop-string
    semantics, tests and the CI smoke to be exact; a deployment drops in
    its tokenizer by implementing ``decode``.

``StopStringMatcher``
    Incremental matcher with buffered emission.  ``feed(text)`` returns
    the longest prefix of the accumulated stream that is *safe to emit*:
    text that can no longer become part of a stop-string match.  The
    invariant (pinned in tests/test_cluster.py): concatenated emissions
    never contain a stop string and never end in a nonempty proper prefix
    of one — so an SSE client never sees a partial stop-string suffix
    that a later token would have completed.  On a match, emission stops
    at the character before the stop string (the matched text is trimmed)
    and ``matched`` records which stop string fired.  ``flush()`` releases
    the held-back tail when the stream ends without a match.
"""
from __future__ import annotations

from typing import Optional, Protocol, Sequence


class Detokenizer(Protocol):
    def decode(self, token_id: int) -> str:
        """Text piece for one token id."""
        ...


class DefaultDetokenizer:
    """Deterministic id -> word-like piece mapping (``"t<id> "``): the
    stand-in for a real tokenizer vocabulary.  A stop string for token 7
    is ``"t7 "``; multi-token stop strings (``"t7 t9 "``) exercise the
    cross-token matching path."""

    def decode(self, token_id: int) -> str:
        return f"t{int(token_id)} "


def default_detokenizer() -> DefaultDetokenizer:
    return DefaultDetokenizer()


class StopStringMatcher:
    """Incremental stop-string matching with buffered emission (see the
    module docstring for the emission invariant)."""

    def __init__(self, stops: Sequence[str]):
        for s in stops:
            if not isinstance(s, str) or not s:
                raise ValueError(f"stop strings must be non-empty strings "
                                 f"(got {s!r})")
        self._stops = tuple(stops)
        self._buf = ""
        #: the stop string that fired, or None while the stream is live
        self.matched: Optional[str] = None

    @property
    def held(self) -> str:
        """Text currently withheld (a prefix of some stop string)."""
        return self._buf

    def _max_hold(self) -> int:
        """Length of the longest buffer suffix that is a nonempty proper
        prefix of any stop string — the text that must be withheld because
        a later token could complete a match."""
        hold = 0
        for s in self._stops:
            top = min(len(s) - 1, len(self._buf))
            for n in range(top, hold, -1):
                if self._buf.endswith(s[:n]):
                    hold = n
                    break
        return hold

    def feed(self, text: str) -> str:
        """Accumulate ``text``; return the text now safe to emit.  After a
        match every subsequent feed returns ""."""
        if self.matched is not None:
            return ""
        self._buf += text
        # earliest match across all stop strings wins (ties: the one
        # starting first; same start: the first in the stops tuple)
        best: Optional[tuple[int, str]] = None
        for s in self._stops:
            i = self._buf.find(s)
            if i != -1 and (best is None or i < best[0]):
                best = (i, s)
        if best is not None:
            i, s = best
            self.matched = s
            out, self._buf = self._buf[:i], ""
            return out
        hold = self._max_hold()
        cut = len(self._buf) - hold
        out, self._buf = self._buf[:cut], self._buf[cut:]
        return out

    def flush(self) -> str:
        """Release the withheld tail — call when the stream ended without
        a stop match (e.g. finish_reason "length")."""
        out, self._buf = self._buf, ""
        return out
