"""Continuous-batching engine: per-slot decode positions over a unified
serving cache (paged KV / latent block pools + slot-indexed state pools),
admission into freed slots every step, chunked prefill interleaved with
decode.  This is the ONLY decode path — the wave-synchronized Server was
retired to a compatibility shim delegating here (runtime/server.py).

Every architecture in the zoo is served.  Each batch row carries its own
position vector, block table and slot-state row, so a finished request's
slot (and its cache blocks) are reused on the very next step, and a long
prompt is prefilled ``prefill_chunk`` tokens at a time between decode steps
instead of blocking them.  Per-family cache routing
(serving/cache_manager.py):
  * attention-family KV — paged block pools, incl. zamba2's weight-shared
    block (one pool per application via the repeat-stacked axis) and MLA's
    latent (c_kv, k_rope) rows;
  * mamba2 state — slot-state rows, carried as h0 across prefill chunks;
  * cross-attn / whisper encoder K/V — slot-state rows written once at
    admission (the whisper encoder runs there, never per step).

Engine step = admit -> one prefill chunk -> one decode step:
  1. every free slot pulls from the RequestScheduler (priority/FCFS +
     max-tokens budget, footprints capped at max_len) if its prompt's
     blocks fit the pool; admission resets the slot's state-pool rows
     (make_slot_admit_step).  With ``share_prefix`` (purely paged archs
     only — see serving/cache_manager.py) admission first matches the
     longest cached full-block prefix of the request context: matched
     blocks are refcount-shared, prefill starts at the matched boundary
     (TTFT skips the shared system prompt / few-shot prefix), and full
     blocks this request writes are committed back to the content index
     for later requests;
  2. the oldest prefilling request advances one chunk; finishing the prompt
     samples its first token (TTFT);
  3. all decoding slots advance one token.  A slot needing a new block under
     cache pressure first evicts unreferenced prefix-cache blocks, then
     preempts the request with the largest resident cache footprint
     (recompute-style: refcounts dropped, request requeued with
     prompt+generated as its new prefill — slot-state needs no checkpoint:
     re-admission re-zeroes the row, and a sharing request re-matches its
     own retired blocks).

Greedy decode is token-for-token identical to the retired wave Server: the
paged attention paths mask exactly the same prefix (layers._paged_sdpa,
mla.mla_paged_attention) and the slot-state path runs the same recurrence
on gathered rows.  tests/test_serving.py pins this against golden token
sequences frozen from the pre-shim wave implementation, for every arch
family, including under forced preemption and on a multi-host (data=4,
model=2) mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.asa import AdaptiveScheduler
from repro.launch.mesh import mesh_shape_of
from repro.runtime import steps as ST
from repro.serving.cache_manager import UnifiedCacheManager, check_servable
from repro.serving.metrics import ServingMetrics
from repro.serving.paged_cache import PagedCacheConfig, blocks_for
from repro.serving.scheduler import RequestScheduler


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    priority: int = 0                # lower = more urgent
    # per-request modality input, consumed ONCE at admission: vision patch
    # embeddings (1, n_img_tokens, d_model) -> cross-attn K/V rows, or audio
    # frame embeddings (1, enc_len, d_model) -> encoder pass -> wdec cross
    # K/V rows (transformer.admit_slot)
    frontend: Optional[np.ndarray] = None
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    _sched_seq: Optional[int] = None   # set by RequestScheduler (FCFS order)
    _charged_footprint: Optional[int] = None   # budget charge at admission

    def context(self) -> np.ndarray:
        """prompt + generated-so-far — what a (re-)prefill must cover."""
        if not self.out_tokens:
            return np.asarray(self.prompt, np.int32)
        return np.concatenate([np.asarray(self.prompt, np.int32),
                               np.asarray(self.out_tokens, np.int32)])


@dataclasses.dataclass
class _Slot:
    idx: int = 0                     # engine slot index == state-pool row
    req: Optional[Request] = None
    state: str = "idle"              # idle | prefill | decode
    pos: int = 0                     # tokens currently resident in the cache
    prefill_pos: int = 0             # prompt tokens already prefilled

    @property
    def busy(self) -> bool:
        return self.req is not None


class ContinuousBatchingEngine:
    def __init__(self, arch: ArchConfig, params, mesh, *,
                 slots: int = 4, max_len: int = 512,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefill_chunk: int = 64,
                 share_prefix: bool = False,
                 scheduler: Optional[RequestScheduler] = None,
                 asa: Optional[AdaptiveScheduler] = None,
                 metrics: Optional[ServingMetrics] = None):
        check_servable(arch)           # precise error for excluded archs
        self.arch, self.mesh = arch, mesh
        self.max_len, self.prefill_chunk = max_len, prefill_chunk
        self.share_prefix = share_prefix
        max_blocks_per_seq = blocks_for(max_len, block_size)
        if num_blocks is None:
            num_blocks = slots * max_blocks_per_seq + 1   # +1: null block
        shape = ShapeSpec("serve", max_len, slots, "decode")
        sched = asa or AdaptiveScheduler(faithful=False)
        self.plan = sched.plan(arch, shape, mesh_shape_of(mesh))
        cdtype = jnp.float32 if arch.dtype == "float32" else jnp.bfloat16
        self.cache = UnifiedCacheManager(
            arch, PagedCacheConfig(block_size, num_blocks, max_blocks_per_seq,
                                   slots=slots, share_prefix=share_prefix),
            dtype=cdtype, mesh=mesh, specs=self.plan.paged_cache_specs())
        self.params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 self.plan.param_specs()))
        self._prefill = jax.jit(ST.make_paged_prefill_step(arch),
                                donate_argnums=(1,))
        self._decode = jax.jit(ST.make_paged_decode_step(arch),
                               donate_argnums=(1,))
        self._admit_slot_state = jax.jit(
            ST.make_slot_admit_step(arch), donate_argnums=(1,)) \
            if self.cache.has_slot_state else None
        self.scheduler = scheduler or RequestScheduler()
        # the engine truncates every request to max_len, so the token budget
        # must charge capped footprints — uncapped, a long-prompt request
        # over-charges and stalls admission forever.  The engine OWNS the
        # cap (unconditional overwrite): it mirrors this engine's
        # truncation, and a stale cap from a previous engine with a
        # different max_len would mis-charge the budget
        self.scheduler.footprint_cap = self.max_len
        self.metrics = metrics or ServingMetrics()
        self.slots = [_Slot(idx=i) for i in range(slots)]
        self.completed: list[Request] = []
        self._active_ids: set[int] = set()   # queued or running request ids

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: Optional[float] = None) -> None:
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.id} has an empty prompt")
        if req.max_new_tokens < 1:
            # a request that may not generate anything would still burn a
            # slot and a full prefill, and the prefill path unconditionally
            # samples its first token — reject instead of emitting one
            raise ValueError(f"request {req.id}: max_new_tokens must be "
                             f">= 1 (got {req.max_new_tokens})")
        if req.done or req.out_tokens or req._sched_seq is not None:
            # a recycled Request object would re-prefill its old output as
            # context and jump the FCFS queue with its stale arrival seq
            raise ValueError(
                f"request {req.id} has already been served (done={req.done}, "
                f"{len(req.out_tokens)} generated tokens) — submit a fresh "
                f"Request object")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(req.prompt)}) >= max_len")
        if req.id in self._active_ids:
            # block tables are keyed by request id — a duplicate would share
            # (and corrupt) the live request's table
            raise ValueError(f"request id {req.id} is already in flight")
        if blocks_for(self._target_total(req), self.cache.cfg.block_size) \
                > self.cache.cfg.num_blocks - 1:
            raise ValueError(f"request {req.id} can never fit the block pool")
        self.scheduler.submit(req)       # may raise (token budget) — only a
        self._active_ids.add(req.id)     # queued request claims its id
        self.metrics.on_submit(req.id, now)

    def _target_total(self, req: Request) -> int:
        # same self-truncation as the wave Server's max_len loop bound
        return min(len(req.prompt) + req.max_new_tokens, self.max_len)

    # ------------------------------------------------------------------
    def _sample(self, logits) -> np.ndarray:
        logits = np.asarray(logits, np.float32)[:, : self.arch.vocab]
        return np.argmax(logits, axis=-1).astype(np.int32)

    def _finish(self, slot: _Slot) -> None:
        req = slot.req
        req.done = True
        self.cache.release(req.id)
        self.scheduler.on_finish(req)
        self.metrics.on_finish(req.id, len(req.out_tokens))
        self._active_ids.discard(req.id)
        self.completed.append(req)
        slot.req, slot.state, slot.pos, slot.prefill_pos = None, "idle", 0, 0

    def _preempt(self, slot: _Slot) -> None:
        req = slot.req
        self.cache.release(req.id)
        self.scheduler.preempt(req)
        self.metrics.on_preempt(req.id)
        slot.req, slot.state, slot.pos, slot.prefill_pos = None, "idle", 0, 0

    # -- phase 1: admission --------------------------------------------
    def _admit(self) -> None:
        for slot in self.slots:
            if slot.busy:
                continue
            head = self.scheduler.peek()
            if head is None:
                break
            ctx = head.context()
            if not self.cache.can_fit_request(ctx):
                if not any(s.busy for s in self.slots):
                    raise RuntimeError(
                        f"request {head.id} cannot fit an empty pool")
                break                      # wait for running requests to free
            req = self.scheduler.next_admission()
            if req is None:                # token budget exhausted
                break
            # longest cached full-block prefix: refcounts bump, the table
            # starts populated, and prefill starts at the matched boundary
            # (no-op with share_prefix off)
            n_cached = self.cache.assign_prefix(req.id, ctx)
            ok = self.cache.reserve(req.id, len(ctx))
            assert ok, "can_fit_request passed but reserve failed"
            slot.req, slot.state = req, "prefill"
            slot.pos, slot.prefill_pos = n_cached, n_cached
            if self.share_prefix:
                self.metrics.on_prefix_match(n_cached, len(ctx))
            if self._admit_slot_state is not None:
                # reset this slot's state-pool rows (zero mamba2 state;
                # cross K/V from the request's frontend, computed once)
                args = (self.params, self.cache.pools,
                        jnp.asarray(slot.idx, jnp.int32))
                if req.frontend is not None:
                    args += (jnp.asarray(req.frontend),)
                self.cache.pools = self._admit_slot_state(*args)

    # -- phase 2: one chunk of prefill ---------------------------------
    def _prefill_chunk(self) -> None:
        # oldest request first (scheduler seq), not lowest slot index — a
        # newer request admitted into a freed lower slot must not starve an
        # older mid-prefill request's TTFT
        prefilling = [s for s in self.slots if s.state == "prefill"]
        if not prefilling:
            return
        slot = min(prefilling, key=lambda s: s.req._sched_seq)
        req = slot.req
        ctx = req.context()
        chunk = ctx[slot.prefill_pos: slot.prefill_pos + self.prefill_chunk]
        n_new = len(chunk)
        if n_new < self.prefill_chunk:      # pad: the step traces one shape
            chunk = np.concatenate(
                [chunk, np.zeros(self.prefill_chunk - n_new, np.int32)])
        table = self.cache.table_array([req.id])
        logits, self.cache.pools = self._prefill(
            self.params, self.cache.pools, jnp.asarray(chunk[None, :]),
            jnp.asarray([slot.prefill_pos], jnp.int32), jnp.asarray(table),
            jnp.asarray([n_new], jnp.int32),
            jnp.asarray([slot.idx], jnp.int32))
        slot.prefill_pos += n_new
        slot.pos = slot.prefill_pos
        self.cache.commit_prefix(req.id, ctx, slot.prefill_pos)
        self.metrics.prefill_chunks += 1
        if slot.prefill_pos == len(ctx):
            nxt = self._sample(logits)
            req.out_tokens.append(int(nxt[0]))
            self.metrics.on_first_token(req.id)
            slot.state = "decode"
            if len(ctx) + 1 >= self._target_total(req):
                self._finish(slot)

    # -- phase 3: one decode step for every decoding slot --------------
    def _decode_step(self) -> None:
        decoding = [s for s in self.slots if s.state == "decode"]
        if not decoding:
            return
        # grow block tables; preempt the longest-running request on pressure
        for slot in list(decoding):
            if slot.req is None:       # already preempted as an earlier victim
                continue
            while not self.cache.reserve(slot.req.id, slot.pos + 1):
                victims = [s.req for s in self.slots if s.busy]
                victim = self.scheduler.pick_preemption_victim(victims)
                vslot = next(s for s in self.slots if s.req is victim)
                self._preempt(vslot)
                if vslot in decoding:
                    decoding.remove(vslot)
                if slot.req is None:       # we preempted ourselves
                    break
        decoding = [s for s in decoding if s.req is not None]
        if not decoding:
            return
        B = len(self.slots)
        last = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        rids: list[Optional[int]] = [None] * B
        for i, s in enumerate(self.slots):
            if s.state == "decode":
                last[i, 0] = s.req.out_tokens[-1]
                pos[i] = s.pos
                rids[i] = s.req.id
        table = self.cache.table_array(rids)
        # idle/prefilling rows scatter their slot-state into the null row;
        # active rows use s.idx (NOT list position — admission/prefill
        # reset/advance the pool row at idx, and the two may diverge)
        sids = self.cache.slot_ids_array(
            [s.idx if s.state == "decode" else None for s in self.slots])
        logits, self.cache.pools = self._decode(
            self.params, self.cache.pools, jnp.asarray(last),
            jnp.asarray(pos), jnp.asarray(table), jnp.asarray(sids))
        nxt = self._sample(logits)
        self.metrics.decode_steps += 1
        for i, s in enumerate(self.slots):
            if s.state != "decode":
                continue
            s.pos += 1
            s.req.out_tokens.append(int(nxt[i]))
            if self.share_prefix and s.pos % self.cache.cfg.block_size == 0:
                # a block just filled: generated tokens extend the hash
                # chain too, so a preempted request re-matches its own
                # retired blocks at re-admission.  Gated on the boundary —
                # rebuilding context() every token would be O(n^2) per
                # request in the decode hot loop
                self.cache.commit_prefix(s.req.id, s.req.context(), s.pos)
            if len(s.req.prompt) + len(s.req.out_tokens) \
                    >= self._target_total(s.req):
                self._finish(s)

    # ------------------------------------------------------------------
    def step(self) -> None:
        self._admit()
        self._prefill_chunk()
        self._decode_step()
        self.metrics.on_step(self.scheduler.queue_depth,
                             sum(s.busy for s in self.slots), len(self.slots),
                             block_utilization=self.cache.utilization)

    @property
    def has_work(self) -> bool:
        return self.scheduler.queue_depth > 0 or any(s.busy for s in self.slots)

    def _progress_marker(self) -> tuple:
        return (self.metrics.prefill_chunks, self.metrics.decode_steps,
                self.metrics.preemptions, len(self.completed),
                self.scheduler.queue_depth,
                sum(s.busy for s in self.slots))

    def run_until_drained(self, *, max_idle_steps: int = 1000) -> float:
        """Step until no queued or running work remains.  Raises after
        ``max_idle_steps`` consecutive steps that neither prefill, decode,
        preempt, finish, admit nor drain anything — a stuck engine (e.g. a
        token budget that can never re-admit) must fail loudly instead of
        spinning forever."""
        t0 = time.perf_counter()
        idle, marker = 0, self._progress_marker()
        while self.has_work:
            self.step()
            now = self._progress_marker()
            idle = idle + 1 if now == marker else 0
            marker = now
            if idle >= max_idle_steps:
                raise RuntimeError(
                    f"engine made no progress for {idle} consecutive steps "
                    f"({self.scheduler.queue_depth} queued, "
                    f"{sum(s.busy for s in self.slots)} busy slots) — "
                    f"admission is wedged")
        return time.perf_counter() - t0
