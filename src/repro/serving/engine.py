"""Continuous-batching engine: per-slot decode positions over a unified
serving cache (paged KV / latent block pools + slot-indexed state pools),
admission into freed slots every step, chunked prefill interleaved with
decode.  This is the ONLY decode path — the wave-synchronized Server was
retired to a compatibility shim delegating here (runtime/server.py).

Generation API v2: requests and results are two typed objects.

  ``Request``        input-only — id, prompt, ``SamplingParams``, priority,
                     optional frontend.  The engine NEVER mutates it;
                     generation state lives in an internal per-request
                     record, so a finished Request may be resubmitted
                     verbatim.
  ``RequestOutput``  what comes back — token ids, ``finish_reason``
                     ("stop" on a stop-token hit, "length" on the
                     max_new_tokens / max_len budget), optional per-token
                     logprobs, and TTFT/TPOT joined from ServingMetrics.

Entry points: ``submit()`` + ``step()``/``run_until_drained()`` for full
control, ``generate(requests)`` for submit-and-drain, ``stream(requests)``
to iterate (request_id, token) pairs as they are sampled, and an
``on_token`` callback fired for every sampled token.

Sampling (serving/sampling.py) is fused into the jitted paged steps: each
batch row carries its own (temperature, top_k, top_p, seed), so one traced
shape serves mixed per-request parameters — greedy rows (temperature=0
lowers exactly to argmax) ride alongside nucleus-sampled rows, and only a
(B,) token vector returns to the host per step.  Sampling keys derive as
``fold_in(seed, absolute_position)``, which makes a recompute-preempted
request regenerate bit-identical tokens — required for its prefix-cache
blocks to re-match at re-admission.

Every architecture in the zoo is served.  Each batch row carries its own
position vector, block table and slot-state row, so a finished request's
slot (and its cache blocks) are reused on the very next step, and a long
prompt is prefilled ``prefill_chunk`` tokens at a time between decode steps
instead of blocking them.  Per-family cache routing
(serving/cache_manager.py):
  * attention-family KV — paged block pools, incl. zamba2's weight-shared
    block (one pool per application via the repeat-stacked axis) and MLA's
    latent (c_kv, k_rope) rows;
  * mamba2 state — slot-state rows, carried as h0 across prefill chunks;
  * cross-attn / whisper encoder K/V — slot-state rows written once at
    admission (the whisper encoder runs there, never per step).

Engine step = admit -> one prefill chunk -> one decode step:
  1. every free slot pulls from the RequestScheduler (priority/FCFS +
     max-tokens budget, footprints capped at max_len) if its prompt's
     blocks fit the pool; admission resets the slot's state-pool rows
     (make_slot_admit_step).  With ``share_prefix`` (purely paged archs
     only — see serving/cache_manager.py) admission first matches the
     longest cached full-block prefix of the request context: matched
     blocks are refcount-shared, prefill starts at the matched boundary
     (TTFT skips the shared system prompt / few-shot prefix), and full
     blocks this request writes are committed back to the content index
     for later requests;
  2. the oldest prefilling request advances one chunk; finishing the prompt
     samples its first token (TTFT);
  3. all decoding slots advance one token.  A slot needing a new block under
     cache pressure first evicts unreferenced prefix-cache blocks, then
     preempts the request with the largest resident cache footprint
     (recompute-style: refcounts dropped, request requeued with
     prompt+generated as its new prefill — slot-state needs no checkpoint:
     re-admission re-zeroes the row, and a sharing request re-matches its
     own retired blocks).

All request-lifecycle timestamps (submit / first token / finish) come from
one injectable ``clock`` — tests pass a synthetic clock and get coherent
TTFT/TPOT instead of mixing fake submit times with real perf_counter
stamps.

Observability: every step is phase-timed (admission / prefix-match /
prefill chunk / decode / sample host-sync) into the ServingMetrics
log-bucketed histograms, a ``StepMonitor`` (core/profiler.py) tracks
step-time EMA drift as the adaptive scheduler's re-profile trigger, and
an optional ``tracer`` (serving/tracing.py ChromeTracer) records the same
clock values as Perfetto-loadable spans — per-phase tracks plus a
lifecycle span per request with admitted/first-token/preempt/resume
annotations.  With ``tracer=None`` (default) no trace work happens at
all; an optional ``snapshot`` (serving/export.py SnapshotWriter) appends
a windowed-signal JSONL line every N seconds of engine time.

Greedy decode is token-for-token identical to the retired wave Server: the
paged attention paths mask exactly the same prefix (layers._paged_sdpa,
mla.mla_paged_attention), the slot-state path runs the same recurrence on
gathered rows, and temperature=0 sampling is a bare argmax inside the
fused sampler.  tests/test_serving.py pins this against golden token
sequences frozen from the pre-shim wave implementation, for every arch
family, including under forced preemption and on a multi-host (data=4,
model=2) mesh.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Iterable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.asa import AdaptiveScheduler
from repro.core.profiler import StepMonitor
from repro.launch.mesh import mesh_shape_of
from repro.runtime import steps as ST
from repro.serving.cache_manager import UnifiedCacheManager, check_servable
from repro.serving.metrics import ServingMetrics
from repro.serving.paged_cache import PagedCacheConfig, blocks_for
from repro.serving.sampling import GREEDY, SamplingParams, make_sampler
from repro.serving.scheduler import RequestScheduler


@dataclasses.dataclass
class Request:
    """Input-only request description (API v2).

    The engine never mutates a Request: generated tokens, finish reason,
    logprobs and latency come back as a ``RequestOutput`` (via
    ``engine.completed``, ``generate()`` or ``stream()``).  Because no
    state sticks to the object, a finished Request may be resubmitted
    as-is (its id must simply not be in flight).
    """
    id: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    priority: int = 0                # lower = more urgent
    sampling: SamplingParams = GREEDY
    # per-request modality input, consumed ONCE at admission: vision patch
    # embeddings (1, n_img_tokens, d_model) -> cross-attn K/V rows, or audio
    # frame embeddings (1, enc_len, d_model) -> encoder pass -> wdec cross
    # K/V rows (transformer.admit_slot)
    frontend: Optional[np.ndarray] = None


@dataclasses.dataclass
class RequestOutput:
    """Typed generation result (API v2).

    finish_reason  "stop"   — a ``stop_token_ids`` member was sampled (it
                              is the last entry of ``token_ids``);
                   "length" — the max_new_tokens / max_len budget ran out.
    logprobs       per-token log-probabilities under the distribution each
                   token was sampled from; None unless the request's
                   ``SamplingParams.logprobs`` was set.
    ttft_s/tpot_s  joined from ServingMetrics at finish time (None if the
                   engine ran without timestamps for this request).
    """
    request_id: int
    token_ids: list
    finish_reason: str               # "stop" | "length"
    prompt_len: int = 0
    logprobs: Optional[list] = None
    ttft_s: Optional[float] = None
    tpot_s: Optional[float] = None

    @property
    def n_tokens(self) -> int:
        return len(self.token_ids)


@dataclasses.dataclass
class _ReqState:
    """Engine-internal mutable generation state for one in-flight request.

    Quacks like the scheduler's request protocol (id / prompt /
    max_new_tokens / priority / out_tokens / _sched_seq), keeping
    RequestScheduler oblivious to the API split; the public Request stays
    untouched.
    """
    req: Request
    seed: int                        # effective seed (params.seed or req.id)
    stop_ids: frozenset
    out_tokens: list = dataclasses.field(default_factory=list)
    logprobs: Optional[list] = None  # [] iff params.logprobs else None
    _sched_seq: Optional[int] = None   # set by RequestScheduler (FCFS order)
    _charged_footprint: Optional[int] = None   # budget charge at admission

    @property
    def id(self) -> int:
        return self.req.id

    @property
    def prompt(self) -> np.ndarray:
        return self.req.prompt

    @property
    def max_new_tokens(self) -> int:
        return self.req.max_new_tokens

    @property
    def priority(self) -> int:
        return self.req.priority

    @property
    def sampling(self) -> SamplingParams:
        return self.req.sampling

    def context(self) -> np.ndarray:
        """prompt + generated-so-far — what a (re-)prefill must cover."""
        if not self.out_tokens:
            return np.asarray(self.req.prompt, np.int32)
        return np.concatenate([np.asarray(self.req.prompt, np.int32),
                               np.asarray(self.out_tokens, np.int32)])


@dataclasses.dataclass
class _Slot:
    idx: int = 0                     # engine slot index == state-pool row
    req: Optional[_ReqState] = None
    state: str = "idle"              # idle | prefill | decode
    pos: int = 0                     # tokens currently resident in the cache
    prefill_pos: int = 0             # prompt tokens already prefilled

    @property
    def busy(self) -> bool:
        return self.req is not None


class ContinuousBatchingEngine:
    def __init__(self, arch: ArchConfig, params, mesh, *,
                 slots: int = 4, max_len: int = 512,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefill_chunk: int = 64,
                 share_prefix: bool = False,
                 scheduler: Optional[RequestScheduler] = None,
                 asa: Optional[AdaptiveScheduler] = None,
                 metrics: Optional[ServingMetrics] = None,
                 clock: Callable[[], float] = time.perf_counter,  # reprolint: disable=clock-injection
                 on_token: Optional[Callable[[int, int], None]] = None,
                 tracer=None, snapshot=None,
                 step_monitor: Optional[StepMonitor] = None,
                 sanitizer=None):
        check_servable(arch)           # precise error for excluded archs
        self.arch, self.mesh = arch, mesh
        self.max_len, self.prefill_chunk = max_len, prefill_chunk
        self.share_prefix = share_prefix
        self._clock = clock
        # on_token(request_id, token_id): fired for every sampled token,
        # in sampling order — the streaming hook stream() builds on
        self.on_token = on_token
        max_blocks_per_seq = blocks_for(max_len, block_size)
        if num_blocks is None:
            num_blocks = slots * max_blocks_per_seq + 1   # +1: null block
        shape = ShapeSpec("serve", max_len, slots, "decode")
        sched = asa or AdaptiveScheduler(faithful=False)
        self.plan = sched.plan(arch, shape, mesh_shape_of(mesh))
        cdtype = jnp.float32 if arch.dtype == "float32" else jnp.bfloat16
        self.cache = UnifiedCacheManager(
            arch, PagedCacheConfig(block_size, num_blocks, max_blocks_per_seq,
                                   slots=slots, share_prefix=share_prefix),
            dtype=cdtype, mesh=mesh, specs=self.plan.paged_cache_specs())
        self.params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s),
                                 self.plan.param_specs()))
        sampler = make_sampler(arch.vocab)
        # donation follows ST.STEP_DONATION (the cache carry is donated,
        # params never are) — audited by analysis/tracecheck.py
        self._prefill = ST.jit_step(
            "paged_prefill", ST.make_paged_prefill_step(arch, sampler=sampler))
        self._decode = ST.jit_step(
            "paged_decode", ST.make_paged_decode_step(arch, sampler=sampler))
        self._admit_slot_state = ST.jit_step(
            "slot_admit", ST.make_slot_admit_step(arch)) \
            if self.cache.has_slot_state else None
        self.scheduler = scheduler or RequestScheduler()
        # the engine truncates every request to max_len, so the token budget
        # must charge capped footprints — uncapped, a long-prompt request
        # over-charges and stalls admission forever.  The engine OWNS the
        # cap (unconditional overwrite): it mirrors this engine's
        # truncation, and a stale cap from a previous engine with a
        # different max_len would mis-charge the budget
        self.scheduler.footprint_cap = self.max_len
        self.metrics = metrics or ServingMetrics()
        # observability: ChromeTracer (serving/tracing.py) and
        # SnapshotWriter (serving/export.py) are optional and cost nothing
        # when absent; the StepMonitor always runs (a handful of floats)
        self.tracer = tracer
        self.snapshot = snapshot
        self.step_monitor = step_monitor or StepMonitor()
        # live references: summary()/exporters read the scheduler counters
        # and cache geometry at call time instead of per-step pushes
        self.metrics.scheduler_stats = self.scheduler.stats
        self.metrics.cache_stats = self.cache.stats
        # paged-cache sanitizer (analysis/sanitizer.py): explicit via the
        # kwarg, or opt-in for a whole test run via REPRO_SANITIZE=1.  The
        # import is lazy so production engine construction never touches
        # the analysis package
        if sanitizer is None and os.environ.get("REPRO_SANITIZE"):
            from repro.analysis.sanitizer import CacheSanitizer
            sanitizer = CacheSanitizer()
        self.sanitizer = sanitizer
        if self.sanitizer is not None:
            self.sanitizer.attach(self.cache)
        self.slots = [_Slot(idx=i) for i in range(slots)]
        self.completed: list[RequestOutput] = []
        self._states: dict[int, _ReqState] = {}   # queued or running

    # ------------------------------------------------------------------
    def _validate(self, req: Request) -> None:
        """Every reject-at-submit check, with NO state change — so
        ``generate()`` can vet a whole batch before putting any of it in
        flight."""
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.id} has an empty prompt")
        if req.max_new_tokens < 1:
            # a request that may not generate anything would still burn a
            # slot and a full prefill, and the prefill path unconditionally
            # samples its first token — reject instead of emitting one
            raise ValueError(f"request {req.id}: max_new_tokens must be "
                             f">= 1 (got {req.max_new_tokens})")
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt ({len(req.prompt)}) >= max_len")
        if req.id in self._states:
            # block tables are keyed by request id — a duplicate would share
            # (and corrupt) the live request's table
            raise ValueError(f"request id {req.id} is already in flight")
        try:
            req.sampling.validate(self.arch.vocab)
        except ValueError as e:      # reject-at-submit, like the shape checks
            raise ValueError(f"request {req.id}: {e}") from None
        if blocks_for(self._target_total(req), self.cache.cfg.block_size) \
                > self.cache.cfg.num_blocks - 1:
            raise ValueError(f"request {req.id} can never fit the block pool")
        self.scheduler.check_submittable(req)

    def submit(self, req: Request, now: Optional[float] = None) -> None:
        self._validate(req)
        sp = req.sampling
        st = _ReqState(
            req=req,
            # distinct requests get distinct streams by default, but the
            # effective seed depends only on stable request identity, never
            # on scheduling — preemption re-derives the same keys
            seed=(sp.seed if sp.seed is not None else req.id % (2 ** 32)),
            stop_ids=frozenset(sp.stop_token_ids),
            logprobs=[] if sp.logprobs else None)
        self.scheduler.submit(st)        # may raise (token budget) — only a
        self._states[req.id] = st        # queued request claims its id
        t = self._clock() if now is None else now
        self.metrics.on_submit(req.id, t, prompt_len=len(req.prompt))
        if self.tracer is not None:
            self.tracer.request_begin(req.id, t, prompt_len=len(req.prompt),
                                      max_new_tokens=req.max_new_tokens,
                                      priority=req.priority)

    def _target_total(self, req) -> int:
        # same self-truncation as the wave Server's max_len loop bound
        # (req is a Request or a _ReqState — both carry the two fields)
        return min(len(req.prompt) + req.max_new_tokens, self.max_len)

    # ------------------------------------------------------------------
    def _sampling_rows(self, states: Sequence[Optional[_ReqState]]):
        """Per-row sampler parameter arrays for a batch of (possibly None)
        request states — None rows get greedy params and are discarded by
        the caller."""
        n = len(states)
        temp = np.zeros((n,), np.float32)
        top_k = np.zeros((n,), np.int32)
        top_p = np.ones((n,), np.float32)
        seeds = np.zeros((n,), np.uint32)
        for i, st in enumerate(states):
            if st is None:
                continue
            sp = st.sampling
            temp[i], top_k[i], top_p[i] = sp.temperature, sp.top_k, sp.top_p
            seeds[i] = st.seed
        return (jnp.asarray(temp), jnp.asarray(top_k), jnp.asarray(top_p),
                jnp.asarray(seeds))

    def _record_token(self, slot: _Slot, tok: int, logp: float) \
            -> Optional[str]:
        """Append one sampled token to the slot's request and return its
        finish reason, if any ("stop" wins when a stop token lands exactly
        on the length budget — it genuinely terminated the stream)."""
        st = slot.req
        st.out_tokens.append(tok)
        if st.logprobs is not None:
            st.logprobs.append(logp)
        if self.on_token is not None:
            self.on_token(st.id, tok)
        if tok in st.stop_ids:
            return "stop"
        if len(st.req.prompt) + len(st.out_tokens) >= self._target_total(st):
            return "length"
        return None

    def _finish(self, slot: _Slot, reason: str) -> None:
        st = slot.req
        self.cache.release(st.id)
        self.scheduler.on_finish(st)
        t = self._clock()
        self.metrics.on_finish(st.id, len(st.out_tokens), t, reason=reason)
        if self.tracer is not None:
            self.tracer.request_end(st.id, t, finish_reason=reason,
                                    n_tokens=len(st.out_tokens))
        del self._states[st.id]
        rep = self.metrics.request_report(st.id)
        self.completed.append(RequestOutput(
            request_id=st.id, token_ids=list(st.out_tokens),
            finish_reason=reason, prompt_len=len(st.req.prompt),
            logprobs=None if st.logprobs is None else list(st.logprobs),
            ttft_s=rep["ttft_s"], tpot_s=rep["tpot_s"]))
        slot.req, slot.state, slot.pos, slot.prefill_pos = None, "idle", 0, 0

    def _preempt(self, slot: _Slot) -> None:
        st = slot.req
        self.cache.release(st.id)
        self.scheduler.preempt(st)
        self.metrics.on_preempt(st.id)
        if self.tracer is not None:
            self.tracer.request_instant(st.id, "preempt", self._clock(),
                                        resident_tokens=slot.pos,
                                        n_generated=len(st.out_tokens))
        slot.req, slot.state, slot.pos, slot.prefill_pos = None, "idle", 0, 0

    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Abort a queued or running request; True iff ``rid`` was in
        flight.  The request finishes with ``finish_reason=reason`` and
        whatever tokens it had produced — the cluster frontend uses this
        when a stop *string* matches at the detokenized boundary (the
        engine stays token-level; see serving/detok.py) and when a client
        disconnects mid-stream.  Running requests release their cache
        blocks and budget charge through the normal ``_finish`` path;
        queued requests hold neither (budget is charged at admission), so
        cancellation there is queue surgery plus the same lifecycle
        bookkeeping — either way metrics/trace/completed stay consistent
        and the drain sanitizer sees a clean engine."""
        st = self._states.get(rid)
        if st is None:
            return False
        for slot in self.slots:
            if slot.busy and slot.req is st:
                self._finish(slot, reason)
                return True
        removed = self.scheduler.remove(st)
        if not removed:
            raise RuntimeError(f"request {rid} tracked but neither running "
                               f"nor queued — lifecycle invariant broken")
        del self._states[rid]
        t = self._clock()
        self.metrics.on_finish(rid, len(st.out_tokens), t, reason=reason)
        if self.tracer is not None:
            self.tracer.request_end(rid, t, finish_reason=reason,
                                    n_tokens=len(st.out_tokens))
        rep = self.metrics.request_report(rid)
        self.completed.append(RequestOutput(
            request_id=rid, token_ids=list(st.out_tokens),
            finish_reason=reason, prompt_len=len(st.req.prompt),
            logprobs=None if st.logprobs is None else list(st.logprobs),
            ttft_s=rep["ttft_s"], tpot_s=rep["tpot_s"]))
        return True

    def outstanding_tokens(self) -> int:
        """Worst-case tokens still to be generated across every queued and
        running request — the load estimate a cluster router balances on
        (exported per worker through the stats protocol)."""
        return sum(
            max(self._target_total(st) - len(st.req.prompt)
                - len(st.out_tokens), 0)
            for st in self._states.values())

    # -- phase 1: admission --------------------------------------------
    def _admit(self) -> int:
        admitted = 0
        for slot in self.slots:
            if slot.busy:
                continue
            head = self.scheduler.peek()
            if head is None:
                break
            ctx = head.context()
            if not self.cache.can_fit_request(ctx):
                if not any(s.busy for s in self.slots):
                    raise RuntimeError(
                        f"request {head.id} cannot fit an empty pool")
                break                      # wait for running requests to free
            st = self.scheduler.next_admission()
            if st is None:                 # token budget exhausted
                break
            # longest cached full-block prefix: refcounts bump, the table
            # starts populated, and prefill starts at the matched boundary
            # (no-op with share_prefix off)
            if self.share_prefix:
                tp0 = self._clock()
                n_cached = self.cache.assign_prefix(st.id, ctx)
                tp1 = self._clock()
                self.metrics.on_phase("prefix_match", tp1 - tp0)
                if self.tracer is not None:
                    self.tracer.phase("prefix_match", tp0, tp1,
                                      request=st.id,
                                      matched_tokens=n_cached)
            else:
                n_cached = self.cache.assign_prefix(st.id, ctx)
            ok = self.cache.reserve(st.id, len(ctx))
            if not ok:
                raise RuntimeError(
                    f"request {st.id}: can_fit_request passed but reserve "
                    f"failed — admission check out of sync with allocator")
            slot.req, slot.state = st, "prefill"
            slot.pos, slot.prefill_pos = n_cached, n_cached
            admitted += 1
            if self.share_prefix:
                self.metrics.on_prefix_match(n_cached, len(ctx),
                                             now=self._clock())
            if self.tracer is not None:
                t = self._clock()
                if st.out_tokens:      # re-admission after preemption
                    self.tracer.request_instant(st.id, "resume", t,
                                                n_generated=len(st.out_tokens))
                self.tracer.request_instant(st.id, "admitted", t,
                                            slot=slot.idx,
                                            context_len=len(ctx),
                                            prefix_cached_tokens=n_cached)
            if self._admit_slot_state is not None:
                # reset this slot's state-pool rows (zero mamba2 state;
                # cross K/V from the request's frontend, computed once)
                args = (self.params, self.cache.pools,
                        jnp.asarray(slot.idx, jnp.int32))
                if st.req.frontend is not None:
                    args += (jnp.asarray(st.req.frontend),)
                self.cache.pools = self._admit_slot_state(*args)
        return admitted

    # -- phase 2: one chunk of prefill ---------------------------------
    def _prefill_chunk(self) -> bool:
        # oldest request first (scheduler seq), not lowest slot index — a
        # newer request admitted into a freed lower slot must not starve an
        # older mid-prefill request's TTFT
        prefilling = [s for s in self.slots if s.state == "prefill"]
        if not prefilling:
            return False
        slot = min(prefilling, key=lambda s: s.req._sched_seq)
        st = slot.req
        ctx = st.context()
        chunk = ctx[slot.prefill_pos: slot.prefill_pos + self.prefill_chunk]
        n_new = len(chunk)
        if n_new < self.prefill_chunk:      # pad: the step traces one shape
            chunk = np.concatenate(
                [chunk, np.zeros(self.prefill_chunk - n_new, np.int32)])
        table = self.cache.table_array([st.id])
        tok, logp, self.cache.pools = self._prefill(
            self.params, self.cache.pools, jnp.asarray(chunk[None, :]),
            jnp.asarray([slot.prefill_pos], jnp.int32), jnp.asarray(table),
            jnp.asarray([n_new], jnp.int32),
            jnp.asarray([slot.idx], jnp.int32),
            *self._sampling_rows([st]))
        slot.prefill_pos += n_new
        slot.pos = slot.prefill_pos
        self.cache.commit_prefix(st.id, ctx, slot.prefill_pos)
        self.metrics.prefill_chunks += 1
        if slot.prefill_pos == len(ctx):
            # the fused sampler produced this chunk's next token at absolute
            # position len(ctx) — only the final chunk's draw is real
            t = self._clock()
            self.metrics.on_first_token(st.id, t)
            if self.tracer is not None:
                self.tracer.request_instant(st.id, "first_token", t)
            reason = self._record_token(slot, int(tok[0]), float(logp[0]))
            if reason is not None:
                self._finish(slot, reason)
            else:
                slot.state = "decode"
        return True

    # -- phase 3: one decode step for every decoding slot --------------
    def _decode_step(self) -> int:
        decoding = [s for s in self.slots if s.state == "decode"]
        if not decoding:
            return 0
        # grow block tables; preempt the longest-running request on pressure
        for slot in list(decoding):
            if slot.req is None:       # already preempted as an earlier victim
                continue
            while not self.cache.reserve(slot.req.id, slot.pos + 1):
                victims = [s.req for s in self.slots if s.busy]
                victim = self.scheduler.pick_preemption_victim(victims)
                vslot = next(s for s in self.slots if s.req is victim)
                self._preempt(vslot)
                if vslot in decoding:
                    decoding.remove(vslot)
                if slot.req is None:       # we preempted ourselves
                    break
        decoding = [s for s in decoding if s.req is not None]
        if not decoding:
            return 0
        B = len(self.slots)
        last = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        rids: list[Optional[int]] = [None] * B
        for i, s in enumerate(self.slots):
            if s.state == "decode":
                last[i, 0] = s.req.out_tokens[-1]
                pos[i] = s.pos
                rids[i] = s.req.id
        table = self.cache.table_array(rids)
        # idle/prefilling rows scatter their slot-state into the null row;
        # active rows use s.idx (NOT list position — admission/prefill
        # reset/advance the pool row at idx, and the two may diverge)
        sids = self.cache.slot_ids_array(
            [s.idx if s.state == "decode" else None for s in self.slots])
        tok, logp, self.cache.pools = self._decode(
            self.params, self.cache.pools, jnp.asarray(last),
            jnp.asarray(pos), jnp.asarray(table), jnp.asarray(sids),
            *self._sampling_rows(
                [s.req if s.state == "decode" else None for s in self.slots]))
        # the (B,) token/logprob transfer is where the host blocks on the
        # device — timed as its own phase so the per-step breakdown
        # separates "waiting for the step" from host-side bookkeeping
        ts0 = self._clock()
        nxt = np.asarray(tok)
        lps = np.asarray(logp)
        ts1 = self._clock()
        self.metrics.on_phase("sample_sync", ts1 - ts0)
        if self.tracer is not None:
            n_sampled = sum(1 for s in decoding
                            if not s.req.sampling.is_greedy)
            self.tracer.phase("sample_sync", ts0, ts1,
                              n_rows=len(decoding), n_sampled=n_sampled)
        self.metrics.decode_steps += 1
        for i, s in enumerate(self.slots):
            if s.state != "decode":
                continue
            s.pos += 1
            reason = self._record_token(s, int(nxt[i]), float(lps[i]))
            if self.share_prefix and s.pos % self.cache.cfg.block_size == 0:
                # a block just filled: generated tokens extend the hash
                # chain too, so a preempted request re-matches its own
                # retired blocks at re-admission.  Gated on the boundary —
                # rebuilding context() every token would be O(n^2) per
                # request in the decode hot loop.  Committed even when the
                # request finishes right here: the block retires to the LRU
                # index and stays matchable
                self.cache.commit_prefix(s.req.id, s.req.context(), s.pos)
            if reason is not None:
                self._finish(s, reason)
        return len(decoding)

    # ------------------------------------------------------------------
    def step(self) -> None:
        tr = self.tracer
        t0 = self._clock()
        admitted = self._admit()
        t1 = self._clock()
        prefilled = self._prefill_chunk()
        t2 = self._clock()
        decoded = self._decode_step()
        t3 = self._clock()
        # phase durations only when the phase did work — zero-work dispatch
        # overhead must not dilute the distributions
        if admitted:
            self.metrics.on_phase("admission", t1 - t0)
            if tr is not None:
                tr.phase("admission", t0, t1, admitted=admitted)
        if prefilled:
            self.metrics.on_phase("prefill", t2 - t1)
            if tr is not None:
                tr.phase("prefill", t1, t2)
        if decoded:
            self.metrics.on_phase("decode", t3 - t2)
            if tr is not None:
                tr.phase("decode", t2, t3, n_rows=decoded)
        util = self.cache.utilization
        if tr is not None:
            tr.counter("queue_depth", t3, self.scheduler.queue_depth)
            tr.counter("block_utilization", t3, util)
        self.metrics.on_step(self.scheduler.queue_depth,
                             sum(s.busy for s in self.slots), len(self.slots),
                             block_utilization=util, now=t3)
        dur = t3 - t0
        triggered = self.step_monitor.update(dur)
        self.metrics.on_step_time(dur, ema=self.step_monitor.ema,
                                  drift=self.step_monitor.drift_fraction(),
                                  triggered=triggered)
        if self.snapshot is not None:
            self.snapshot.maybe_write(self.metrics, t3)
        if self.sanitizer is not None:
            self.sanitizer.check_engine_step(self)

    @property
    def has_work(self) -> bool:
        return self.scheduler.queue_depth > 0 or any(s.busy for s in self.slots)

    def _progress_marker(self) -> tuple:
        return (self.metrics.prefill_chunks, self.metrics.decode_steps,
                self.metrics.preemptions, len(self.completed),
                self.scheduler.queue_depth,
                sum(s.busy for s in self.slots))

    def run_until_drained(self, *, max_idle_steps: int = 1000) -> float:
        """Step until no queued or running work remains.  Raises after
        ``max_idle_steps`` consecutive steps that neither prefill, decode,
        preempt, finish, admit nor drain anything — a stuck engine (e.g. a
        token budget that can never re-admit) must fail loudly instead of
        spinning forever."""
        t0 = self._clock()
        idle, marker = 0, self._progress_marker()
        while self.has_work:
            self.step()
            now = self._progress_marker()
            idle = idle + 1 if now == marker else 0
            marker = now
            if idle >= max_idle_steps:
                raise RuntimeError(
                    f"engine made no progress for {idle} consecutive steps "
                    f"({self.scheduler.queue_depth} queued, "
                    f"{sum(s.busy for s in self.slots)} busy slots) — "
                    f"admission is wedged")
        if self.sanitizer is not None:
            self.sanitizer.check_drained(self)
        return self._clock() - t0

    # -- v2 entry points ------------------------------------------------
    def generate(self, requests: Iterable[Request]) -> list[RequestOutput]:
        """Submit every request, run the engine until drained, and return
        their ``RequestOutput``s in the order given (independent of finish
        order).  Outputs also accumulate on ``self.completed``.  The whole
        batch is validated before ANY request is submitted, so a malformed
        entry raises with nothing newly in flight."""
        reqs = list(requests)
        seen: set[int] = set()
        for r in reqs:
            self._validate(r)
            if r.id in seen:
                raise ValueError(f"request id {r.id} appears twice in the "
                                 f"batch")
            seen.add(r.id)
        for r in reqs:
            self.submit(r)
        self.run_until_drained()
        by_id = {o.request_id: o for o in self.completed}  # latest id wins
        return [by_id[r.id] for r in reqs]

    def stream(self, requests: Iterable[Request]) \
            -> Iterator[tuple[int, int]]:
        """Submit every request (eagerly, before returning — the requests
        are in flight even if the iterator is never advanced) and step the
        engine as the returned iterator is consumed, yielding
        ``(request_id, token_id)`` pairs in sampling order as they are
        produced — including tokens of requests that were already in
        flight.  A caller-installed ``on_token`` keeps firing too.
        Abandoning the iterator mid-stream leaves the engine with work in
        flight (resume with ``step()``/``run_until_drained()``)."""
        for r in requests:
            self.submit(r)

        def _drive() -> Iterator[tuple[int, int]]:
            buf: list[tuple[int, int]] = []
            prev = self.on_token

            def tap(rid: int, tok: int) -> None:
                if prev is not None:
                    prev(rid, tok)
                buf.append((rid, tok))

            self.on_token = tap
            try:
                while self.has_work:
                    self.step()
                    while buf:
                        yield buf.pop(0)
            finally:
                self.on_token = prev

        return _drive()
