"""Serving telemetry primitives: histograms, counters, gauges and
sliding-window aggregates.

This module is the measurement substrate under ``ServingMetrics`` (the
backward-compatible facade in serving/metrics.py) and the exporters
(serving/export.py).  Everything here is host-side, allocation-light and
O(1) per observation — these objects sit on the engine's per-step hot
path, so none of them may grow with run length:

``LogHistogram``
    Log-bucketed histogram with O(1) ``record`` and approximate
    percentiles (p50/p95/p99 via :meth:`percentile`).  Bucket boundaries
    grow geometrically by ``growth`` (default 1.1), so any percentile
    estimate is within ~``growth - 1`` relative error of the true value —
    the right trade for latency-shaped (long-tailed, positive)
    distributions, and the reason memory stays fixed (~a few hundred int
    buckets) no matter how many samples stream in.  ``count``/``total``/
    ``vmin``/``vmax`` are exact; ``total`` accumulates in record order, so
    ``mean`` is bit-identical to ``sum(samples)/len(samples)``.

``Counter`` / ``Gauge``
    A monotonically increasing count and a last-value-wins measurement.
    Deliberately tiny — they exist so exporters can enumerate "everything
    countable" and "everything settable" uniformly.

``SlidingWindow``
    Timestamped samples over the trailing ``window_s`` seconds, expired
    lazily on access.  This is what turns lifetime aggregates into the
    *recent-workload* signal vector the adaptive scheduler (ROADMAP
    item 3) consumes: arrival rate, prompt-length mix, prefix hit rate
    and cache pressure *over the last N seconds*, not since process
    start.  Memory is bounded by events-in-window, and all timestamps are
    caller-supplied (the engine's injectable clock), so tests drive it
    with a synthetic clock.

``Telemetry``
    A flat name -> primitive registry tying the four together, so the
    Prometheus/JSONL exporters can walk every metric without knowing the
    engine's internals.

``quantile``
    Exact linear-interpolation quantile over a bounded sample list
    (numpy-free twin of ``np.quantile(..., method="linear")``) — used for
    per-request TTFT/TPOT percentiles, where the sample count is bounded
    by the number of requests and exactness is worth keeping.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Optional


def quantile(xs, q: float) -> Optional[float]:
    """Exact q-quantile (linear interpolation, numpy's default method) of
    an iterable of numbers; None when empty.  For bounded sample sets —
    unbounded streams belong in a LogHistogram."""
    s = sorted(xs)
    if not s:
        return None
    if len(s) == 1:
        return float(s[0])
    pos = q * (len(s) - 1)
    lo = math.floor(pos)
    hi = math.ceil(pos)
    frac = pos - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        self.value += n


class Gauge:
    """Last-value-wins measurement; None until first set."""

    __slots__ = ("value",)

    def __init__(self):
        self.value: Optional[float] = None

    def set(self, x: Optional[float]) -> None:
        self.value = x


class LogHistogram:
    """Log-bucketed histogram: O(1) record, fixed memory, approximate
    percentiles.

    Bucket 0 holds values below ``lo`` (including zero — queue depths and
    durations are never negative, and negatives clamp there too); bucket i
    (1..n) holds ``[lo * growth**(i-1), lo * growth**i)``; the last bucket
    is the overflow for values >= ``hi``.  ``percentile`` walks the
    cumulative counts and returns the geometric midpoint of the target
    bucket, clamped into the observed [vmin, vmax] — relative error is
    bounded by the bucket width (~``growth - 1``).
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e5,
                 growth: float = 1.1):
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError(f"bad histogram shape lo={lo} hi={hi} "
                             f"growth={growth}")
        self.lo, self.hi, self.growth = lo, hi, growth
        self._log_growth = math.log(growth)
        self._n = math.ceil(math.log(hi / lo) / self._log_growth)
        self.counts = [0] * (self._n + 2)      # [under, 1..n, over]
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def _index(self, x: float) -> int:
        if x < self.lo:
            return 0
        return min(int(math.log(x / self.lo) / self._log_growth) + 1,
                   self._n + 1)

    def upper_bound(self, idx: int) -> float:
        """Exclusive upper bound of bucket ``idx`` (inf for the overflow
        bucket) — what a Prometheus ``le`` label reports."""
        if idx <= 0:
            return self.lo
        if idx > self._n:
            return math.inf
        return self.lo * self.growth ** idx

    def record(self, x: float) -> None:
        self.count += 1
        self.total += x
        if self.vmin is None or x < self.vmin:
            self.vmin = x
        if self.vmax is None or x > self.vmax:
            self.vmax = x
        self.counts[self._index(x)] += 1

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def percentile(self, q: float) -> Optional[float]:
        """Approximate q-th percentile (q in [0, 1]); None when empty."""
        if not self.count:
            return None
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c:
                if i == 0:
                    est = self.lo / 2.0
                elif i > self._n:
                    est = self.vmax
                else:
                    lo_b = self.lo * self.growth ** (i - 1)
                    est = math.sqrt(lo_b * self.upper_bound(i))
                return min(max(est, self.vmin), self.vmax)
        return self.vmax

    def nonzero_buckets(self):
        """[(upper_bound, cumulative_count)] over non-empty buckets —
        sparse cumulative rendering for Prometheus exposition."""
        out, cum = [], 0
        for i, c in enumerate(self.counts):
            if c:
                cum += c
                out.append((self.upper_bound(i), cum))
        return out

    def summary(self) -> dict:
        """JSON-able digest: exact count/mean/min/max plus approximate
        p50/p95/p99 (all None when no samples)."""
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin, "max": self.vmax,
                "p50": self.percentile(0.50),
                "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class SlidingWindow:
    """Timestamped samples over the trailing ``window_s`` seconds.

    ``record(t, value)`` appends; every accessor takes ``now`` and first
    drops samples older than ``now - window_s``.  Timestamps must be
    non-decreasing (they come from one engine clock).  Memory is bounded
    by the number of events inside the window.
    """

    def __init__(self, window_s: float = 10.0):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0 (got {window_s})")
        self.window_s = window_s
        self._q: deque = deque()               # (t, value)

    def record(self, t: float, value: float = 1.0) -> None:
        self._q.append((t, value))
        self._expire(t)

    def _expire(self, now: float) -> None:
        cutoff = now - self.window_s
        q = self._q
        while q and q[0][0] <= cutoff:
            q.popleft()

    def values(self, now: float) -> list:
        self._expire(now)
        return [v for _, v in self._q]

    def count(self, now: float) -> int:
        self._expire(now)
        return len(self._q)

    def rate(self, now: float) -> float:
        """Events per second over the window."""
        return self.count(now) / self.window_s

    def total(self, now: float) -> float:
        self._expire(now)
        return sum(v for _, v in self._q)

    def mean(self, now: float) -> Optional[float]:
        self._expire(now)
        return (sum(v for _, v in self._q) / len(self._q)
                if self._q else None)

    def vmax(self, now: float) -> Optional[float]:
        self._expire(now)
        return max((v for _, v in self._q), default=None)

    def quantile(self, q: float, now: float) -> Optional[float]:
        return quantile(self.values(now), q)


class Telemetry:
    """Flat name -> primitive registry.

    One instance per ServingMetrics; exporters iterate ``counters`` /
    ``gauges`` / ``histograms`` / ``windows`` without knowing which
    subsystem registered what.  ``window_s`` is the shared horizon for
    every window created through :meth:`window`.
    """

    def __init__(self, window_s: float = 10.0):
        self.window_s = window_s
        self.counters: dict[str, Counter] = {}
        self.gauges: dict[str, Gauge] = {}
        self.histograms: dict[str, LogHistogram] = {}
        self.windows: dict[str, SlidingWindow] = {}

    def counter(self, name: str) -> Counter:
        return self.counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self.gauges.setdefault(name, Gauge())

    def histogram(self, name: str, **kw) -> LogHistogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = LogHistogram(**kw)
        return h

    def window(self, name: str) -> SlidingWindow:
        w = self.windows.get(name)
        if w is None:
            w = self.windows[name] = SlidingWindow(self.window_s)
        return w

    def snapshot(self, now: float) -> dict:
        """JSON-able dump of every registered primitive."""
        return {
            "counters": {k: c.value for k, c in self.counters.items()},
            "gauges": {k: g.value for k, g in self.gauges.items()},
            "histograms": {k: h.summary()
                           for k, h in self.histograms.items()},
            "windows": {k: {"count": w.count(now), "rate": w.rate(now),
                            "mean": w.mean(now), "max": w.vmax(now)}
                        for k, w in self.windows.items()},
        }
