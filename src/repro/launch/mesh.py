"""Production mesh builders.

Functions (not module-level constants) so importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips (data, model).  Multi-pod:
2x16x16 = 512 chips (pod, data, model) — the `pod` axis is the slow-link
(DCN) axis carrying data parallelism + pod-sharded ZeRO only.
"""
from __future__ import annotations

import jax

from repro.core.costmodel import MeshShape


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape_of(mesh) -> MeshShape:
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    return MeshShape(data=d.get("data", 1), model=d.get("model", 1),
                     pod=d.get("pod", 1))


def make_host_mesh(n_devices: int | None = None, model: int = 1):
    """Small CPU mesh for tests/examples (uses however many host devices
    exist, factored as (data, model))."""
    n = n_devices or len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
