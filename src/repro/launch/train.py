"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 50 [--checkpoint-dir ckpts] [--opt8bit]

--smoke uses the reduced same-family config (CPU-runnable); without it the
full config is planned on the production mesh (requires real hardware or
the dry-run's virtual devices).
"""
from __future__ import annotations

import argparse


from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.configs.base import ShapeSpec
from repro.core.asa import AdaptiveScheduler
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--opt8bit", action="store_true")
    ap.add_argument("--replan-every", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        arch = reduce_for_smoke(arch)
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    mesh = make_host_mesh()
    sched = AdaptiveScheduler(
        faithful=False,
        opt_preset="adamw8bit" if args.opt8bit else "adamw32")
    trainer = Trainer(
        arch, shape, mesh,
        TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps, replan_every=args.replan_every,
                    quantized_opt=args.opt8bit,
                    checkpoint_every=max(args.steps // 2, 1)),
        scheduler=sched, checkpoint_dir=args.checkpoint_dir)
    print(trainer.plan.summary())

    params, opt_state = trainer.init_state()
    if args.checkpoint_dir:
        params, opt_state = trainer.maybe_restore(params, opt_state)
    data = SyntheticLM(arch.vocab, args.seq_len, args.batch,
                       start_step=trainer.data_offset)
    params, opt_state, hist = trainer.train(
        params, opt_state, data, steps=args.steps,
        on_metrics=lambda s, m: print(
            f"step {s:5d}  loss {m['loss']:.4f}  "
            f"{m['step_time_s']*1e3:.0f} ms"))
    print(f"done: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    if trainer.ckpt:
        trainer.ckpt.wait()


if __name__ == "__main__":
    main()
