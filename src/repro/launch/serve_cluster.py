"""Cluster serving launcher: N engine replica workers + prefix-affinity
router + HTTP/SSE frontend.

    PYTHONPATH=src python -m repro.launch.serve_cluster \
        --arch qwen3-8b --smoke --replicas 2 --http-port 8080

Boot sequence: bind the worker port (ephemeral unless --worker-port),
spawn the replicas (subprocess each, per-worker XLA_FLAGS mesh slice),
accept their connections + ready handshakes, then start the router poll
loop on a background thread and the HTTP server on this one.  Prints
``serving on http://...`` and the worker pids once ready — the CI
cluster job scrapes both (the pids for the no-orphans check).

Shutdown: SIGTERM/SIGINT trips one event; the HTTP server stops, the
router broadcasts ``shutdown``, the launcher reaps every worker
(terminate -> kill escalation for stragglers) and the process exits 0.
A worker dying early fails the boot loudly instead of hanging accept.

The router/frontend process never imports jax — only the worker
subprocesses pay device-runtime startup.
"""
from __future__ import annotations

import argparse
import signal
import sys
import threading
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--http-host", default="127.0.0.1")
    ap.add_argument("--http-port", type=int, default=0,
                    help="frontend port (0 = ephemeral, printed at boot)")
    ap.add_argument("--worker-port", type=int, default=0,
                    help="router's worker-facing port (0 = ephemeral)")
    ap.add_argument("--devices-per-worker", type=int, default=1,
                    help="forced host-platform device count per worker "
                         "(each replica's own mesh slice)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None)
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--share-prefix", action="store_true")
    ap.add_argument("--metrics-window", type=float, default=10.0)
    ap.add_argument("--heartbeat-interval", type=float, default=1.0)
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0)
    ap.add_argument("--boot-timeout", type=float, default=300.0,
                    help="seconds to wait for every worker to connect "
                         "(first run pays jit compilation)")
    args = ap.parse_args()
    if args.replicas < 1:
        ap.error("--replicas must be >= 1")

    from repro.serving.cluster.frontend import ClusterHTTPServer
    from repro.serving.cluster.launcher import (WorkerProcesses,
                                                accept_workers,
                                                listen_socket)
    from repro.serving.cluster.router import ReplicaHandle, Router

    srv = listen_socket(port=args.worker_port)
    host, port = srv.getsockname()[:2]
    procs = WorkerProcesses.spawn(
        args.replicas, connect=f"{host}:{port}", arch=args.arch,
        devices_per_worker=args.devices_per_worker, smoke=args.smoke,
        slots=args.slots, max_len=args.max_len, block_size=args.block_size,
        num_blocks=args.num_blocks, prefill_chunk=args.prefill_chunk,
        share_prefix=args.share_prefix,
        metrics_window=args.metrics_window)
    try:
        conns = accept_workers(srv, args.replicas,
                               timeout=args.boot_timeout, procs=procs)
    except Exception:
        procs.stop(grace=2.0)
        raise
    handles = [ReplicaHandle(replica=rid, transport=stream,
                             pid=ready.get("pid"),
                             max_len=ready.get("max_len", args.max_len))
               for rid, (stream, ready) in sorted(conns.items())]
    router = Router(handles, block_size=args.block_size,
                    heartbeat_interval=args.heartbeat_interval,
                    heartbeat_timeout=args.heartbeat_timeout)
    http = ClusterHTTPServer(router, host=args.http_host,
                             port=args.http_port)

    stop = threading.Event()

    def on_signal(signum, frame):
        stop.set()
        # unblock serve_forever from the signal handler's thread safely
        threading.Thread(target=http.shutdown, daemon=True).start()

    signal.signal(signal.SIGTERM, on_signal)
    signal.signal(signal.SIGINT, on_signal)

    # Router.poll already contains per-replica failures (ProtocolError /
    # ConnectionClosed -> mark dead); anything that still escapes is a
    # router bug, and the one poll thread dying silently would leave the
    # HTTP server accepting requests that can never finish.  Fail the
    # whole process loudly instead.
    poll_failure: list = []

    def poll_loop():
        try:
            while not stop.is_set():
                router.poll(0.05)
        except Exception:
            poll_failure.append(traceback.format_exc())
            print(f"fatal: router poll thread died\n{poll_failure[0]}",
                  file=sys.stderr, flush=True)
            stop.set()
            threading.Thread(target=http.shutdown, daemon=True).start()

    poller = threading.Thread(target=poll_loop, daemon=True,
                              name="router-poll")
    poller.start()

    print(f"serving on {http.url} "
          f"({args.replicas} replica(s), arch {args.arch})", flush=True)
    print(f"worker pids: {' '.join(str(p) for p in procs.pids)}",
          flush=True)
    try:
        http.serve_forever(poll_interval=0.2)
    finally:
        stop.set()
        poller.join(timeout=5.0)
        router.broadcast_shutdown()
        codes = procs.stop(grace=10.0)
        http.server_close()
        srv.close()
        print(f"workers exited with {codes}", flush=True)
    sys.exit(1 if poll_failure else 0)


if __name__ == "__main__":
    main()
