"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b --smoke \
        --engine continuous --requests 8 --prompt-len 16 --max-new 12

    # seeded nucleus sampling with stop tokens
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --temperature 0.8 --top-p 0.95 --seed 7 --stop 11,12

--engine continuous  (default) continuous batching over the unified serving
                     cache (paged KV / latent block pools + slot-state pools
                     for SSM, cross-attn and encoder K/V state) with chunked
                     prefill and per-slot positions (repro/serving/); emits
                     a JSON metrics report (TTFT/TPOT/occupancy/tokens-per-
                     sec).  Serves every config in the zoo — zamba2's
                     weight-shared block, whisper's encoder-decoder and
                     deepseek's MLA included.
--engine wave        DEPRECATED: the wave decode path was deleted; this now
                     exercises the runtime.server.Server compatibility shim,
                     which delegates every token to the continuous engine
                     (greedy-only — the legacy API has no sampling field).
--temperature /
--top-k / --top-p    per-request SamplingParams for every submitted request
                     (temperature 0 = exact greedy argmax, the default).
--seed               base RNG seed; request i samples with seed+i.  Token
                     streams are deterministic — identical across reruns
                     and across recompute-preemptions.
--stop               comma-separated token ids: sampling one finishes the
                     request with finish_reason="stop" (the stop token is
                     the last entry of token_ids).
--logprobs           attach per-token logprobs to each RequestOutput.
--share-prefix       cross-request prefix caching (continuous engine, purely
                     paged archs only): prompts share a system prefix of
                     --shared-prefix-len tokens, later requests reuse its
                     cached blocks and start prefill at the matched boundary;
                     the report line gains the prefix-cache hit rate.

Observability (continuous engine only):
--trace-out PATH     record a Chrome trace-event JSON of the whole run —
                     one track per engine phase (admission / prefix-match /
                     prefill / decode / sample host-sync) plus a lifecycle
                     span per request with preemption/resume annotations.
                     Open it in Perfetto (ui.perfetto.dev) or
                     chrome://tracing.
--prom-out PATH      write the final metrics registry as Prometheus text
                     exposition.
--metrics-every S    with --metrics-out: append a windowed-signal JSONL
                     snapshot to <metrics-out>.jsonl every S seconds of
                     engine time (atomic rewrite per snapshot).
--metrics-window S   sliding-window length for the workload signal vector
                     (arrival rate / prompt mix / prefix hit rate / block
                     pressure; default 10s).
"""
from __future__ import annotations

import argparse
import collections
import sys

import jax
import numpy as np

from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("wave", "continuous"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged-KV block size (continuous engine)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prompt tokens prefilled per engine step")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="physical KV blocks (default: slots*max_len worth)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed; request i uses seed+i")
    ap.add_argument("--stop", default=None,
                    help="comma-separated stop token ids (finish_reason="
                         "'stop' when one is sampled)")
    ap.add_argument("--logprobs", action="store_true",
                    help="attach per-token logprobs to each RequestOutput")
    ap.add_argument("--share-prefix", action="store_true",
                    help="continuous engine only: reuse cached KV blocks "
                         "across requests sharing a prompt prefix")
    ap.add_argument("--shared-prefix-len", type=int, default=None,
                    help="with --share-prefix: length of the common system "
                         "prefix prepended to every prompt (default: "
                         "prompt-len, i.e. suffixes of 4 unique tokens)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the continuous engine's JSON metrics here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON (Perfetto) of the "
                         "run: per-phase tracks + per-request spans")
    ap.add_argument("--prom-out", default=None,
                    help="write the final metrics as Prometheus text "
                         "exposition")
    ap.add_argument("--metrics-every", type=float, default=None,
                    help="with --metrics-out: append a windowed-signal JSONL "
                         "snapshot to <metrics-out>.jsonl every S seconds of "
                         "engine time")
    ap.add_argument("--metrics-window", type=float, default=10.0,
                    help="sliding-window seconds for the workload signal "
                         "vector (default 10)")
    ap.add_argument("--sanitize", action="store_true",
                    help="continuous engine only: attach the paged-cache "
                         "sanitizer (analysis/sanitizer.py) — records "
                         "allocation sites, cross-validates refcounts "
                         "against block tables and the prefix index every "
                         "step, and fails loudly on leaks/double-frees at "
                         "drain; prints an activity report")
    args = ap.parse_args()
    if args.metrics_every is not None and not args.metrics_out:
        ap.error("--metrics-every needs --metrics-out (snapshots go to "
                 "<metrics-out>.jsonl)")

    arch = get_arch(args.arch)
    if args.smoke:
        arch = reduce_for_smoke(arch)
    params = T.init_lm(jax.random.PRNGKey(0), arch)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    if args.share_prefix:
        plen = (args.prompt_len if args.shared_prefix_len is None
                else args.shared_prefix_len)
        shared = rng.integers(1, arch.vocab, size=plen).astype(np.int32)
        prompts = [np.concatenate(
            [shared, rng.integers(1, arch.vocab, size=4).astype(np.int32)])
            for _ in range(args.requests)]
    else:
        prompts = [rng.integers(1, arch.vocab, size=args.prompt_len)
                   .astype(np.int32) for _ in range(args.requests)]

    if args.engine == "wave":
        if (args.temperature != 0.0 or args.top_k != 0 or args.top_p != 1.0
                or args.seed != 0 or args.stop or args.logprobs):
            ap.error("--engine wave is greedy-only (the legacy API has no "
                     "sampling field): drop the sampling flags or use "
                     "--engine continuous")
        if args.trace_out or args.prom_out or args.metrics_every is not None:
            ap.error("--trace-out/--prom-out/--metrics-every need the "
                     "continuous engine (the wave shim exposes no "
                     "telemetry): use --engine continuous")
        if args.sanitize:
            ap.error("--sanitize needs the continuous engine (the wave "
                     "shim exposes no cache hooks): use --engine "
                     "continuous")
        from repro.runtime.server import Request, Server
        server = Server(arch, params, mesh, slots=args.slots,
                        max_len=args.max_len,
                        block_size=args.block_size,
                        num_blocks=args.num_blocks,
                        prefill_chunk=args.prefill_chunk)
        for i, p in enumerate(prompts):
            server.submit(Request(id=i, prompt=p,
                                  max_new_tokens=args.max_new))
        wall = server.run_until_drained()
        total = sum(len(r.out_tokens) for r in server.completed)
        print(f"[wave-shim] {len(server.completed)} requests, {total} "
              f"tokens, {wall:.2f}s wall ({total / max(wall, 1e-9):.1f} "
              f"tok/s host-wall), {server.decode_steps} decode steps "
              f"(continuous engine under the hood)")
        return

    from repro.serving import (ChromeTracer, ContinuousBatchingEngine,
                               Request, SamplingParams, ServingMetrics,
                               SnapshotWriter, prometheus_text)
    from repro.serving.export import atomic_write_text
    stop_ids = (tuple(int(s) for s in args.stop.split(","))
                if args.stop else ())
    tracer = ChromeTracer() if args.trace_out else None
    snapshot = (SnapshotWriter(args.metrics_out + ".jsonl",
                               every_s=args.metrics_every)
                if args.metrics_every is not None else None)
    sanitizer = None
    if args.sanitize:
        from repro.analysis.sanitizer import CacheSanitizer
        sanitizer = CacheSanitizer()
    engine = ContinuousBatchingEngine(
        arch, params, mesh, slots=args.slots, max_len=args.max_len,
        block_size=args.block_size, num_blocks=args.num_blocks,
        prefill_chunk=args.prefill_chunk, share_prefix=args.share_prefix,
        metrics=ServingMetrics(window_s=args.metrics_window),
        tracer=tracer, snapshot=snapshot, sanitizer=sanitizer)

    def flush_artifacts(out=sys.stdout) -> None:
        """Write every requested artifact through its atomic path.  One
        function for BOTH exits: the success epilogue below and the
        crash path — an engine raise mid-drain must still leave complete,
        loadable trace/metrics/prometheus files (what it captured up to
        the failure), never a stranded half-written snapshot cycle."""
        if args.metrics_out:
            engine.metrics.write(args.metrics_out, engine="continuous",
                                 arch=arch.name)
            print(f"metrics -> {args.metrics_out}", file=out)
        if snapshot is not None:
            snapshot.write(engine.metrics)   # final flush past the cadence
            print(f"snapshots -> {snapshot.path} "
                  f"({snapshot.n_snapshots} lines)", file=out)
        if tracer is not None:
            tracer.write(args.trace_out)
            print(f"trace -> {args.trace_out} (open in ui.perfetto.dev)",
                  file=out)
        if args.prom_out:
            atomic_write_text(args.prom_out, prometheus_text(engine.metrics))
            print(f"prometheus -> {args.prom_out}", file=out)

    try:
        outs = engine.generate([
            Request(id=i, prompt=p, max_new_tokens=args.max_new,
                    sampling=SamplingParams(temperature=args.temperature,
                                            top_k=args.top_k,
                                            top_p=args.top_p,
                                            seed=args.seed + i,
                                            stop_token_ids=stop_ids,
                                            logprobs=args.logprobs))
            for i, p in enumerate(prompts)])
    except Exception as e:
        print(f"engine failed mid-drain: {type(e).__name__}: {e}",
              file=sys.stderr)
        try:
            flush_artifacts(out=sys.stderr)
        except Exception as flush_err:       # the crash exit must survive
            print(f"artifact flush failed: {flush_err}", file=sys.stderr)
        raise SystemExit(1)
    s = engine.metrics.summary()
    reasons = collections.Counter(o.finish_reason for o in outs)
    share = (f", prefix hit rate {s['prefix_hit_rate']:.2f}"
             if args.share_prefix else "")
    mode = ("greedy" if args.temperature == 0 else
            f"T={args.temperature} top_k={args.top_k} top_p={args.top_p} "
            f"seed={args.seed}")

    def ms(x):                       # None-safe: "no data" is not 0.0ms
        return "n/a" if x is None else f"{x * 1e3:.1f}ms"

    print(f"[continuous/{mode}] {s['completed']} requests, "
          f"{s['total_tokens']} tokens, "
          f"{s['decode_steps']} decode steps / {s['prefill_chunks']} prefill "
          f"chunks, ttft mean {ms(s['ttft_mean_s'])} "
          f"p50 {ms(s['ttft_p50_s'])} p95 {ms(s['ttft_p95_s'])} "
          f"p99 {ms(s['ttft_p99_s'])}, tpot p50 {ms(s['tpot_p50_s'])}, "
          f"occupancy {s['slot_occupancy_mean']*100:.0f}%, block util "
          f"{s['block_utilization_mean']:.2f}, "
          f"{s['preemptions']} preemptions, finish reasons "
          f"{dict(reasons)}{share}")
    for o in outs[:3]:
        lp = (f" logprobs[:3]={[round(x, 3) for x in o.logprobs[:3]]}"
              if o.logprobs else "")
        print(f"  req {o.request_id} [{o.finish_reason}] "
              f"{o.token_ids}{lp}")
    flush_artifacts()
    if sanitizer is not None:
        # reaching this line means every per-step and drain check passed
        print(f"sanitizer: clean ({sanitizer.report()})")


if __name__ == "__main__":
    main()
