"""Serving launcher CLI (wave-batched greedy decoding).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --requests 8 --prompt-len 16 --max-new 12
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.runtime.server import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.smoke:
        arch = reduce_for_smoke(arch)
    params = T.init_lm(jax.random.PRNGKey(0), arch)
    mesh = make_host_mesh()
    server = Server(arch, params, mesh, slots=args.slots,
                    max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        server.submit(Request(
            id=i,
            prompt=rng.integers(1, arch.vocab,
                                size=args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    wall = server.run_until_drained()
    total = sum(len(r.out_tokens) for r in server.completed)
    print(f"{len(server.completed)} requests, {total} tokens, "
          f"{wall:.2f}s wall ({total / max(wall, 1e-9):.1f} tok/s host-wall), "
          f"{server.waves} waves / {server.decode_steps} decode steps")


if __name__ == "__main__":
    main()
