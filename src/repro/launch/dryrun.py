import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell: build the ASA plan,
lower + compile the real step function against ShapeDtypeStruct stand-ins
(no allocation), print memory_analysis / cost_analysis, and parse the
collective schedule out of the partitioned HLO for §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.

The two lines above the docstring request 512 placeholder devices BEFORE
jax initializes (jax locks the device count on first init; consequently no
`from __future__ import annotations` in this module).
"""
import argparse
import functools
import json
import pathlib
import re
import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.core import components as C
from repro.core import sharding as SH
from repro.core.asa import AdaptiveScheduler
from repro.launch.mesh import make_production_mesh, mesh_shape_of
from repro.models import transformer as T
from repro.optim import optimizers as O
from repro.runtime import steps as ST

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# archs whose optimizer states only fit with int8 moments (DESIGN.md §7)
QUANTIZED_OPT = {"arctic-480b", "deepseek-v3-671b"}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of_shape_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s(\w[\w\-]*)\(")
_CALLEE_RE = re.compile(r"(?:body|to_apply|branch_computations|called_computations)="
                        r"\{?%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Split the HLO module into computations.  Headers look like
    `%region_0.123 (arg: (s32[], ...)) -> (...) {` — names captured up to the
    first '(' (arg types may contain nested parens)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{") and not line.startswith("  "):
                cur = m.group(1)
                comps[cur] = []
        else:
            comps[cur].append(line)
            if stripped == "}":
                cur = None
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic: the loop bound is the largest integer constant compared
    against in the condition computation."""
    best = 1
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
    return best


def parse_collectives(hlo_text: str) -> dict:
    """Trip-count-aware collective accounting: result bytes of every
    collective op, scaled by the product of enclosing while-loop trip counts
    (scan bodies appear once in HLO but execute trip times).  Per-device
    traffic; x chips = fabric-total."""
    comps = _split_computations(hlo_text)

    import functools as _ft

    @_ft.lru_cache(maxsize=None)
    def totals(comp_name: str) -> tuple:
        acc = {k: [0, 0] for k in _COLLECTIVES}
        for line in comps.get(comp_name, ()):
            m = _OP_RE.match(line)
            if not m:
                continue
            shp, opname = m.group(2), m.group(3)
            matched = False
            for coll in _COLLECTIVES:
                if opname.replace("_", "-").startswith(coll):
                    acc[coll][0] += _bytes_of_shape_str(shp)
                    acc[coll][1] += 1
                    matched = True
                    break
            if matched:
                continue
            if opname == "while":
                bm = _CALLEE_RE.search(line)
                cm = _COND_RE.search(line)
                if bm:
                    trips = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                    sub = totals(bm.group(1))
                    for k, (b, c) in zip(_COLLECTIVES, sub):
                        acc[k][0] += trips * b
                        acc[k][1] += trips * c
            else:
                for callee in _CALLEE_RE.findall(line):
                    if callee in comps:
                        sub = totals(callee)
                        for k, (b, c) in zip(_COLLECTIVES, sub):
                            acc[k][0] += b
                            acc[k][1] += c
        return tuple((acc[k][0], acc[k][1]) for k in _COLLECTIVES)

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    out = {}
    res = totals(entry) if entry else tuple((0, 0) for _ in _COLLECTIVES)
    for k, (b, c) in zip(_COLLECTIVES, res):
        out[k] = {"bytes": int(b), "count": int(c)}
    out["total_bytes"] = int(sum(b for b, _ in res))
    return out


def _sds(tree, specs, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda leaf, s: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)


def build_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
               faithful: bool = False, remat: Optional[str] = None,
               seq_shard: bool = False, opt8bit: bool = False,
               moe_ep: bool = False):
    """Construct (fn, args_sds, plan, meta) for one dry-run cell.

    seq_shard=True turns on Megatron-style sequence parallelism: layer
    boundary activations sharded over `model` on the sequence axis (§Perf).
    opt8bit=True forces int8 optimizer moments (halves state memory — opens
    uniform-DP plans for small models, §Perf).
    """
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ms = mesh_shape_of(mesh)
    opt_preset = ("adamw8bit" if (arch_name in QUANTIZED_OPT or opt8bit)
                  else "adamw32")
    # seq-sharding the scan carry breaks on SSM families (the conv/scan mix
    # tokens across shard boundaries -> GSPMD gathers); keep batch-only there
    seq_ok = seq_shard and arch.family not in ("ssm", "hybrid") \
        and shape.kind != "decode" and shape.seq_len % ms.model == 0
    from repro.core import sharding as SHmod
    from repro.models import moe as moe_mod
    if moe_ep and arch.moe is not None:
        SHmod.MOE_EP_AXIS = "data"
        moe_mod.EP_CONSTRAINTS = ("data", "model",
                                  SH.batch_axes(ms, shape.global_batch))
    else:
        SHmod.MOE_EP_AXIS = "model"
        moe_mod.EP_CONSTRAINTS = None

    sched = AdaptiveScheduler(faithful=faithful, opt_preset=opt_preset,
                              remat="full", seq_sharded=seq_ok,
                              moe_ep=(moe_ep and arch.moe is not None))
    plan = sched.plan(arch, shape, ms)

    pspecs = plan.param_specs()
    params_sds = _sds(C.abstract_params(arch), pspecs, mesh)
    B, S = shape.global_batch, shape.seq_len
    # FS and uniform-DP shard the batch over every mesh axis
    full_batch = plan.uniform in ("FS", "DP") and shape.kind == "train"
    tok_ns = NamedSharding(mesh, SH.token_spec(ms, B, full=full_batch))
    # layer-boundary activation sharding constraint (seq-sharding is
    # meaningless under FS/uniform-DP where `model` already carries batch)
    seq_ok = seq_ok and not full_batch
    act_ns = NamedSharding(mesh, P(SH.batch_axes(ms, B, full=full_batch),
                                   "model" if seq_ok else None, None))

    fe_sds = None
    if arch.frontend == "vision":
        fe_sds = jax.ShapeDtypeStruct((B, arch.n_img_tokens, arch.d_model),
                                      jnp.bfloat16, sharding=tok_ns.update(
                                          spec=P(tok_ns.spec[0], None, None)))
    elif arch.frontend == "audio":
        fe_sds = jax.ShapeDtypeStruct((B, arch.encoder.seq_len, arch.d_model),
                                      jnp.bfloat16, sharding=tok_ns.update(
                                          spec=P(tok_ns.spec[0], None, None)))

    meta = {"arch": arch_name, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "opt_preset": opt_preset, "microbatches": plan.microbatches,
            "seq_shard": seq_ok,
            "method": plan.plan.method, "feasible": plan.plan.feasible,
            "predicted": plan.plan.cost,
            "assignment": {k: str(v) for k, v in plan.assignment.items()}}

    if shape.kind == "train":
        # "full" per-layer remat inside the layer scan: O(1) activation
        # memory in depth — the production default for these model sizes
        # ("selective" saves every dot output; see EXPERIMENTS.md §Perf)
        remat_policy = remat or "full"
        if plan.uniform == "FS" and remat is None:
            # FS: per-device batch is 1 — activations are tiny, so skip
            # grad accumulation (halves ZeRO gathers + grad reductions).
            # Keep full remat: under "selective" XLA holds every layer's
            # *gathered* weights for backward (53 GB/dev temps, §Perf it.3)
            plan.microbatches = 1
        opt_init, _ = optimizer = O.adamw(
            1e-4, quantized=(opt_preset == "adamw8bit"))
        opt_sds_raw = jax.eval_shape(opt_init, C.abstract_params(arch))
        opt_specs = SH.opt_state_specs(opt_sds_raw, pspecs, ms)
        opt_sds = _sds(opt_sds_raw, opt_specs, mesh)
        grad_ns = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        step = ST.make_train_step(arch, optimizer,
                                  microbatches=plan.microbatches,
                                  remat=remat_policy, act_sharding=act_ns,
                                  grad_shardings=grad_ns)
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_ns)
        batch = {"tokens": tok, "labels": tok}
        if fe_sds is not None:
            batch["frontend"] = fe_sds
        out_shardings = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                         jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      opt_specs),
                         None)
        fn = ST.jit_step("train", step, out_shardings=out_shardings)
        args = (params_sds, opt_sds, batch)
        meta["remat"] = remat_policy
    else:
        cache_sds_raw = jax.eval_shape(
            functools.partial(T.init_cache, arch, B, S, jnp.bfloat16))
        cspecs = plan.cache_specs(B)
        cache_sds = _sds(cache_sds_raw, cspecs, mesh)
        if shape.kind == "prefill":
            pstep = ST.make_prefill_step(arch, act_sharding=act_ns)
            tok = jax.ShapeDtypeStruct((B, S), jnp.int32, sharding=tok_ns)
            if fe_sds is not None:
                fn = ST.jit_step("prefill", lambda p, c, t, f: pstep(p, c, t, f))
                args = (params_sds, cache_sds, tok, fe_sds)
            else:
                fn = ST.jit_step("prefill", lambda p, c, t: pstep(p, c, t))
                args = (params_sds, cache_sds, tok)
        else:  # decode
            dstep = ST.make_decode_step(arch, act_sharding=act_ns)
            tok = jax.ShapeDtypeStruct((B, 1), jnp.int32, sharding=tok_ns)
            fn = ST.jit_step("decode", dstep)
            args = (params_sds, cache_sds, tok)
    return fn, args, plan, meta, mesh


def model_flops(arch_name: str, shape_name: str) -> float:
    """6·N·D (train) / 2·N·D (inference), N = active params."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    n_active = C.active_param_count(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch       # decode: 1 token/seq


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             save: bool = True, seq_shard: bool = False,
             opt8bit: bool = False, moe_ep: bool = False,
             tag: str = "") -> dict:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    rec = {"arch": arch_name, "shape": shape_name, "tag": tag,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        _save(rec, save)
        return rec

    t0 = time.time()
    fn, args, plan, meta, mesh = build_cell(arch_name, shape_name,
                                            multi_pod=multi_pod,
                                            seq_shard=seq_shard,
                                            opt8bit=opt8bit, moe_ep=moe_ep)
    rec.update(meta)
    try:
        with jax.set_mesh(mesh):   # ambient mesh for bare-P constraints
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failure here is a bug in our sharding config
        rec.update({"status": "FAILED", "error": f"{type(e).__name__}: {e}"})
        _save(rec, save)
        return rec

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes") if hasattr(mem, k)}
        print(f"memory_analysis: {rec['memory']}")
    except Exception as e:
        rec["memory"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
        print(f"cost_analysis[flops]: {rec['cost_analysis'].get('flops')}")
    except Exception as e:
        rec["cost_analysis"] = {"error": str(e)}

    rec["collectives"] = parse_collectives(compiled.as_text())
    rec["model_flops"] = model_flops(arch_name, shape_name)
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    rec["status"] = "ok"
    _save(rec, save)
    return rec


def _save(rec: dict, save: bool):
    if not save:
        return
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    name = (f"{rec['arch']}__{rec['shape']}__"
            f"{rec['mesh'].replace('x', '_')}{tag}.json")
    (OUT_DIR / name).write_text(json.dumps(rec, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel activations (optimized mode)")
    ap.add_argument("--opt8bit", action="store_true",
                    help="int8 optimizer moments for any arch")
    ap.add_argument("--moe-ep", action="store_true",
                    help="EP-major MoE layout (a2a dispatch, no gathers)")
    ap.add_argument("--tag", default="", help="suffix for result files")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    n_fail = 0
    for a, s in cells:
        print(f"\n=== dry-run {a} x {s} ({'2x16x16' if args.multi_pod else '16x16'}) ===",
              flush=True)
        rec = run_cell(a, s, multi_pod=args.multi_pod, save=not args.no_save,
                       seq_shard=args.seq_shard, opt8bit=args.opt8bit,
                       moe_ep=args.moe_ep, tag=args.tag)
        print(f"-> {rec['status']} "
              f"(lower {rec.get('lower_s', '-')}s, compile {rec.get('compile_s', '-')}s) "
              f"coll={rec.get('collectives', {}).get('total_bytes', 0)/1e9:.2f}GB/dev "
              + (rec.get("reason", "") or rec.get("error", "")), flush=True)
        n_fail += rec["status"] == "FAILED"
    print(f"\ndry-run finished: {len(cells)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
