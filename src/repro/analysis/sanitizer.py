"""Runtime paged-cache sanitizer: ASan for the block allocator.

reprolint (analysis/lint.py) proves what it can from program structure;
this module covers the dynamic remainder.  Cross-function refcount
pairing — the prefix index holding exactly one reference per committed
block, block tables and the LRU partitioning ownership, release()
retiring shared blocks instead of freeing them — cannot be checked
intraprocedurally, so in sanitize mode every engine step cross-validates
the allocator's refcounts against the *independent* ground truth (live
block tables + content index + LRU), and every allocation records the
host stack that made it, so a violation reports WHERE the blocks came
from, not just that counts disagree.

Detected bug classes (each one has a mutation-injection test in
tests/test_analysis.py asserting the report fires):

  double-free / foreign free    ``decref`` on a block with no refcount —
                                reported with the allocation site AND the
                                site of the earlier free.
  invalid incref                referencing the null block or a freed
                                block (a stale table row about to share
                                garbage).
  refcount/table mismatch       allocator refcount != (#tables holding
                                the block) + (1 if content-indexed) —
                                a stranded or lost reference.
  null-block write              a slot's resident-token position exceeds
                                its table capacity, so the next device
                                write lands in reserved block 0.
  leaked blocks at drain        allocated blocks neither LRU-cached nor
                                owned by any table once the engine is
                                empty.

Zero-cost when off: the allocator's ``observer`` is None in production
and every hook is behind one attribute check; nothing here imports until
the engine is constructed with ``sanitizer=`` or REPRO_SANITIZE=1.
"""
from __future__ import annotations

import traceback
from typing import Optional

from repro.serving.paged_cache import NULL_BLOCK, PagedKVCache

# frames from these files are machinery, not the interesting caller
_INTERNAL_FRAMES = ("analysis/sanitizer.py", "serving/paged_cache.py")


def _capture_site(depth: int) -> tuple:
    frames = traceback.extract_stack()
    keep = [f for f in frames
            if not f.filename.replace("\\", "/").endswith(_INTERNAL_FRAMES)]
    return tuple(f"{f.filename}:{f.lineno} in {f.name}"
                 for f in keep[-depth:])


def _fmt_site(site: Optional[tuple]) -> str:
    if not site:
        return "<unknown>"
    return "\n    ".join(site)


class SanitizerError(RuntimeError):
    """A paged-cache invariant violation, with allocation backtraces."""


class CacheSanitizer:
    """Attachable invariant checker for one PagedKVCache.

    ``attach(cache)`` installs this object as the BlockAllocator's
    observer; the engine then calls ``check_engine_step`` after every
    step and ``check_drained`` when run_until_drained empties.  All
    checks raise :class:`SanitizerError` with every violation found and
    the recorded allocation sites.
    """

    def __init__(self, *, site_depth: int = 5):
        self.site_depth = site_depth
        self.cache: Optional[PagedKVCache] = None
        self._alloc_site: dict[int, tuple] = {}   # block -> host stack
        self._free_site: dict[int, tuple] = {}    # block -> last rc->0 stack
        self.counters = {"allocs": 0, "increfs": 0, "decrefs": 0,
                         "frees": 0, "step_checks": 0, "violations": 0}

    def attach(self, cache: PagedKVCache) -> "CacheSanitizer":
        self.cache = cache
        cache.allocator.observer = self
        return self

    # -- allocator observer hooks (see BlockAllocator) -------------------
    def on_alloc(self, blocks: list) -> None:
        site = _capture_site(self.site_depth)
        for b in blocks:
            self._alloc_site[b] = site
            self._free_site.pop(b, None)
        self.counters["allocs"] += len(blocks)

    def on_incref(self, block: int, refcount: int) -> None:
        self.counters["increfs"] += 1

    def on_decref(self, block: int, refcount: int) -> None:
        self.counters["decrefs"] += 1
        if refcount == 0:
            self.counters["frees"] += 1
            self._free_site[block] = _capture_site(self.site_depth)

    def on_invalid_free(self, block: int) -> None:
        if block == NULL_BLOCK:
            self._fail([f"free of the reserved null block {NULL_BLOCK}"])
        self._fail([
            f"double free / foreign free of block {block}\n"
            f"  allocated at:\n    {_fmt_site(self._alloc_site.get(block))}\n"
            f"  previously freed at:\n"
            f"    {_fmt_site(self._free_site.get(block))}\n"
            f"  second free at:\n"
            f"    {_fmt_site(_capture_site(self.site_depth))}"])

    def on_invalid_incref(self, block: int) -> None:
        if block == NULL_BLOCK:
            self._fail([f"incref of the reserved null block {NULL_BLOCK}"])
        self._fail([
            f"incref of unallocated block {block} (stale reference)\n"
            f"  last freed at:\n"
            f"    {_fmt_site(self._free_site.get(block))}\n"
            f"  incref at:\n    {_fmt_site(_capture_site(self.site_depth))}"])

    def _fail(self, problems: list) -> None:
        self.counters["violations"] += len(problems)
        head = f"paged-cache sanitizer: {len(problems)} invariant " \
               f"violation{'s' if len(problems) != 1 else ''}"
        raise SanitizerError("\n".join([head] + [f"- {p}" for p in problems]))

    def _where(self, block: int) -> str:
        return f" (allocated at:\n    " \
               f"{_fmt_site(self._alloc_site.get(block))})"

    # -- invariant checks -------------------------------------------------
    def check_cache(self, cache: Optional[PagedKVCache] = None) -> None:
        """Cross-validate the allocator against its independent ground
        truth: block tables, content index, and LRU.  The refcount of
        every allocated block must equal the number of tables holding it
        plus one if the content index does — any other value is a
        stranded or lost reference that will surface later as a leak or
        a shared-garbage read."""
        cache = cache if cache is not None else self.cache
        if cache is None:
            raise RuntimeError("sanitizer not attached to a cache")
        alloc = cache.allocator
        free, ref = alloc._free, alloc._ref
        problems: list = []

        if NULL_BLOCK in ref or NULL_BLOCK in free:
            problems.append(f"reserved null block {NULL_BLOCK} entered the "
                            f"allocator")
        if len(set(free)) != len(free):
            dups = sorted(b for b in set(free) if free.count(b) > 1)
            problems.append(f"free list holds duplicates: {dups}")
        both = set(free) & set(ref)
        if both:
            problems.append(f"blocks simultaneously free and allocated: "
                            f"{sorted(both)}")
        if len(free) + len(ref) != alloc.num_blocks - 1:
            problems.append(
                f"block conservation broken: {len(free)} free + "
                f"{len(ref)} allocated != {alloc.num_blocks - 1} usable")

        # ground-truth reference ownership per block
        expected: dict[int, int] = {}
        for rid, table in cache.tables.items():
            if NULL_BLOCK in table:
                problems.append(f"request {rid} table contains the null "
                                f"block")
            if len(set(table)) != len(table):
                problems.append(f"request {rid} table holds duplicate "
                                f"physical blocks: {table}")
            for b in table:
                expected[b] = expected.get(b, 0) + 1
                if b != NULL_BLOCK and b not in ref:
                    problems.append(f"request {rid} table references freed "
                                    f"block {b}{self._where(b)}")
        for b in cache._block_to_hash:
            expected[b] = expected.get(b, 0) + 1

        for b, rc in ref.items():
            exp = expected.get(b, 0)
            if rc != exp:
                holders = [rid for rid, t in cache.tables.items() if b in t]
                problems.append(
                    f"refcount mismatch on block {b}: allocator says {rc}, "
                    f"tables {holders} + "
                    f"{'the content index' if b in cache._block_to_hash else 'no index entry'}"
                    f" account for {exp}{self._where(b)}")

        for b in cache._lru:
            if b not in cache._block_to_hash:
                problems.append(f"LRU-cached block {b} is not content-"
                                f"indexed{self._where(b)}")
            if alloc.refcount(b) != 1:
                problems.append(f"LRU-cached block {b} has refcount "
                                f"{alloc.refcount(b)}, expected exactly the "
                                f"index's 1{self._where(b)}")
            holders = [rid for rid, t in cache.tables.items() if b in t]
            if holders:
                problems.append(f"LRU-cached block {b} still held by live "
                                f"requests {holders}{self._where(b)}")

        for key, b in cache._hash_to_block.items():
            if cache._block_to_hash.get(b) != key:
                problems.append(f"content index asymmetry: hash->block {b} "
                                f"but block->hash disagrees")
            if b not in ref:
                problems.append(f"content index references freed block "
                                f"{b}{self._where(b)}")
        for b, key in cache._block_to_hash.items():
            if cache._hash_to_block.get(key) != b:
                problems.append(f"content index asymmetry: block {b} -> key "
                                f"not mapping back")

        for rid in cache._committed:
            if rid not in cache.tables:
                problems.append(f"commit cursor for request {rid} survives "
                                f"its table (release() missed it)")

        if problems:
            self._fail(problems)

    def check_engine_step(self, engine) -> None:
        """Per-step engine-level checks layered over check_cache: every
        busy slot's resident position must fit its block table (one token
        past the end means the next device write scatters into reserved
        block 0 — the null-block-write class)."""
        self.check_cache(engine.cache)
        bs = engine.cache.cfg.block_size
        problems: list = []
        for slot in engine.slots:
            if not slot.busy:
                continue
            rid = slot.req.id
            table = engine.cache.tables.get(rid)
            if table is None:
                problems.append(f"slot {slot.idx} runs request {rid} which "
                                f"owns no block table")
            elif slot.pos > len(table) * bs:
                problems.append(
                    f"null-block write: slot {slot.idx} (request {rid}) is "
                    f"at position {slot.pos} but its table covers only "
                    f"{len(table) * bs} tokens ({len(table)} blocks x {bs}) "
                    f"— the next cache write lands in reserved block "
                    f"{NULL_BLOCK}")
            if rid not in engine._states:
                problems.append(f"slot {slot.idx} runs request {rid} which "
                                f"the engine no longer tracks")
        self.counters["step_checks"] += 1
        if problems:
            self._fail(problems)

    def check_drained(self, engine) -> None:
        """After run_until_drained: no request may own blocks, and every
        still-allocated block must be an LRU-cached prefix block (exactly
        the content index's single reference).  Anything else leaked —
        reported with the stack that allocated it.  The drain checks run
        BEFORE the generic cross-validation: a leaked block also shows up
        as a refcount mismatch, and "leaked at drain + allocation site"
        is the actionable report."""
        cache = engine.cache
        problems: list = []
        if cache.tables:
            problems.append(f"drained engine still owns block tables for "
                            f"requests {sorted(cache.tables)}")
        for b in sorted(cache.allocator._ref):
            if b not in cache._lru:
                problems.append(
                    f"leaked block {b} (refcount "
                    f"{cache.allocator.refcount(b)}): allocated but neither "
                    f"freed nor LRU-cached at drain{self._where(b)}")
        if problems:
            self._fail(problems)
        self.check_cache(cache)

    def report(self) -> dict:
        """JSON-able activity summary (surfaced by launch/serve.py
        --sanitize and the tests)."""
        return dict(self.counters,
                    attached=self.cache is not None,
                    tracked_blocks=len(self._alloc_site))
