"""Lowered-IR extraction for the jitted serving steps.

The jax-facing half of tracecheck: builds ShapeDtypeStruct stand-ins for
every registered serving step (make_paged_prefill_step /
make_paged_decode_step / make_slot_admit_step) at the engine's real call
shapes, lowers + compiles them (no allocation), and extracts the raw
facts — donation flags, buffer aliasing, primitive census, output
structure/shardings, XLA cost analysis — that the analyzers in
``repro.analysis.tracecheck`` turn into findings.

Everything here is pure extraction: no thresholds, no verdicts.  The
engine's geometry conventions are mirrored exactly (prefill is a B=1
chunk, decode advances every slot, block tables are padded to
``max_blocks_per_seq``), so what gets lowered IS what serves.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.asa import AdaptiveScheduler
from repro.launch.mesh import mesh_shape_of
from repro.models import transformer as T
from repro.runtime import steps as ST
from repro.serving.cache_manager import SLOT_STATE_KINDS
from repro.serving.paged_cache import blocks_for
from repro.serving.sampling import make_sampler


@dataclasses.dataclass(frozen=True)
class ServeGeom:
    """One serving geometry: the shapes every step is traced at.

    ``table_len`` (= max_blocks_per_seq * block_size) is the padded
    attention span — paged attention scores every query against that full
    (masked) capacity, which makes it the effective T for static cost.
    """
    slots: int = 4
    max_len: int = 64
    block_size: int = 8
    prefill_chunk: int = 16

    @property
    def max_blocks_per_seq(self) -> int:
        return blocks_for(self.max_len, self.block_size)

    @property
    def num_blocks(self) -> int:
        return self.slots * self.max_blocks_per_seq + 1      # +1: null block

    @property
    def table_len(self) -> int:
        return self.max_blocks_per_seq * self.block_size


def step_kinds(arch: ArchConfig) -> tuple[str, ...]:
    """The jitted step kinds the engine registers for this arch."""
    kinds = {k for seg in arch.pattern for k in seg.blocks}
    out = ("paged_prefill", "paged_decode")
    if kinds & SLOT_STATE_KINDS:
        out += ("slot_admit",)
    return out


def build_plan(arch: ArchConfig, geom: ServeGeom, mesh):
    """The same ASA plan the engine builds for this serve shape."""
    shape = ShapeSpec("serve", geom.max_len, geom.slots, "decode")
    return AdaptiveScheduler(faithful=False).plan(
        arch, shape, mesh_shape_of(mesh))


def _cache_dtype(arch: ArchConfig):
    return jnp.float32 if arch.dtype == "float32" else jnp.bfloat16


def _attach(tree, specs, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dryrun idiom)."""
    return jax.tree.map(
        lambda leaf, s: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                             sharding=NamedSharding(mesh, s)),
        tree, specs)


def _sds(shape, dtype, mesh=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, P()))


def frontend_sds(arch: ArchConfig, mesh=None) -> Optional[jax.ShapeDtypeStruct]:
    """Admission-time modality input, iff the arch consumes one: vision
    patch embeddings or audio frame embeddings (see transformer.admit_slot)."""
    if arch.frontend == "vision":
        return _sds((1, arch.n_img_tokens, arch.d_model), jnp.float32, mesh)
    if arch.frontend == "audio":
        return _sds((1, arch.encoder.seq_len, arch.d_model), jnp.float32, mesh)
    return None


def step_arguments(arch: ArchConfig, kind: str, geom: ServeGeom, *,
                   mesh=None, plan=None) -> tuple:
    """ShapeDtypeStruct argument tuple for one step kind, at exactly the
    shapes serving/engine.py calls it with.  With ``mesh`` the params and
    cache carry the plan's NamedShardings (host-side operands replicated),
    mirroring the device_put layout of a live engine."""
    if mesh is not None and plan is None:
        plan = build_plan(arch, geom, mesh)
    params = jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), arch))
    cache = jax.eval_shape(lambda: T.init_paged_cache(
        arch, geom.num_blocks, geom.block_size, dtype=_cache_dtype(arch),
        slots=geom.slots))
    if mesh is not None:
        params = _attach(params, plan.param_specs(), mesh)
        cache = _attach(cache, plan.paged_cache_specs(), mesh)

    if kind == "slot_admit":
        args = (params, cache, _sds((), jnp.int32, mesh))
        fe = frontend_sds(arch, mesh)
        return args + ((fe,) if fe is not None else ())

    B = 1 if kind == "paged_prefill" else geom.slots
    S = geom.prefill_chunk if kind == "paged_prefill" else 1
    args = (params, cache,
            _sds((B, S), jnp.int32, mesh),                    # tokens
            _sds((B,), jnp.int32, mesh))                      # positions
    args += (_sds((B, geom.max_blocks_per_seq), jnp.int32, mesh),)
    if kind == "paged_prefill":
        args += (_sds((B,), jnp.int32, mesh),)                # new_lens
    args += (_sds((B,), jnp.int32, mesh),)                    # slot_ids
    # fused per-row sampler parameters (temperature, top_k, top_p, seeds)
    args += (_sds((B,), jnp.float32, mesh), _sds((B,), jnp.int32, mesh),
             _sds((B,), jnp.float32, mesh), _sds((B,), jnp.uint32, mesh))
    return args


def build_step_fn(arch: ArchConfig, kind: str):
    """The un-jitted step callable the engine registers for ``kind``."""
    if kind == "paged_prefill":
        return ST.make_paged_prefill_step(arch,
                                          sampler=make_sampler(arch.vocab))
    if kind == "paged_decode":
        return ST.make_paged_decode_step(arch,
                                         sampler=make_sampler(arch.vocab))
    if kind == "slot_admit":
        return ST.make_slot_admit_step(arch)
    raise ValueError(f"unknown serving step kind {kind!r}")


@dataclasses.dataclass
class LoweredStep:
    """One step lowered (and lazily compiled) against its SDS arguments."""
    arch: ArchConfig
    kind: str
    fn: object                     # the un-jitted callable
    args: tuple                    # SDS argument tuple
    lowered: object                # jax.stages.Lowered
    _compiled: object = None

    @property
    def compiled(self):
        if self._compiled is None:
            self._compiled = self.lowered.compile()
        return self._compiled

    @property
    def cache_index(self) -> int:
        return 1                   # (params, cache, ...) for every kind


@functools.lru_cache(maxsize=None)
def _lowered_cache():
    return {}


def lower_step(arch: ArchConfig, kind: str, geom: ServeGeom, *,
               mesh=None, plan=None) -> LoweredStep:
    """Lower one serving step.  Results are memoized per
    (arch, kind, geom, meshful) — lowering is the expensive part and the
    analyzers share it freely."""
    key = (arch.name, kind, geom, mesh is not None)
    cache = _lowered_cache()
    if key not in cache:
        fn = build_step_fn(arch, kind)
        args = step_arguments(arch, kind, geom, mesh=mesh, plan=plan)
        cache[key] = LoweredStep(arch, kind, fn, args,
                                 ST.jit_step(kind, fn).lower(*args))
    return cache[key]


# ---------------------------------------------------------------------------
# extraction reports
# ---------------------------------------------------------------------------

def _leaf_bytes(leaf) -> int:
    return math.prod(leaf.shape) * jnp.dtype(leaf.dtype).itemsize \
        if leaf.shape else jnp.dtype(leaf.dtype).itemsize


def tree_bytes(tree) -> int:
    return sum(_leaf_bytes(x) for x in jax.tree.leaves(tree))


def donation_report(ls: LoweredStep) -> dict:
    """Which positional args are donated (from ``lowered.args_info``), and
    whether the runtime will actually elide them (``alias_size_in_bytes``
    of the buffer assignment)."""
    infos = ls.lowered.args_info
    # args_info mirrors the (args, kwargs) calling convention — unwrap to
    # the positional tuple (serving steps take no kwargs)
    if isinstance(infos, tuple) and len(infos) == 2 \
            and isinstance(infos[1], dict) and not infos[1]:
        infos = infos[0]
    donated, arg_bytes = [], []
    for i, info in enumerate(infos):
        leaves = jax.tree.leaves(info)
        arg_bytes.append(sum(
            math.prod(l.shape) * jnp.dtype(l.dtype).itemsize for l in leaves))
        if leaves and all(l.donated for l in leaves):
            donated.append(i)
    mem = ls.compiled.memory_analysis()
    return {
        "donated_args": tuple(donated),
        "arg_bytes": tuple(arg_bytes),
        "cache_bytes": arg_bytes[ls.cache_index],
        "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
    }


def _walk_jaxpr(jaxpr, prims: set):
    for eqn in jaxpr.eqns:
        prims.add(eqn.primitive.name)
        for v in eqn.params.values():
            for sub in _iter_subjaxprs(v):
                _walk_jaxpr(sub, prims)


def _iter_subjaxprs(value):
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _iter_subjaxprs(v)


def primitive_census(ls: LoweredStep) -> frozenset:
    """Every primitive name reachable in the step's jaxpr (recursing into
    scan/cond/remat/... sub-jaxprs)."""
    prims: set = set()
    _walk_jaxpr(jax.make_jaxpr(ls.fn)(*ls.args).jaxpr, prims)
    return frozenset(prims)


def output_structure(ls: LoweredStep):
    """ShapeDtypeStruct pytree of the step's outputs."""
    return jax.eval_shape(ls.fn, *ls.args)


def output_shardings(ls: LoweredStep):
    """Compiled output shardings, as a pytree matching output_structure."""
    return ls.compiled.output_shardings


def cost_report(ls: LoweredStep) -> dict:
    """XLA's static cost analysis of the compiled step: total FLOPs, bytes
    accessed, and the peak temp-buffer footprint."""
    ca = ls.compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # some backends wrap in a list
        ca = ca[0] if ca else {}
    mem = ls.compiled.memory_analysis()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
    }
