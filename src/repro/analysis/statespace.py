"""Generic explicit-state bounded model checking: BFS exploration with
canonical-state dedup, shortest-counterexample reconstruction, sleep-set
partial-order pruning, and graph-level temporal checks.

This module is deliberately model-agnostic — it knows nothing about
schedulers or block allocators.  A *model* is any object with:

    initial_state()            -> state
    enabled_events(state)      -> list of hashable event labels
    apply(state, event)        -> successor state (must NOT mutate state)
    canonical_key(state)       -> hashable dedup key.  Everything
                                  behavior-relevant must be in the key;
                                  monotonic telemetry counters must NOT be
                                  (or cyclic systems never reach fixpoint)
    is_accepting(state)        -> bool (e.g. "drained"): the good terminal
    check_safety(state)        -> list of (rule, message) violations
    independent(state, a, b)   -> bool, OPTIONAL: True only when a and b
                                  provably commute from ``state`` AND each
                                  stays enabled after the other

Exploration is plain breadth-first with a visited table keyed by
``canonical_key``, so the first path that discovers any state is a
shortest event sequence to it — counterexample minimization falls out of
the search order instead of needing a separate pass.

Temporal checks run on the explored graph after the search:

* **deadlock** — a non-accepting state with no enabled events (checked
  inline during the search, so a deadlock found at depth d carries a
  length-d trace).
* **livelock** — a state from which no accepting state is reachable at
  all, found by backward reachability from the accepting set.  Only
  meaningful at *fixpoint* (the search exhausted the state space rather
  than hitting a depth/state bound): on a truncated frontier a state may
  merely not have reached drain *yet*.  Sleep-set pruning can drop edges
  from the recorded graph, so every backward-unreachable candidate is
  re-confirmed by a forward search over full (unpruned) event sets before
  it is reported — the pruning stays a pure work-saver and can never
  manufacture a false livelock.

Sleep sets here are the one-step variant: when expanding a state's
events in order, the successor via event ``e_i`` is told to skip any
earlier sibling ``e_j`` (j < i) that is independent of ``e_i`` — the
commuted interleaving ``e_j . e_i`` is explored from the sibling branch
and lands on the same canonical state, so re-applying it here would only
re-derive a known state.  With full state dedup this prunes *work*, not
*reachability*: the reached state set is provably identical.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional


@dataclasses.dataclass
class Violation:
    """One property violation with its minimized witness trace."""
    kind: str                 # rule id: "deadlock" | "livelock" | safety ids
    message: str
    trace: tuple              # shortest event sequence from the initial state
    depth: int                # == len(trace)

    def format(self) -> str:
        steps = " -> ".join(repr(e) for e in self.trace) or "<initial state>"
        return f"[{self.kind}] {self.message}\n  trace ({self.depth} " \
               f"events): {steps}"


@dataclasses.dataclass
class ExplorationResult:
    states: int               # distinct canonical states discovered
    transitions: int          # edges executed (incl. ones landing on dups)
    pruned: int               # transitions skipped by sleep sets
    accepting: int            # accepting (drained) states found
    max_depth: int            # deepest state discovered
    fixpoint: bool            # True iff the full space was exhausted
    violations: list          # list[Violation], BFS order (shallowest first)
    # executed transitions per event class (a tuple event's first element)
    # — lets callers assert the model actually exercised a path (e.g.
    # "this config really preempts") instead of vacuously passing
    event_counts: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations


class _Node:
    """Visited-table entry: enough to rebuild a shortest trace."""
    __slots__ = ("state", "parent", "event", "depth", "has_events",
                 "accepting")

    def __init__(self, state, parent, event, depth):
        self.state = state
        self.parent = parent          # canonical key of the BFS parent
        self.event = event            # event that produced this state
        self.depth = depth
        self.has_events = False
        self.accepting = False


def explore(model, *, max_depth: Optional[int] = None,
            max_states: Optional[int] = None,
            check_liveness: bool = True,
            max_violations: int = 32,
            on_progress: Optional[Callable[[int], None]] = None,
            ) -> ExplorationResult:
    """Exhaustively explore ``model`` breadth-first.

    ``max_depth`` / ``max_states`` bound the search (None = unbounded:
    termination then relies on the model itself being finite-state, which
    is what the canonical key's counter-exclusion buys).  Liveness is
    checked only when the search reaches fixpoint within the bounds.
    """
    independent = getattr(model, "independent", None)
    init = model.initial_state()
    ikey = model.canonical_key(init)
    nodes: dict = {ikey: _Node(init, None, None, 0)}
    # reverse edges for backward reachability (to-key -> set of from-keys);
    # recorded for every executed transition, including duplicates
    redges: dict = {}
    queue = deque([(ikey, frozenset())])        # (key, sleep set)
    violations: list = []
    transitions = pruned = 0
    truncated = False
    event_counts: dict = {}

    def trace_to(key) -> tuple:
        ev = []
        while key is not None:
            node = nodes[key]
            if node.event is not None:
                ev.append(node.event)
            key = node.parent
        return tuple(reversed(ev))

    def report(kind: str, message: str, key) -> None:
        if len(violations) < max_violations:
            violations.append(Violation(kind, message, trace_to(key),
                                        nodes[key].depth))

    for kind, message in model.check_safety(init):
        report(kind, message, ikey)
    nodes[ikey].accepting = model.is_accepting(init)

    while queue:
        key, sleep = queue.popleft()
        node = nodes[key]
        if on_progress is not None:
            on_progress(len(nodes))
        events = model.enabled_events(node.state)
        node.has_events = bool(events)
        if not events:
            if not node.accepting:
                report("deadlock",
                       "non-drained state with no enabled event",
                       key)
            continue
        if max_depth is not None and node.depth >= max_depth:
            truncated = True
            continue
        explorable = [e for e in events if e not in sleep]
        pruned += len(events) - len(explorable)
        for i, ev in enumerate(explorable):
            child = model.apply(node.state, ev)
            ckey = model.canonical_key(child)
            transitions += 1
            cls = ev[0] if isinstance(ev, tuple) and ev else str(ev)
            event_counts[cls] = event_counts.get(cls, 0) + 1
            redges.setdefault(ckey, set()).add(key)
            if ckey in nodes:
                continue
            cnode = _Node(child, key, ev, node.depth + 1)
            nodes[ckey] = cnode
            cnode.accepting = model.is_accepting(child)
            for kind, message in model.check_safety(child):
                report(kind, message, ckey)
            if max_states is not None and len(nodes) >= max_states:
                truncated = True
                continue
            child_sleep = frozenset(
                explorable[j] for j in range(i)
                if independent is not None
                and independent(node.state, explorable[j], ev)
            ) if independent is not None else frozenset()
            queue.append((ckey, child_sleep))

    accepting = {k for k, n in nodes.items() if n.accepting}
    fixpoint = not truncated

    if check_liveness and fixpoint and not violations:
        _check_liveness(model, nodes, redges, accepting, report)

    depths = [n.depth for n in nodes.values()]
    return ExplorationResult(
        states=len(nodes), transitions=transitions, pruned=pruned,
        accepting=len(accepting), max_depth=max(depths) if depths else 0,
        fixpoint=fixpoint, violations=violations,
        event_counts=event_counts)


def _check_liveness(model, nodes, redges, accepting, report) -> None:
    """Livelock check: every state must be able to reach an accepting
    (drained) state.  Backward reachability over the recorded edge set
    finds the candidates; each is then confirmed by a forward search with
    *full* event sets, because sleep-set pruning may have skipped edges
    (never states) and a skipped edge could be a state's recorded-graph
    path to drain."""
    good = set(accepting)
    frontier = deque(good)
    while frontier:
        k = frontier.popleft()
        for pred in redges.get(k, ()):
            if pred not in good:
                good.add(pred)
                frontier.append(pred)

    candidates = [k for k, n in nodes.items() if k not in good]
    if not candidates:
        return
    candidates.sort(key=lambda k: nodes[k].depth)   # shallowest witness

    # forward confirmation with memoization; ``good`` grows as confirmed
    # escape routes are found, so later candidates reuse earlier work
    doomed: set = set()
    for cand in candidates:
        if cand in good or cand in doomed:
            continue
        seen = {cand}
        fq = deque([cand])
        escaped = False
        while fq and not escaped:
            k = fq.popleft()
            for ev in model.enabled_events(nodes[k].state):
                child = model.apply(nodes[k].state, ev)
                ckey = model.canonical_key(child)
                if ckey in good or (ckey in nodes and nodes[ckey].accepting):
                    escaped = True
                    break
                if ckey in seen or ckey in doomed:
                    continue
                seen.add(ckey)
                if ckey in nodes:            # only walk explored states
                    fq.append(ckey)
        if escaped:
            # only ``cand`` itself is proven: the forward search visited
            # sibling branches that may not share its escape route
            good.add(cand)
        else:
            doomed.update(seen)
            if nodes[cand].has_events:
                report("livelock",
                       "state can never reach drain (all continuations "
                       "cycle without finishing the submitted requests)",
                       cand)
            # has_events == False would already be a deadlock report
