"""Mechanical enforcement of the serving stack's invariants.

Two complementary halves:

  * ``repro.analysis.lint`` — reprolint, an AST static-analysis pass
    (``python -m repro.analysis.lint src/repro``) whose rules check jit
    hygiene, PRNG discipline, alloc/free pairing, atomic writes and
    clock injection from program structure.  Stdlib-only.
  * ``repro.analysis.sanitizer`` — a runtime paged-cache sanitizer that
    records allocation sites and cross-validates refcounts against live
    block tables and the prefix index every engine step.

The sanitizer half touches the jax-backed cache, so it is exported
lazily: importing ``repro.analysis`` (as the CI lint job does, with no
jax installed) must never pull in jax.
"""
import importlib

__all__ = ["Finding", "Linter", "ModuleInfo",
           "CacheSanitizer", "SanitizerError"]

# everything is lazy: the sanitizer half must not import jax when only
# the linter is wanted, and eagerly importing lint here would trip
# runpy's double-import warning for `python -m repro.analysis.lint`
_EXPORTS = {"Finding": "lint", "Linter": "lint", "ModuleInfo": "lint",
            "CacheSanitizer": "sanitizer", "SanitizerError": "sanitizer"}


def __getattr__(name):
    submodule = _EXPORTS.get(name)
    if submodule is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(
        importlib.import_module(f"repro.analysis.{submodule}"), name)
