"""Mechanical enforcement of the serving stack's invariants.

Three complementary layers:

  * ``repro.analysis.lint`` — reprolint, an AST static-analysis pass
    (``python -m repro.analysis.lint src/repro``) whose rules check jit
    hygiene, PRNG discipline, alloc/free pairing, atomic writes and
    clock injection from program structure.  Stdlib-only.
  * ``repro.analysis.tracecheck`` (+ ``ircost``) — IR-level analysis of
    the jitted serving steps (``python -m repro.analysis.tracecheck``):
    trace-cache budgets, buffer-donation audit, host-transfer detection,
    sharding conformance and static cost extraction over the lowered
    jaxpr / compiled executable of every registry arch.
  * ``repro.analysis.sanitizer`` — a runtime paged-cache sanitizer that
    records allocation sites and cross-validates refcounts against live
    block tables and the prefix index every engine step.

The tracecheck/sanitizer layers touch jax, so they are exported lazily:
importing ``repro.analysis`` (as the CI lint job does, with no jax
installed) must never pull in jax.
"""
import importlib

__all__ = ["Finding", "Linter", "ModuleInfo", "emit_findings",
           "CacheSanitizer", "SanitizerError",
           "run_analyzers", "collect_bench", "validate_bench", "ServeGeom"]

# everything is lazy: the sanitizer/tracecheck halves must not import jax
# when only the linter is wanted, and eagerly importing lint here would
# trip runpy's double-import warning for `python -m repro.analysis.lint`
_EXPORTS = {"Finding": "lint", "Linter": "lint", "ModuleInfo": "lint",
            "emit_findings": "lint",
            "CacheSanitizer": "sanitizer", "SanitizerError": "sanitizer",
            "run_analyzers": "tracecheck", "collect_bench": "tracecheck",
            "validate_bench": "tracecheck", "ServeGeom": "ircost"}


def __getattr__(name):
    submodule = _EXPORTS.get(name)
    if submodule is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(
        importlib.import_module(f"repro.analysis.{submodule}"), name)
