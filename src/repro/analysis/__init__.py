"""Mechanical enforcement of the serving stack's invariants.

Three complementary layers:

  * ``repro.analysis.lint`` — reprolint, an AST static-analysis pass
    (``python -m repro.analysis.lint src/repro``) whose rules check jit
    hygiene, PRNG discipline, alloc/free pairing, atomic writes and
    clock injection from program structure.  Stdlib-only.
  * ``repro.analysis.tracecheck`` (+ ``ircost``) — IR-level analysis of
    the jitted serving steps (``python -m repro.analysis.tracecheck``):
    trace-cache budgets, buffer-donation audit, host-transfer detection,
    sharding conformance and static cost extraction over the lowered
    jaxpr / compiled executable of every registry arch.
  * ``repro.analysis.sanitizer`` — a runtime paged-cache sanitizer that
    records allocation sites and cross-validates refcounts against live
    block tables and the prefix index every engine step.
  * ``repro.analysis.schedcheck`` (+ ``statespace``) — exhaustive
    bounded model checking of the serving control plane
    (``python -m repro.analysis.schedcheck``): every interleaving of
    submit/admit/prefill/decode/preempt events on the real scheduler
    and paged-cache objects, with the sanitizer battery asserted at
    every reachable state and minimized counterexample traces on
    violation.

``python -m repro.analysis`` runs all layers under one CLI with shared
``--select``/``--format``/exit-code conventions.

The tracecheck/sanitizer layers touch jax, so they are exported lazily:
importing ``repro.analysis`` (as the CI lint job does, with no jax
installed) must never pull in jax.
"""
import importlib

__all__ = ["Finding", "Linter", "ModuleInfo", "emit_findings",
           "CacheSanitizer", "SanitizerError",
           "run_analyzers", "collect_bench", "validate_bench", "ServeGeom",
           "CheckConfig", "ControlPlaneModel", "SCHED_CONFIGS",
           "run_config", "replay_trace",
           "explore", "ExplorationResult", "Violation"]

# everything is lazy: the sanitizer/tracecheck halves must not import jax
# when only the linter is wanted, and eagerly importing lint here would
# trip runpy's double-import warning for `python -m repro.analysis.lint`
_EXPORTS = {"Finding": "lint", "Linter": "lint", "ModuleInfo": "lint",
            "emit_findings": "lint",
            "CacheSanitizer": "sanitizer", "SanitizerError": "sanitizer",
            "run_analyzers": "tracecheck", "collect_bench": "tracecheck",
            "validate_bench": "tracecheck", "ServeGeom": "ircost",
            "CheckConfig": "schedcheck", "ControlPlaneModel": "schedcheck",
            "run_config": "schedcheck", "replay_trace": "schedcheck",
            "explore": "statespace", "ExplorationResult": "statespace",
            "Violation": "statespace"}
# schedcheck's config dict is exported under a package-level alias (its
# in-module name, CONFIGS, is too generic at this scope)
_ALIASES = {"SCHED_CONFIGS": ("schedcheck", "CONFIGS")}


def __getattr__(name):
    if name in _ALIASES:
        submodule, attr = _ALIASES[name]
    elif name in _EXPORTS:
        submodule, attr = _EXPORTS[name], name
    else:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    return getattr(
        importlib.import_module(f"repro.analysis.{submodule}"), attr)
