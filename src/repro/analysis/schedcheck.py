"""schedcheck — exhaustive state-space model checking of the serving
control plane (the third analysis layer: syntactic reprolint → IR-level
tracecheck → semantic schedcheck).

reprolint proves what source structure can, tracecheck audits the lowered
IR, and the runtime sanitizer samples whatever interleavings the chaos
tests happen to hit.  This module closes the gap: it drives the **actual
implementation objects** — ``RequestScheduler`` and a host-only
``PagedKVCache`` (real allocator, block tables, prefix index, LRU) — plus
a stepless mirror of ``ContinuousBatchingEngine``'s control-plane
transitions through *every* interleaving of nondeterministic events up to
the workload bound, and asserts the full invariant battery at every
reachable state.  Small configs (2–4 requests, 4–8 blocks, block_size 2)
are exhaustively coverable in seconds; docs/INVARIANTS.md §9 documents
the property set and the covering config matrix.

Event alphabet (one hashable tuple each):

    ("submit", rid)        client submits request rid (any order)
    ("admit",)             engine admission: peek → prefix match → reserve
                           into the lowest idle slot (engine's slot choice)
    ("prefill", kind)      one chunk for the oldest prefilling request
                           (engine's min-_sched_seq choice); on the final
                           chunk the first token is sampled — kind "stop"
                           models a stop-token draw, "tok" a regular one
    ("decode", i, kind)    one decode token for slot i (kind as above);
                           enabled only when the needed block is
                           obtainable (free or LRU-evictable)
    ("preempt", i)         recompute-preemption of slot i, enabled while
                           some decoding slot cannot obtain its next
                           block.  With ``nondet_victims`` every busy slot
                           is a candidate (a strict superset of the
                           implementation's pick); otherwise exactly
                           ``pick_preemption_victim``'s choice

This is a sound *superset* of the engine's behaviors: the engine's
admit-all/prefill-one/decode-all step loop is one particular event
ordering, and the adaptive planner (ROADMAP item 3) will re-plan chunk
sizes and interleave ratios — i.e. pick different orderings from this
same alphabet — so invariants are checked against every ordering any
planner could choose.  Token values are a pure function of (rid,
absolute position) with a reserved stop id, exactly the fold_in(seed,
position) determinism contract, so recompute-preemption and prefix
re-matching behave as in the real engine.

Safety is checked at every state by reusing the sanitizer's ground-truth
cross-validation (``CacheSanitizer.check_cache``) as a pure predicate,
plus harness-level checks the sanitizer cannot see (budget accounting,
request conservation, LRU-retirement converse, length caps, prefix
re-match).  Temporal properties come from the explored graph: deadlock
(non-drained state with no enabled event) and admission livelock (a
state from which drain is unreachable).  Violations carry a shortest
event trace (BFS order), replayable deterministically via
``replay_trace`` — ``--emit-replay`` turns one into a pytest regression.

CLI conventions match reprolint/tracecheck: positional config names,
``--select``, ``--format text|json|github``, exit 1 on findings.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Optional

from repro.analysis.lint import Finding, emit_findings
from repro.analysis.sanitizer import CacheSanitizer, SanitizerError
from repro.analysis.statespace import ExplorationResult, explore
from repro.serving.paged_cache import (PagedCacheConfig, PagedKVCache,
                                       blocks_for)
from repro.serving.scheduler import RequestScheduler

STOP_ID = 1          # reserved stop token (never produced by _tok)


def _tok(rid: int, pos: int) -> int:
    """Deterministic token value for request ``rid`` at absolute position
    ``pos`` — the model-checking stand-in for fold_in(seed, position):
    depends only on stable identity + position, so preemption recompute
    and prefix re-matching are bit-exact, and distinct requests diverge
    after a shared prompt prefix.  Never collides with STOP_ID."""
    return 2 + (rid * 7 + pos * 3) % 11


@dataclasses.dataclass(frozen=True)
class CheckConfig:
    """One bounded workload + engine geometry to exhaust."""
    name: str
    # (rid, prompt tuple, max_new_tokens, priority) per request
    requests: tuple
    slots: int
    block_size: int
    num_blocks: int            # incl. the reserved null block
    max_len: int
    prefill_chunk: int
    max_tokens_in_flight: Optional[int] = None
    share_prefix: bool = False
    with_stop: bool = True     # enable the nondet stop-token branch
    nondet_victims: bool = True  # preempt any busy slot, not just the pick
    description: str = ""


#: Properties checked at every state / over the explored graph.  Keys are
#: the ``--select`` rule ids; docs/INVARIANTS.md §9 documents each.
PROPERTIES = {
    "invariant": "sanitizer cross-validation: block conservation, "
                 "refcount == #table refs + index ref, free/ref "
                 "disjointness, LRU membership, hash<->block bijection, "
                 "commit-cursor liveness, slot pos within table capacity",
    "lru-retirement": "converse LRU check: every indexed rc==1 block held "
                      "by no table must sit in the LRU (else it is "
                      "unevictable — leaks until restart)",
    "budget": "scheduler._in_flight_tokens == sum of charged footprints "
              "of running requests, and never exceeds "
              "max_tokens_in_flight",
    "conservation": "every submitted unfinished request is in exactly one "
                    "of {queue, slot}; finished requests are in neither; "
                    "no duplicates",
    "length-cap": "len(prompt) + len(out_tokens) stays under "
                  "min(prompt+max_new, max_len) until the finish event",
    "prefix-rematch": "assign_prefix returns exactly the longest cached "
                      "chain the harness recomputes independently — a "
                      "re-admitted preempted request re-matches its "
                      "retired blocks",
    "admission-stuck": "queue non-empty + all slots idle + head cannot "
                       "fit: the engine would raise 'cannot fit an empty "
                       "pool'",
    "oom-unexpected": "reserve failed although free + evictable blocks "
                      "covered the need",
    "crash": "an implementation call raised during a transition",
    "deadlock": "a non-drained state with no enabled event",
    "livelock": "a state from which drain is unreachable (some submitted "
                "request can never finish)",
}


class _Rec:
    """Minimal request record satisfying the scheduler/cache protocol —
    the harness twin of engine._ReqState (id / prompt / max_new_tokens /
    priority / out_tokens / _sched_seq / _charged_footprint /
    context())."""
    __slots__ = ("id", "prompt", "max_new_tokens", "priority", "out_tokens",
                 "_sched_seq", "_charged_footprint")

    def __init__(self, rid, prompt, max_new_tokens, priority):
        self.id = rid
        self.prompt = tuple(prompt)
        self.max_new_tokens = max_new_tokens
        self.priority = priority
        self.out_tokens: list = []
        self._sched_seq = None
        self._charged_footprint = None

    def context(self) -> tuple:
        return self.prompt + tuple(self.out_tokens)


class SchedState:
    """One snapshot of the whole control plane.  ``key`` is the canonical
    dedup key: every behavior-relevant structure, including free-list and
    LRU order, but excluding monotonic telemetry counters (scheduler
    stats, prefix hit/lookup/eviction counts) — preempt/re-admit cycles
    revisit the same behavioral state with ever-growing counters, and
    including them would make the state space infinite.  Transition-level
    violation notes ARE part of the key, so a violating edge always
    produces a distinct (reported) state."""
    __slots__ = ("key", "data", "notes", "_mat")

    def __init__(self, key, data, notes=()):
        self.key = key
        self.data = data
        self.notes = tuple(notes)
        self._mat = None


def _freeze(obj):
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


class ControlPlaneModel:
    """The statespace.explore model over the real serving objects.

    ``sched_cls`` / ``cache_cls`` exist for mutation-injection tests:
    substituting a subclass with a seeded bug must make the checker fire.
    """

    def __init__(self, cfg: CheckConfig, *, sched_cls=RequestScheduler,
                 cache_cls=PagedKVCache):
        self.cfg = cfg
        self.sched_cls = sched_cls
        self.cache_cls = cache_cls
        self.stop_ids = frozenset({STOP_ID}) if cfg.with_stop \
            else frozenset()
        self.cache_cfg = PagedCacheConfig(
            block_size=cfg.block_size, num_blocks=cfg.num_blocks,
            max_blocks_per_seq=blocks_for(cfg.max_len, cfg.block_size),
            share_prefix=cfg.share_prefix)
        self._sanitizer = CacheSanitizer()
        self._validate_workload()

    # -- workload vetting (mirrors engine._validate) -------------------
    def _validate_workload(self) -> None:
        cfg = self.cfg
        seen = set()
        for rid, prompt, max_new, _prio in cfg.requests:
            if rid in seen:
                raise ValueError(f"duplicate request id {rid}")
            seen.add(rid)
            if not prompt:
                raise ValueError(f"request {rid} has an empty prompt")
            if max_new < 1:
                raise ValueError(f"request {rid}: max_new_tokens >= 1")
            if len(prompt) >= cfg.max_len:
                raise ValueError(f"request {rid}: prompt >= max_len")
            if blocks_for(self._target_total(prompt, max_new),
                          cfg.block_size) > cfg.num_blocks - 1:
                raise ValueError(f"request {rid} can never fit the pool")
            fp = min(len(prompt) + max_new, cfg.max_len)
            if (cfg.max_tokens_in_flight is not None
                    and fp > cfg.max_tokens_in_flight):
                raise ValueError(f"request {rid} exceeds the token budget")

    def _target_total(self, prompt, max_new) -> int:
        return min(len(prompt) + max_new, self.cfg.max_len)

    # -- snapshot <-> live objects -------------------------------------
    def initial_state(self) -> SchedState:
        sched = self.sched_cls(
            max_tokens_in_flight=self.cfg.max_tokens_in_flight,
            footprint_cap=self.cfg.max_len)
        cache = self.cache_cls.host_only(self.cache_cfg)
        recs = {rid: _Rec(rid, prompt, mx, prio)
                for rid, prompt, mx, prio in self.cfg.requests}
        slots = [None] * self.cfg.slots
        return self._snapshot(sched, cache, recs, slots,
                              submitted=set(), finished={})

    def _snapshot(self, sched, cache, recs, slots, *, submitted, finished,
                  notes=()) -> SchedState:
        data = {
            "sched": sched.state_dict(),
            "cache": cache.host_state_dict(),
            "recs": {rid: {"out": tuple(r.out_tokens),
                           "seq": r._sched_seq,
                           "charged": r._charged_footprint}
                     for rid, r in recs.items()},
            "slots": [None if s is None else tuple(s) for s in slots],
            "submitted": frozenset(submitted),
            "finished": dict(finished),
        }
        key = (
            _freeze({k: v for k, v in data["sched"].items()
                     if k != "stats"}),
            _freeze({k: v for k, v in data["cache"].items()
                     if k != "counters"}),
            _freeze(data["recs"]),
            _freeze(data["slots"]),
            tuple(sorted(data["submitted"])),
            _freeze(data["finished"]),
            tuple(notes),
        )
        return SchedState(key, data, notes)

    def _materialize(self, state: SchedState, *, fresh: bool = False):
        """Rebuild live objects from a snapshot.  Read-only callers share
        a cached materialization; ``apply`` demands a fresh one because
        it mutates."""
        if not fresh and state._mat is not None:
            return state._mat
        d = state.data
        recs = {}
        for rid, prompt, mx, prio in self.cfg.requests:
            rec = _Rec(rid, prompt, mx, prio)
            saved = d["recs"].get(rid)
            if saved is not None:
                rec.out_tokens = list(saved["out"])
                rec._sched_seq = saved["seq"]
                rec._charged_footprint = saved["charged"]
            recs[rid] = rec
        sched = self.sched_cls()
        sched.load_state_dict(d["sched"], recs)
        cache = self.cache_cls.host_only(self.cache_cfg)
        cache.load_host_state_dict(d["cache"])
        slots = [None if s is None else list(s) for s in d["slots"]]
        mat = (sched, cache, recs, slots,
               set(d["submitted"]), dict(d["finished"]))
        if not fresh:
            state._mat = mat
        return mat

    def canonical_key(self, state: SchedState):
        return state.key

    # -- event enumeration ---------------------------------------------
    def _can_reserve(self, cache, rid: int, n_tokens: int) -> bool:
        have = len(cache.tables.get(rid, ()))
        need = blocks_for(n_tokens, self.cfg.block_size) - have
        return need <= 0 or \
            need <= cache.allocator.num_free + cache.num_cached

    def _budget_admits(self, sched, req) -> bool:
        return (sched.max_tokens_in_flight is None
                or sched._in_flight_tokens + sched._footprint(req)
                <= sched.max_tokens_in_flight)

    def enabled_events(self, state: SchedState) -> list:
        sched, cache, recs, slots, submitted, finished = \
            self._materialize(state)
        evs = []
        for rid, _p, _m, _prio in self.cfg.requests:
            if rid not in submitted:
                evs.append(("submit", rid))
        head = sched.peek()
        if head is not None and any(s is None for s in slots):
            ctx = head.context()
            if cache.can_fit_request(ctx) and \
                    self._budget_admits(sched, head):
                evs.append(("admit",))
        prefilling = [s for s in slots
                      if s is not None and s[1] == "prefill"]
        if prefilling:
            s = min(prefilling, key=lambda s: recs[s[0]]._sched_seq)
            ctx = recs[s[0]].context()
            final = min(s[3] + self.cfg.prefill_chunk, len(ctx)) == len(ctx)
            evs.append(("prefill", "tok"))
            if final and self.cfg.with_stop:
                evs.append(("prefill", "stop"))
        pressure = False
        for i, s in enumerate(slots):
            if s is None or s[1] != "decode":
                continue
            if self._can_reserve(cache, s[0], s[2] + 1):
                evs.append(("decode", i, "tok"))
                if self.cfg.with_stop:
                    evs.append(("decode", i, "stop"))
            else:
                pressure = True
        if pressure:
            busy = [i for i, s in enumerate(slots) if s is not None]
            if self.cfg.nondet_victims:
                evs.extend(("preempt", i) for i in busy)
            elif busy:
                victim = sched.pick_preemption_victim(
                    [recs[slots[i][0]] for i in busy])
                vslot = next(i for i in busy
                             if slots[i][0] == victim.id)
                evs.append(("preempt", vslot))
        return evs

    # -- transitions (each mirrors one engine control-plane path) ------
    def apply(self, state: SchedState, event: tuple) -> SchedState:
        sched, cache, recs, slots, submitted, finished = \
            self._materialize(state, fresh=True)
        notes: list = []
        try:
            kind = event[0]
            if kind == "submit":
                self._apply_submit(event[1], sched, recs, submitted)
            elif kind == "admit":
                self._apply_admit(sched, cache, recs, slots, notes)
            elif kind == "prefill":
                self._apply_prefill(event[1], sched, cache, recs, slots,
                                    finished)
            elif kind == "decode":
                self._apply_decode(event[1], event[2], sched, cache, recs,
                                   slots, finished, notes)
            elif kind == "preempt":
                self._apply_preempt(event[1], sched, cache, recs, slots)
            else:
                raise ValueError(f"unknown event {event!r}")
        except Exception as e:                    # a real-code crash IS a
            notes.append(("crash",                # checkable violation
                          f"{event!r}: {type(e).__name__}: {e}"))
        return self._snapshot(sched, cache, recs, slots,
                              submitted=submitted, finished=finished,
                              notes=notes)

    def _apply_submit(self, rid, sched, recs, submitted) -> None:
        sched.submit(recs[rid])
        submitted.add(rid)

    def _expected_match_tokens(self, cache, ctx) -> int:
        """Independent recomputation of the longest cached chain covering
        a prefix of ``ctx`` (capped at len(ctx)-1 like match_prefix) —
        the ground truth for the prefix-rematch property."""
        bs = self.cfg.block_size
        limit = max(len(ctx) - 1, 0) // bs
        prev, n = None, 0
        for i in range(limit):
            prev = (prev, tuple(int(t) for t in ctx[i * bs:(i + 1) * bs]))
            if prev not in cache._hash_to_block:
                break
            n += 1
        return n * bs

    def _apply_admit(self, sched, cache, recs, slots, notes) -> None:
        slot_i = next(i for i, s in enumerate(slots) if s is None)
        st = sched.next_admission()
        if st is None:                 # budget refused (engine breaks)
            return
        ctx = st.context()
        expected = self._expected_match_tokens(cache, ctx) \
            if self.cfg.share_prefix else 0
        n_cached = cache.assign_prefix(st.id, ctx)
        if n_cached != expected:
            notes.append((
                "prefix-rematch",
                f"request {st.id}: assign_prefix matched {n_cached} tokens "
                f"but {expected} are cached along its chain "
                f"({'re-admission' if st.out_tokens else 'admission'})"))
        ok = cache.reserve(st.id, len(ctx))
        if not ok:
            notes.append(("crash",
                          f"request {st.id}: can_fit_request passed but "
                          f"reserve failed"))
        slots[slot_i] = [st.id, "prefill", n_cached, n_cached]

    def _record_token(self, rec, tok: int) -> Optional[str]:
        rec.out_tokens.append(tok)
        if tok in self.stop_ids:
            return "stop"
        if len(rec.prompt) + len(rec.out_tokens) >= \
                self._target_total(rec.prompt, rec.max_new_tokens):
            return "length"
        return None

    def _finish(self, i, reason, sched, cache, recs, slots,
                finished) -> None:
        rid = slots[i][0]
        cache.release(rid)
        sched.on_finish(recs[rid])
        slots[i] = None
        finished[rid] = reason

    def _apply_prefill(self, kind, sched, cache, recs, slots,
                       finished) -> None:
        prefilling = [i for i, s in enumerate(slots)
                      if s is not None and s[1] == "prefill"]
        i = min(prefilling, key=lambda i: recs[slots[i][0]]._sched_seq)
        rid = slots[i][0]
        rec = recs[rid]
        ctx = rec.context()
        n_new = min(self.cfg.prefill_chunk, len(ctx) - slots[i][3])
        slots[i][3] += n_new
        slots[i][2] = slots[i][3]
        cache.commit_prefix(rid, ctx, slots[i][3])
        if slots[i][3] == len(ctx):
            tok = STOP_ID if kind == "stop" else _tok(rid, len(ctx))
            reason = self._record_token(rec, tok)
            if reason is not None:
                self._finish(i, reason, sched, cache, recs, slots, finished)
            else:
                slots[i][1] = "decode"

    def _apply_decode(self, i, kind, sched, cache, recs, slots, finished,
                      notes) -> None:
        rid = slots[i][0]
        rec = recs[rid]
        if not cache.reserve(rid, slots[i][2] + 1):
            notes.append(("oom-unexpected",
                          f"slot {i} request {rid}: reserve failed though "
                          f"free + evictable covered the need"))
            return
        slots[i][2] += 1
        tok = STOP_ID if kind == "stop" \
            else _tok(rid, len(rec.prompt) + len(rec.out_tokens))
        reason = self._record_token(rec, tok)
        if self.cfg.share_prefix and \
                slots[i][2] % self.cfg.block_size == 0:
            cache.commit_prefix(rid, rec.context(), slots[i][2])
        if reason is not None:
            self._finish(i, reason, sched, cache, recs, slots, finished)

    def _apply_preempt(self, i, sched, cache, recs, slots) -> None:
        rid = slots[i][0]
        cache.release(rid)
        sched.preempt(recs[rid])
        slots[i] = None

    # -- acceptance + safety battery -----------------------------------
    def is_accepting(self, state: SchedState) -> bool:
        d = state.data
        return (len(d["finished"]) == len(self.cfg.requests)
                and len(d["submitted"]) == len(self.cfg.requests)
                and not d["sched"]["queue"]
                and all(s is None for s in d["slots"]))

    def check_safety(self, state: SchedState) -> list:
        out = list(state.notes)
        sched, cache, recs, slots, submitted, finished = \
            self._materialize(state)
        bs = self.cfg.block_size

        # 1. the sanitizer's ground-truth cross-validation, as a predicate
        try:
            self._sanitizer.check_cache(cache)
        except SanitizerError as e:
            out.append(("invariant", str(e).replace("\n", "; ")))

        # 2. converse LRU retirement: indexed + rc==1 + unheld => in LRU
        held = {b for t in cache.tables.values() for b in t}
        for b in cache._block_to_hash:
            if (cache.allocator.refcount(b) == 1 and b not in held
                    and b not in cache._lru):
                out.append(("lru-retirement",
                            f"indexed block {b} (rc=1, unheld) missing "
                            f"from the LRU — unevictable leak"))

        # 3. slot/table consistency (null-block-write mirror)
        for i, s in enumerate(slots):
            if s is None:
                continue
            rid, _st, pos, pp = s
            table = cache.tables.get(rid)
            if table is None:
                out.append(("invariant",
                            f"busy slot {i} request {rid} has no table"))
            elif pos > len(table) * bs:
                out.append(("invariant",
                            f"slot {i} pos {pos} exceeds table capacity "
                            f"{len(table) * bs} — next write hits the "
                            f"null block"))
            if pp > pos:
                out.append(("invariant",
                            f"slot {i} prefill cursor {pp} ahead of "
                            f"residency {pos}"))

        # 4. budget accounting
        running = [s[0] for s in slots if s is not None]
        expected = sum(recs[rid]._charged_footprint or 0 for rid in running)
        if sched._in_flight_tokens != expected:
            out.append(("budget",
                        f"_in_flight_tokens={sched._in_flight_tokens} but "
                        f"running requests {sorted(running)} are charged "
                        f"{expected}"))
        if (sched.max_tokens_in_flight is not None
                and sched._in_flight_tokens > sched.max_tokens_in_flight):
            out.append(("budget",
                        f"budget exceeded: {sched._in_flight_tokens} > "
                        f"{sched.max_tokens_in_flight}"))

        # 5. request conservation: no lost or duplicated request
        queue_rids = [rid for _p, _s, rid in state.data["sched"]["queue"]]
        if len(set(queue_rids)) != len(queue_rids):
            out.append(("conservation",
                        f"queue holds duplicates: {queue_rids}"))
        if len(set(running)) != len(running):
            out.append(("conservation",
                        f"slots hold duplicates: {running}"))
        for rid, _p, _m, _prio in self.cfg.requests:
            places = ((rid in queue_rids) + (rid in running)
                      + (rid in finished))
            if rid not in submitted:
                if places:
                    out.append(("conservation",
                                f"unsubmitted request {rid} present"))
            elif places != 1:
                where = [n for n, hit in
                         [("queue", rid in queue_rids),
                          ("slot", rid in running),
                          ("finished", rid in finished)] if hit]
                out.append(("conservation",
                            f"request {rid} in {places} places "
                            f"({where or 'nowhere'}) — "
                            f"{'duplicated' if places else 'lost'}"))

        # 6. length caps
        for rid, r in recs.items():
            total = len(r.prompt) + len(r.out_tokens)
            target = self._target_total(r.prompt, r.max_new_tokens)
            if rid in finished:
                if total > target:
                    out.append(("length-cap",
                                f"finished request {rid} holds {total} "
                                f"tokens > target {target}"))
            elif total >= target and rid in submitted:
                out.append(("length-cap",
                            f"request {rid} reached {total} tokens "
                            f"(target {target}) without finishing"))

        # 7. the engine's cannot-fit-an-empty-pool raise
        head = sched.peek()
        if (head is not None and all(s is None for s in slots)
                and not cache.can_fit_request(head.context())):
            out.append(("admission-stuck",
                        f"request {head.id} cannot fit an empty pool — "
                        f"the engine would raise"))
        return out

    # -- partial-order reduction ---------------------------------------
    def independent(self, state: SchedState, a: tuple, b: tuple) -> bool:
        """True only for pairs that provably commute: decode-"tok" events
        on distinct slots where neither needs a new block (stays within
        reserved capacity), neither finishes, and neither lands on a
        block boundary (whose commit_prefix touches the shared index).
        Such events mutate disjoint slot/request state only."""
        if not (a[0] == b[0] == "decode" and a[2] == b[2] == "tok"
                and a[1] != b[1]):
            return False
        _sched, cache, recs, slots, _sub, _fin = self._materialize(state)
        for ev in (a, b):
            s = slots[ev[1]]
            if s is None or s[1] != "decode":
                return False
            rid, _st, pos, _pp = s
            rec = recs[rid]
            table = cache.tables.get(rid, ())
            if pos + 1 > len(table) * self.cfg.block_size:
                return False               # needs a new block: allocator
            if (pos + 1) % self.cfg.block_size == 0:
                return False               # boundary commit: shared index
            if len(rec.prompt) + len(rec.out_tokens) + 1 >= \
                    self._target_total(rec.prompt, rec.max_new_tokens):
                return False               # would finish: scheduler/cache
        return True


# ---------------------------------------------------------------------
# replay: a violation trace re-executed deterministically
# ---------------------------------------------------------------------

def replay_trace(cfg: CheckConfig, trace, *, model: Optional[
        ControlPlaneModel] = None):
    """Re-execute ``trace`` from the initial state.  Returns
    ``(final_state, violations)`` where violations is every (step index,
    rule, message) the safety battery reports along the way — a
    counterexample emitted by the checker reproduces its violation here,
    which is what turns traces into deterministic pytest regressions."""
    model = model if model is not None else ControlPlaneModel(cfg)
    state = model.initial_state()
    violations = [(0, kind, msg) for kind, msg in model.check_safety(state)]
    for n, event in enumerate(trace, start=1):
        state = model.apply(state, event)
        violations.extend((n, kind, msg)
                          for kind, msg in model.check_safety(state))
    return state, violations


_REPLAY_TEMPLATE = '''\
"""Auto-generated schedcheck regression (python -m repro.analysis.schedcheck
--emit-replay).  Replays a minimized counterexample trace and asserts the
violation still reproduces — commit next to the fix."""
from repro.analysis.schedcheck import CheckConfig, replay_trace

CONFIG = {config!r}

TRACE = {trace!r}

EXPECT_RULE = {rule!r}


def test_replayed_trace_reproduces_violation():
    _state, violations = replay_trace(CONFIG, TRACE)
    assert any(rule == EXPECT_RULE for _n, rule, _m in violations), (
        "trace no longer reproduces a %s violation: %r"
        % (EXPECT_RULE, violations))
'''


def emit_replay(path: str, cfg: CheckConfig, violation) -> None:
    """Write a standalone pytest regression for ``violation``."""
    src = _REPLAY_TEMPLATE.format(config=cfg, trace=list(violation.trace),
                                  rule=violation.kind)
    with open(path, "w") as f:
        f.write(src)


# ---------------------------------------------------------------------
# bounded config matrix (the CI gate exhausts every entry)
# ---------------------------------------------------------------------

CONFIGS: dict[str, CheckConfig] = {c.name: c for c in [
    CheckConfig(
        name="fcfs-tight",
        description="2 FCFS requests on a pool that cannot hold both "
                    "(forced decode-OOM preemption, nondet victims, "
                    "stop branches)",
        requests=((1, (3, 4), 4, 0), (2, (5, 6), 4, 0)),
        slots=2, block_size=2, num_blocks=5, max_len=8, prefill_chunk=2,
        max_tokens_in_flight=12, share_prefix=False,
        with_stop=True, nondet_victims=True),
    CheckConfig(
        name="priority-prefix",
        description="3 requests in 2 priority classes sharing a prompt "
                    "block; prefix index + LRU retirement + budget "
                    "refusals, impl victim pick",
        requests=((1, (5, 6, 7), 2, 0), (2, (5, 6, 8), 2, 1),
                  (3, (5, 6, 7), 2, 1)),
        slots=2, block_size=2, num_blocks=7, max_len=8, prefill_chunk=4,
        max_tokens_in_flight=10, share_prefix=True,
        with_stop=False, nondet_victims=False),
    CheckConfig(
        name="preempt-rematch",
        description="2 identical-prompt prefix-sharing requests on a "
                    "tight pool: preemption retires committed blocks and "
                    "re-admission must re-match them (nondet victims)",
        requests=((1, (9, 9), 4, 0), (2, (9, 9), 4, 0)),
        slots=2, block_size=2, num_blocks=5, max_len=8, prefill_chunk=2,
        max_tokens_in_flight=None, share_prefix=True,
        with_stop=False, nondet_victims=True),
    CheckConfig(
        name="wide-block",
        description="2 requests on block_size 4: mid-block decodes on "
                    "distinct slots provably commute, so sleep-set "
                    "partial-order pruning engages",
        requests=((1, (3, 4), 4, 0), (2, (5, 6), 4, 0)),
        slots=2, block_size=4, num_blocks=5, max_len=8, prefill_chunk=4,
        max_tokens_in_flight=None, share_prefix=False,
        with_stop=False, nondet_victims=True),
    CheckConfig(
        name="ample-stop",
        description="3 FCFS requests with headroom (no preemption "
                    "reachable): budget refusals + stop/length branches "
                    "only",
        requests=((1, (3, 4), 2, 0), (2, (5, 6), 2, 0), (3, (7, 8), 2, 0)),
        slots=2, block_size=2, num_blocks=9, max_len=8, prefill_chunk=4,
        max_tokens_in_flight=10, share_prefix=False,
        with_stop=True, nondet_victims=True),
]}


# ---------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------

def run_config(cfg: CheckConfig, *, max_states: Optional[int] = None,
               max_depth: Optional[int] = None,
               check_liveness: bool = True, max_violations: int = 32,
               sched_cls=RequestScheduler, cache_cls=PagedKVCache,
               model: Optional[ControlPlaneModel] = None,
               ) -> ExplorationResult:
    if model is None:
        model = ControlPlaneModel(cfg, sched_cls=sched_cls,
                                  cache_cls=cache_cls)
    return explore(model, max_states=max_states, max_depth=max_depth,
                   check_liveness=check_liveness,
                   max_violations=max_violations)


def findings_from(cfg: CheckConfig, result: ExplorationResult,
                  select=None) -> list:
    findings = []
    for v in result.violations:
        if select is not None and v.kind not in select:
            continue
        trace = " -> ".join(
            ":".join(str(p) for p in e) for e in v.trace) or "<initial>"
        findings.append(Finding(
            path=f"{cfg.name}/{v.kind}", line=0, col=0, rule=v.kind,
            message=f"{v.message} | {v.depth}-event trace: {trace}"))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.schedcheck",
        description="Exhaustive state-space model checking of the serving "
                    "control plane (docs/INVARIANTS.md section 9)")
    ap.add_argument("configs", nargs="*",
                    help="config names to explore (default: all)")
    ap.add_argument("--select", default=None,
                    help="comma-separated property ids to report")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text")
    ap.add_argument("--list-configs", action="store_true")
    ap.add_argument("--list-properties", action="store_true")
    ap.add_argument("--max-states", type=int, default=None,
                    help="truncate the search (disables liveness)")
    ap.add_argument("--depth", type=int, default=None,
                    help="bound the search depth (disables liveness)")
    ap.add_argument("--emit-replay", metavar="PATH", default=None,
                    help="write a pytest regression replaying the first "
                         "violation")
    args = ap.parse_args(argv)

    if args.list_configs:
        for cfg in CONFIGS.values():
            print(f"{cfg.name}: {cfg.description}")
        return 0
    if args.list_properties:
        for rule, desc in PROPERTIES.items():
            print(f"{rule}: {desc}")
        return 0

    select = None
    if args.select:
        select = {r.strip() for r in args.select.split(",") if r.strip()}
        unknown = select - set(PROPERTIES)
        if unknown:
            print(f"unknown properties: {sorted(unknown)}",
                  file=sys.stderr)
            return 2

    names = args.configs or list(CONFIGS)
    unknown = [n for n in names if n not in CONFIGS]
    if unknown:
        print(f"unknown configs: {unknown} (have: {sorted(CONFIGS)})",
              file=sys.stderr)
        return 2

    all_findings = []
    total_states = 0
    for name in names:
        cfg = CONFIGS[name]
        t0 = time.perf_counter()
        result = run_config(cfg, max_states=args.max_states,
                            max_depth=args.depth)
        dt = time.perf_counter() - t0
        total_states += result.states
        cover = " ".join(f"{k}={v}"
                         for k, v in sorted(result.event_counts.items()))
        print(f"schedcheck: {name}: {result.states} states / "
              f"{result.transitions} transitions ({result.pruned} pruned) "
              f"/ {result.accepting} drained / depth {result.max_depth} / "
              f"{'fixpoint' if result.fixpoint else 'TRUNCATED'} / "
              f"{len(result.violations)} violation(s) in {dt:.2f}s "
              f"[{cover}]", file=sys.stderr)
        findings = findings_from(cfg, result, select)
        if findings and args.emit_replay and not all_findings:
            emit_replay(args.emit_replay, cfg, result.violations[0])
            print(f"schedcheck: replay regression written to "
                  f"{args.emit_replay}", file=sys.stderr)
        all_findings.extend(findings)

    emit_findings(all_findings, args.format, tool="schedcheck")
    if not all_findings:
        print(f"schedcheck: clean — {len(names)} config(s), "
              f"{total_states} states explored", file=sys.stderr)
    return 1 if all_findings else 0


if __name__ == "__main__":
    sys.exit(main())
