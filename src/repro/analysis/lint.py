"""reprolint: a static-analysis pass enforcing the serving stack's
invariants mechanically instead of rediscovering them as production bugs.

The engine's correctness rests on a handful of unwritten rules — jitted
step builders must not sync to the host, sampling keys must derive via
``fold_in`` on an absolute position, every allocated cache block needs an
owner on every exit path, serving files must be written atomically, and
the injectable engine clock is the ONLY clock.  Each of those invariants
was originally enforced by whichever regression test happened to be
written after a bug shipped (CHANGES.md records ~15 such bugs across
PRs 1-6).  reprolint checks them from program structure, on every run:

    PYTHONPATH=src python -m repro.analysis.lint src/repro

Architecture: a two-pass driver over a file set.  Pass 1 parses every
file into a :class:`ModuleInfo` (AST + import aliases + top-level
function table + per-line pragma suppressions) and registers it in a
:class:`LintContext`, so rules can resolve calls *across* analyzed
modules (the jit rules follow ``T.lm_apply`` from runtime/steps.py into
models/transformer.py).  Pass 2 runs every :class:`~repro.analysis.rules.
Rule` against every module and collects :class:`Finding`\\ s.

False positives are suppressed inline, never globally::

    t = time.perf_counter()  # reprolint: disable=clock-injection

Each suppression documents WHY the flagged line is the sanctioned
exception (see docs/INVARIANTS.md for the catalogue).  The CLI exits
nonzero on any unsuppressed finding, which is the CI gate.

This module is stdlib-only (``ast`` + friends): the lint gate runs in
CI jobs that do not install jax.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import pathlib
import re
import sys
from typing import Iterable, Optional

PRAGMA_RE = re.compile(r"#\s*reprolint:\s*disable=([\w\-, ]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}"


def emit_findings(findings: list, fmt: str, *, tool: str = "reprolint",
                  stream=None) -> None:
    """Render findings in one of the CLI output formats — shared by
    reprolint and tracecheck (repro.analysis.tracecheck).

    text    the classic ``path:line:col: rule: message`` lines
    json    a machine-readable array (the whole stream is valid JSON —
            summaries go to stderr, never here)
    github  GitHub Actions workflow commands: the CI jobs emit these so
            findings surface as inline PR annotations
    """
    stream = stream if stream is not None else sys.stdout
    if fmt == "json":
        json.dump([dataclasses.asdict(f) for f in findings], stream,
                  indent=1)
        stream.write("\n")
    elif fmt == "github":
        for f in findings:
            # newlines terminate a workflow command; escape per the spec
            msg = f.message.replace("%", "%25").replace("\r", "%0D") \
                           .replace("\n", "%0A")
            stream.write(f"::error file={f.path},line={f.line},"
                         f"col={f.col},title={tool}({f.rule})::{msg}\n")
    elif fmt == "text":
        for f in findings:
            stream.write(f.format() + "\n")
    else:
        raise ValueError(f"unknown findings format {fmt!r}")


class ModuleInfo:
    """Parsed view of one source file: AST, import aliases, top-level
    functions, and per-line pragma suppressions."""

    def __init__(self, path: str, source: str, modname: Optional[str] = None):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.modname = modname if modname is not None else _modname_of(path)
        self.tree = ast.parse(source, filename=path)
        # alias -> dotted module it names:  "import numpy as np" -> np,
        # "from repro.models import transformer as T" -> T
        self.import_aliases: dict[str, str] = {}
        # name -> (module, original name):  "from x import f as g" -> g
        self.from_imports: dict[str, tuple[str, str]] = {}
        # top-level function table for cross-module call resolution
        self.functions: dict[str, ast.FunctionDef] = {}
        for node in self.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
            elif isinstance(node, ast.Import):
                for a in node.names:
                    self.import_aliases[a.asname or a.name.split(".")[0]] = \
                        a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.from_imports[a.asname or a.name] = \
                        (node.module, a.name)
        # line -> set of rule names suppressed there
        self.suppressions: dict[int, set[str]] = {}
        for i, text in enumerate(self.lines, 1):
            m = PRAGMA_RE.search(text)
            if m:
                self.suppressions[i] = {
                    r.strip() for r in m.group(1).split(",") if r.strip()}

    @property
    def in_serving(self) -> bool:
        """True for modules under the serving package — the scope of the
        prng/atomic-write/clock rules."""
        return "serving" in pathlib.PurePath(self.path).parts \
            or self.modname.startswith("repro.serving")

    def suppressed(self, finding: Finding) -> bool:
        return finding.rule in self.suppressions.get(finding.line, set())


def _modname_of(path: str) -> str:
    """Dotted module name, anchored at the last path component named
    ``repro`` (the package root under src/)."""
    parts = list(pathlib.PurePath(path).parts)
    name = parts[-1]
    if name.endswith(".py"):
        parts[-1] = name[:-3]
    if "repro" in parts:
        parts = parts[len(parts) - 1 - parts[::-1].index("repro"):]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


class LintContext:
    """All modules of one lint run, keyed by dotted name — the resolver
    rules use to follow calls across analyzed files."""

    def __init__(self, modules: Iterable[ModuleInfo]):
        self.modules: dict[str, ModuleInfo] = {m.modname: m for m in modules}

    def resolve_call(self, module: ModuleInfo, func: ast.expr) \
            -> Optional[tuple[ModuleInfo, ast.FunctionDef]]:
        """Resolve a called expression to a top-level function in an
        analyzed module: bare names via the caller's own table or its
        ``from x import f`` imports, ``alias.attr`` via import aliases.
        Returns None for anything unresolvable (builtins, methods,
        closures over parameters, externals)."""
        if isinstance(func, ast.Name):
            fn = module.functions.get(func.id)
            if fn is not None:
                return module, fn
            target = module.from_imports.get(func.id)
            if target is not None:
                mod = self.modules.get(target[0])
                if mod is not None and target[1] in mod.functions:
                    return mod, mod.functions[target[1]]
        elif isinstance(func, ast.Attribute) \
                and isinstance(func.value, ast.Name):
            base = func.value.id
            dotted = module.import_aliases.get(base)
            if dotted is None and base in module.from_imports:
                # "from repro.models import transformer as T" parses as a
                # from-import whose value is itself a module
                fmod, orig = module.from_imports[base]
                dotted = f"{fmod}.{orig}"
            if dotted is not None:
                mod = self.modules.get(dotted)
                if mod is not None and func.attr in mod.functions:
                    return mod, mod.functions[func.attr]
        return None


class Linter:
    """Two-pass driver: parse every file, then run every rule."""

    def __init__(self, select: Optional[set[str]] = None):
        from repro.analysis.rules import all_rules
        self.rules = [r for r in all_rules()
                      if select is None or r.name in select]

    def lint_modules(self, modules: list[ModuleInfo]) -> list[Finding]:
        ctx = LintContext(modules)
        by_path = {m.path: m for m in modules}
        findings: set[Finding] = set()   # set: the jit closure rules can
        for mod in modules:              # reach one callee from many roots
            for rule in self.rules:
                for f in rule.check(mod, ctx):
                    owner = by_path.get(f.path, mod)
                    if not owner.suppressed(f):
                        findings.add(f)
        return sorted(findings)

    def lint_sources(self, sources: dict[str, str]) -> list[Finding]:
        """Lint in-memory sources ({path: text}) — the fixture-corpus
        entry point tests/test_analysis.py drives."""
        return self.lint_modules(
            [ModuleInfo(p, s) for p, s in sources.items()])

    def lint_paths(self, paths: list[str]) -> list[Finding]:
        modules = []
        for path in sorted(iter_python_files(paths)):
            text = pathlib.Path(path).read_text()
            try:
                modules.append(ModuleInfo(str(path), text))
            except SyntaxError as e:
                raise SystemExit(f"reprolint: cannot parse {path}: {e}")
        return self.lint_modules(modules)


def iter_python_files(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            for f in path.rglob("*.py"):
                if "__pycache__" not in f.parts:
                    yield str(f)
        elif path.suffix == ".py":
            yield str(path)
        else:
            raise SystemExit(f"reprolint: not a python file or dir: {p}")


def main(argv: Optional[list[str]] = None) -> int:
    from repro.analysis.rules import all_rules
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="reprolint: serving-invariant static analysis")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to lint (default: src/repro)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule names to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--format", default="text",
                    choices=("text", "json", "github"),
                    help="finding output format (github: workflow "
                         "annotations for inline PR review)")
    args = ap.parse_args(argv)
    if args.list_rules:
        for r in all_rules():
            print(f"{r.name:22s} {r.description}")
        return 0
    select = ({s.strip() for s in args.select.split(",") if s.strip()}
              if args.select else None)
    if select:
        known = {r.name for r in all_rules()}
        unknown = select - known
        if unknown:
            raise SystemExit(f"reprolint: unknown rule(s) "
                             f"{sorted(unknown)}; see --list-rules")
    findings = Linter(select=select).lint_paths(args.paths or ["src/repro"])
    emit_findings(findings, args.format)
    n = len(findings)
    summary = (f"reprolint: {n} finding{'s' if n != 1 else ''}"
               if n else "reprolint: clean")
    # json output must stay parseable as a whole; github annotations keep
    # the log scannable — route the human summary to stderr there
    print(summary, file=sys.stdout if args.format == "text" else sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
