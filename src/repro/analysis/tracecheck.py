"""tracecheck: IR-level static analysis of the jitted serving steps.

reprolint (repro.analysis.lint) checks invariants from *source* structure;
tracecheck checks the ones only visible in the *lowered IR*.  Every
registered serving step (make_paged_prefill_step / make_paged_decode_step /
make_slot_admit_step) is traced for every registry architecture (reduced
via ``configs.reduce_for_smoke``) and a set of pluggable analyzers walks
the jaxpr / lowered module / compiled executable:

  trace-cache    run a mixed serve workload (short+long prompts, greedy and
                 nucleus rows, forced preemption) through a real engine and
                 gate each jitted step's compile count (``_cache_size()``)
                 against TRACE_BUDGETS — a shape leak that would recompile
                 in production fails here first.
  donation       the cache carry must be donated per ST.STEP_DONATION in
                 every step, the donation must actually be elided in the
                 buffer assignment (alias_size), and no other large operand
                 may ride along undonated.
  host-transfer  no callback/infeed/outfeed primitive anywhere in the step
                 jaxpr, and the only host-bound outputs are the sanctioned
                 per-row (B,) token/logprob vectors — everything else must
                 be the cache carry.
  sharding       under the 8-device (data=4, model=2) host mesh, the
                 compiled step's cache *output* shardings must match the
                 ``core/sharding.paged_cache_specs`` declarations — XLA
                 silently replicating a pool would 2x serving HBM.
  cost-drift     XLA's static cost analysis of each compiled step (FLOPs /
                 bytes accessed / peak temps, via analysis/ircost.py) must
                 agree with ``core/costmodel.predict_serving_step`` within
                 the declared tolerances; the pair is committed to
                 BENCH_static_costs.json as the serving cost vector.

CLI mirrors reprolint::

    PYTHONPATH=src python -m repro.analysis.tracecheck
    PYTHONPATH=src python -m repro.analysis.tracecheck \\
        --arch qwen3-8b,mamba2-780m --select donation,host-transfer
    PYTHONPATH=src python -m repro.analysis.tracecheck \\
        --write-bench BENCH_static_costs.json
    PYTHONPATH=src python -m repro.analysis.tracecheck \\
        --validate-bench BENCH_static_costs.json

Exit status 1 on any finding (the CI gate), 0 when clean.
"""
from __future__ import annotations

import os

# The sharding-conformance analyzer needs the engine's CI mesh (data=4,
# model=2) — request 8 host devices BEFORE jax initializes.  setdefault:
# a no-op under the CI job env or an embedding test session that already
# chose its device count.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import sys
from typing import Iterable, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro import configs
from repro.analysis import ircost as IC
from repro.analysis.lint import Finding, emit_findings
from repro.core import costmodel as CM
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.runtime import steps as ST

# Per-step compile-count budgets for one drained mixed workload: chunked
# prefill pads to one shape, decode always advances the full slot batch,
# and admission resets one scalar-indexed slot — exactly one trace each.
TRACE_BUDGETS = {"paged_prefill": 1, "paged_decode": 1, "slot_admit": 1}

DEFAULT_GEOM = IC.ServeGeom()


def serve_mesh():
    """The largest (data, model=2) host mesh the process offers — the CI
    jobs run with XLA_FLAGS=--xla_force_host_platform_device_count=8,
    giving the engine's (4, 2) serving mesh."""
    n = jax.device_count()
    return make_host_mesh(model=2 if n % 2 == 0 and n >= 2 else 1)


@dataclasses.dataclass
class ArchContext:
    """Everything the analyzers share for one architecture: the smoke-
    reduced arch, serve geometry, mesh + ASA plan, and memoized lowerings."""
    arch: object
    geom: IC.ServeGeom
    mesh: object
    _plan: object = None

    @classmethod
    def for_arch(cls, name: str, geom: IC.ServeGeom = DEFAULT_GEOM,
                 mesh=None) -> "ArchContext":
        arch = configs.reduce_for_smoke(configs.get_arch(name))
        return cls(arch, geom, mesh if mesh is not None else serve_mesh())

    @property
    def plan(self):
        if self._plan is None:
            self._plan = IC.build_plan(self.arch, self.geom, self.mesh)
        return self._plan

    def kinds(self) -> tuple[str, ...]:
        return IC.step_kinds(self.arch)

    def lowered(self, kind: str, *, meshful: bool) -> IC.LoweredStep:
        return IC.lower_step(self.arch, kind, self.geom,
                             mesh=self.mesh if meshful else None,
                             plan=self.plan if meshful else None)

    def finding(self, kind: str, analyzer: str, message: str) -> Finding:
        return Finding(path=f"{self.arch.name}/{kind}", line=0, col=0,
                       rule=analyzer, message=message)


# ---------------------------------------------------------------------------
# analyzer 1: trace-cache audit (runs a real engine)
# ---------------------------------------------------------------------------

def _mixed_workload(ctx: ArchContext):
    """Requests spanning the shape space that historically caused trace
    leaks: short/long prompts (different chunk counts), greedy alongside
    nucleus-sampled rows, logprobs on/off, and a block pool tight enough
    to force preemption + re-admission."""
    from repro.serving.engine import Request
    from repro.serving.sampling import GREEDY, SamplingParams

    arch = ctx.arch
    frontend = None
    if arch.frontend == "vision":
        frontend = np.zeros((1, arch.n_img_tokens, arch.d_model), np.float32)
    elif arch.frontend == "audio":
        frontend = np.zeros((1, arch.encoder.seq_len, arch.d_model),
                            np.float32)
    sampling = [GREEDY,
                SamplingParams(temperature=0.8, top_k=50),
                SamplingParams(temperature=1.0, top_p=0.9),
                SamplingParams(logprobs=True)]
    reqs = []
    for i, (plen, mnt) in enumerate([(3, 20), (13, 12), (9, 16), (21, 6)]):
        reqs.append(Request(
            id=i, prompt=(np.arange(plen) % arch.vocab).astype(np.int32),
            max_new_tokens=mnt, sampling=sampling[i % len(sampling)],
            frontend=frontend))
    return reqs


def check_trace_cache(ctx: ArchContext) -> list[Finding]:
    from repro.serving.engine import ContinuousBatchingEngine

    arch = ctx.arch
    params = jax.jit(lambda k: T.init_lm(k, arch))(jax.random.PRNGKey(0))
    # slots=2 with a 12-usable-block pool: two in-flight requests need 13
    # blocks at peak, so the decode loop must preempt and re-admit —
    # recompute prefill re-traces through the same padded chunk shape
    eng = ContinuousBatchingEngine(
        arch, params, ctx.mesh, slots=2, max_len=48, block_size=4,
        num_blocks=13, prefill_chunk=8)
    eng.generate(_mixed_workload(ctx))

    findings = []
    jitted = {"paged_prefill": eng._prefill, "paged_decode": eng._decode}
    if eng._admit_slot_state is not None:
        jitted["slot_admit"] = eng._admit_slot_state
    for kind, fn in jitted.items():
        n = fn._cache_size()
        if n == 0:
            findings.append(ctx.finding(
                kind, "trace-cache",
                "step never executed during the audit workload — the "
                "budget check proved nothing"))
        elif n > TRACE_BUDGETS[kind]:
            findings.append(ctx.finding(
                kind, "trace-cache",
                f"compiled {n} distinct trace signatures over one drained "
                f"mixed workload (budget {TRACE_BUDGETS[kind]}) — an "
                f"argument shape/dtype is leaking into the trace"))
    if eng.metrics.preemptions == 0:
        findings.append(ctx.finding(
            "paged_decode", "trace-cache",
            "audit workload finished without a preemption — the tight-pool "
            "scenario no longer exercises recompute re-admission"))
    return findings


# ---------------------------------------------------------------------------
# analyzer 2: donation audit
# ---------------------------------------------------------------------------

def check_donation(ctx: ArchContext) -> list[Finding]:
    findings = []
    for kind in ctx.kinds():
        ls = ctx.lowered(kind, meshful=False)
        rep = IC.donation_report(ls)
        want = ST.STEP_DONATION[kind]
        if rep["donated_args"] != want:
            findings.append(ctx.finding(
                kind, "donation",
                f"donated args {rep['donated_args']} != STEP_DONATION "
                f"convention {want}"))
        elif rep["alias_bytes"] < rep["cache_bytes"]:
            findings.append(ctx.finding(
                kind, "donation",
                f"cache donation not elided: buffer assignment aliases "
                f"{rep['alias_bytes']} of {rep['cache_bytes']} cache bytes "
                f"— the pool is double-resident during the step"))
        for i, nbytes in enumerate(rep["arg_bytes"]):
            if i == 0 or i in want:        # params are read-only by design
                continue
            if nbytes >= 0.25 * rep["cache_bytes"]:
                findings.append(ctx.finding(
                    kind, "donation",
                    f"operand {i} holds {nbytes} undonated bytes "
                    f"(>=25% of the cache) with no convention entry"))
    return findings


# ---------------------------------------------------------------------------
# analyzer 3: host-transfer / callback detection
# ---------------------------------------------------------------------------

_HOST_PRIM_MARKERS = ("callback", "infeed", "outfeed")


def check_host_transfer(ctx: ArchContext) -> list[Finding]:
    findings = []
    for kind in ctx.kinds():
        ls = ctx.lowered(kind, meshful=False)
        bad = sorted(p for p in IC.primitive_census(ls)
                     if any(m in p for m in _HOST_PRIM_MARKERS))
        for prim in bad:
            findings.append(ctx.finding(
                kind, "host-transfer",
                f"host-crossing primitive {prim!r} inside the jitted step "
                f"— serving steps must stay device-resident"))
        outs = IC.output_structure(ls)
        cache_td = jax.tree.structure(ls.args[ls.cache_index])
        if kind == "slot_admit":
            if jax.tree.structure(outs) != cache_td:
                findings.append(ctx.finding(
                    kind, "host-transfer",
                    "slot_admit must return exactly the cache carry"))
            continue
        B = ls.args[2].shape[0]
        ok = (isinstance(outs, tuple) and len(outs) == 3
              and outs[0].shape == (B,) and outs[1].shape == (B,)
              and jax.tree.structure(outs[2]) == cache_td)
        if not ok:
            findings.append(ctx.finding(
                kind, "host-transfer",
                f"outputs are not the sanctioned (token (B,), logprob "
                f"(B,), cache) contract (B={B}) — any extra output is an "
                f"unsanctioned device->host transfer per step"))
    return findings


# ---------------------------------------------------------------------------
# analyzer 4: sharding conformance
# ---------------------------------------------------------------------------

def check_sharding(ctx: ArchContext) -> list[Finding]:
    findings = []
    expected = jax.tree.map(lambda s: NamedSharding(ctx.mesh, s),
                            ctx.plan.paged_cache_specs())
    exp_flat, exp_td = jax.tree.flatten(expected)
    for kind in ctx.kinds():
        ls = ctx.lowered(kind, meshful=True)
        out_sh = IC.output_shardings(ls)
        outs = IC.output_structure(ls)
        cache_sh = out_sh if kind == "slot_admit" else out_sh[2]
        cache_sds = outs if kind == "slot_admit" else outs[2]
        got, got_td = jax.tree.flatten(cache_sh)
        if got_td != exp_td:
            findings.append(ctx.finding(
                kind, "sharding",
                f"cache output tree {got_td} does not match "
                f"paged_cache_specs tree"))
            continue
        paths = [jax.tree_util.keystr(p) for p, _ in
                 jax.tree_util.tree_flatten_with_path(cache_sds)[0]]
        for path, sds, g, w in zip(
                paths, jax.tree.leaves(cache_sds), got, exp_flat):
            if not g.is_equivalent_to(w, len(sds.shape)):
                findings.append(ctx.finding(
                    kind, "sharding",
                    f"cache pool {path} compiled to {g.spec} but "
                    f"core/sharding.paged_cache_specs declares {w.spec}"))
    return findings


# ---------------------------------------------------------------------------
# analyzer 5: static cost extraction / drift vs core/costmodel.py
# ---------------------------------------------------------------------------

def bench_row(ctx: ArchContext, kind: str) -> dict:
    """Extracted-vs-predicted static cost for one (arch, step) cell — one
    row of BENCH_static_costs.json."""
    ls = ctx.lowered(kind, meshful=False)
    rep = IC.cost_report(ls)
    batch = 1 if kind == "paged_prefill" else ctx.geom.slots
    new_tokens = ctx.geom.prefill_chunk if kind == "paged_prefill" else 1
    pred = CM.predict_serving_step(ctx.arch, batch=batch,
                                   new_tokens=new_tokens,
                                   table_len=ctx.geom.table_len)
    flops_rel_err = abs(rep["flops"] - pred["flops"]) / max(pred["flops"], 1.0)
    lo = max(min(rep["bytes"], pred["bytes"]), 1.0)
    bytes_ratio = max(rep["bytes"], pred["bytes"]) / lo
    return {
        "arch": ctx.arch.name, "step": kind,
        "batch": batch, "new_tokens": new_tokens,
        "table_len": ctx.geom.table_len,
        "flops_extracted": rep["flops"], "flops_predicted": pred["flops"],
        "flops_rel_err": round(flops_rel_err, 4),
        "bytes_extracted": rep["bytes"], "bytes_predicted": pred["bytes"],
        "bytes_ratio": round(bytes_ratio, 2),
        "temp_bytes_peak": rep["temp_bytes"],
    }


def check_cost_drift(ctx: ArchContext) -> list[Finding]:
    findings = []
    for kind in ("paged_prefill", "paged_decode"):
        row = bench_row(ctx, kind)
        if row["flops_rel_err"] > CM.SERVING_FLOPS_RTOL:
            findings.append(ctx.finding(
                kind, "cost-drift",
                f"extracted {row['flops_extracted']:.3g} FLOPs vs "
                f"predicted {row['flops_predicted']:.3g} — rel err "
                f"{row['flops_rel_err']:.2f} > SERVING_FLOPS_RTOL "
                f"{CM.SERVING_FLOPS_RTOL} (costmodel.predict_serving_step "
                f"no longer models this step)"))
        if row["bytes_ratio"] > CM.SERVING_BYTES_RFACTOR:
            findings.append(ctx.finding(
                kind, "cost-drift",
                f"extracted {row['bytes_extracted']:.3g} bytes vs "
                f"predicted {row['bytes_predicted']:.3g} — ratio "
                f"{row['bytes_ratio']:.1f} > SERVING_BYTES_RFACTOR "
                f"{CM.SERVING_BYTES_RFACTOR}"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

ANALYZERS = {
    "trace-cache": (check_trace_cache,
                    "compile-count budgets over a drained mixed workload"),
    "donation": (check_donation,
                 "cache donated per STEP_DONATION and elided in buffers"),
    "host-transfer": (check_host_transfer,
                      "no callbacks; only (B,) token/logprob leave device"),
    "sharding": (check_sharding,
                 "cache output shardings match paged_cache_specs"),
    "cost-drift": (check_cost_drift,
                   "XLA static costs agree with costmodel predictions"),
}


def run_analyzers(arch_names: Optional[Iterable[str]] = None,
                  select: Optional[Iterable[str]] = None,
                  geom: IC.ServeGeom = DEFAULT_GEOM,
                  mesh=None) -> list[Finding]:
    names = sorted(arch_names) if arch_names else sorted(configs.ARCHS)
    chosen = list(select) if select else list(ANALYZERS)
    mesh = mesh if mesh is not None else serve_mesh()
    findings: list[Finding] = []
    for name in names:
        ctx = ArchContext.for_arch(name, geom, mesh)
        for a in chosen:
            findings.extend(ANALYZERS[a][0](ctx))
    return sorted(findings)


# ---------------------------------------------------------------------------
# BENCH_static_costs.json
# ---------------------------------------------------------------------------

BENCH_ROW_FIELDS = ("arch", "step", "batch", "new_tokens", "table_len",
                    "flops_extracted", "flops_predicted", "flops_rel_err",
                    "bytes_extracted", "bytes_predicted", "bytes_ratio",
                    "temp_bytes_peak")


def collect_bench(arch_names: Optional[Iterable[str]] = None,
                  geom: IC.ServeGeom = DEFAULT_GEOM) -> dict:
    names = sorted(arch_names) if arch_names else sorted(configs.ARCHS)
    rows = []
    for name in names:
        ctx = ArchContext.for_arch(name, geom)
        for kind in ("paged_prefill", "paged_decode"):
            rows.append(bench_row(ctx, kind))
    return {
        "schema_version": 1,
        "geometry": dataclasses.asdict(geom),
        "tolerances": {"flops_rtol": CM.SERVING_FLOPS_RTOL,
                       "bytes_rfactor": CM.SERVING_BYTES_RFACTOR},
        "rows": rows,
    }


def validate_bench(doc: dict,
                   require_archs: Optional[Iterable[str]] = None) \
        -> list[str]:
    """Schema + tolerance validation of a committed BENCH_static_costs.json
    (the CI check that the committed cost vector is well-formed and within
    its own declared drift bounds).  Returns human-readable errors."""
    errors = []
    for key in ("schema_version", "geometry", "tolerances", "rows"):
        if key not in doc:
            errors.append(f"missing top-level key {key!r}")
    if errors:
        return errors
    tol = doc["tolerances"]
    for t in ("flops_rtol", "bytes_rfactor"):
        if not isinstance(tol.get(t), (int, float)):
            errors.append(f"tolerances.{t} missing or non-numeric")
    seen = set()
    for i, row in enumerate(doc["rows"]):
        for f in BENCH_ROW_FIELDS:
            if f not in row:
                errors.append(f"rows[{i}] missing field {f!r}")
                break
        else:
            if not all(isinstance(row[f], (int, float))
                       for f in BENCH_ROW_FIELDS[2:]):
                errors.append(f"rows[{i}] has non-numeric cost fields")
                continue
            seen.add((row["arch"], row["step"]))
            if row["flops_rel_err"] > tol.get("flops_rtol", 0):
                errors.append(
                    f"rows[{i}] ({row['arch']}/{row['step']}): "
                    f"flops_rel_err {row['flops_rel_err']} exceeds "
                    f"declared flops_rtol {tol.get('flops_rtol')}")
            if row["bytes_ratio"] > tol.get("bytes_rfactor", 0):
                errors.append(
                    f"rows[{i}] ({row['arch']}/{row['step']}): "
                    f"bytes_ratio {row['bytes_ratio']} exceeds declared "
                    f"bytes_rfactor {tol.get('bytes_rfactor')}")
    for name in (sorted(require_archs) if require_archs
                 else sorted(configs.ARCHS)):
        smoke = name + "-smoke"
        for kind in ("paged_prefill", "paged_decode"):
            if (smoke, kind) not in seen:
                errors.append(f"no row for {smoke}/{kind}")
    return errors


# ---------------------------------------------------------------------------
# CLI (mirrors reprolint)
# ---------------------------------------------------------------------------

def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.tracecheck",
        description="IR-level static analysis of the jitted serving steps")
    ap.add_argument("--arch", default=None,
                    help="comma-separated registry arch names "
                         "(default: the whole registry)")
    ap.add_argument("--select", default=None,
                    help="comma-separated analyzer names (default: all)")
    ap.add_argument("--list-analyzers", action="store_true",
                    help="print the analyzer catalogue and exit")
    ap.add_argument("--format", default="text",
                    choices=("text", "json", "github"),
                    help="finding output format (github: workflow "
                         "annotations)")
    ap.add_argument("--write-bench", metavar="PATH", default=None,
                    help="extract static costs for every arch and write "
                         "the BENCH_static_costs.json document to PATH")
    ap.add_argument("--validate-bench", metavar="PATH", default=None,
                    help="schema/tolerance-check a committed bench file "
                         "and exit")
    args = ap.parse_args(argv)

    if args.list_analyzers:
        for name, (_, desc) in ANALYZERS.items():
            print(f"{name:16s} {desc}")
        return 0

    if args.validate_bench:
        with open(args.validate_bench) as f:
            errors = validate_bench(json.load(f))
        for e in errors:
            print(f"{args.validate_bench}: {e}")
        print(f"tracecheck: bench "
              f"{'INVALID' if errors else 'valid'} ({len(errors)} errors)")
        return 1 if errors else 0

    archs = ([a.strip() for a in args.arch.split(",") if a.strip()]
             if args.arch else None)
    for a in archs or []:
        configs.get_arch(a)            # precise unknown-arch error

    if args.write_bench:
        doc = collect_bench(archs)
        with open(args.write_bench, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        worst = max((r["flops_rel_err"] for r in doc["rows"]), default=0.0)
        print(f"tracecheck: wrote {len(doc['rows'])} rows to "
              f"{args.write_bench} (worst flops_rel_err {worst:.3f})")
        return 0

    select = ({s.strip() for s in args.select.split(",") if s.strip()}
              if args.select else None)
    if select:
        unknown = select - set(ANALYZERS)
        if unknown:
            raise SystemExit(f"tracecheck: unknown analyzer(s) "
                             f"{sorted(unknown)}; see --list-analyzers")
    findings = run_analyzers(archs, select)
    emit_findings(findings, args.format, tool="tracecheck")
    n = len(findings)
    if args.format == "text":
        print(f"tracecheck: {n} finding{'s' if n != 1 else ''}"
              if n else "tracecheck: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
