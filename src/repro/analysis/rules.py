"""reprolint rules: one class per enforced serving-stack invariant.

Every rule exists because a real bug class shipped (or nearly shipped) in
PRs 1-6 and is now pinned only by after-the-fact regression tests; the
rules check the *structure* that makes the bug impossible.  The catalogue
(details and motivating bugs in docs/INVARIANTS.md):

  jit-host-sync        no ``print`` / ``.item()`` / ``np.asarray`` /
                       ``jax.device_get`` inside the jitted step builders
                       or anything they (transitively) call — a host sync
                       in the fused step serializes every engine step.
  jit-recompile-hazard no Python ``if``/``while`` on a *traced value*
                       inside a jitted scope — it either recompiles per
                       value or raises ConcretizationTypeError.  Branching
                       on ``.shape``/``.ndim``/``len()`` is static and
                       allowed.
  prng-discipline      serving code must derive sampling keys as
                       ``fold_in(key, absolute_position)`` and never
                       ``split`` — key streams must be pure functions of
                       (seed, position) or recompute-preemption replays a
                       different token stream (the PR 5 determinism
                       invariant).
  refcount-pairing     a local holding ``BlockAllocator.alloc()`` blocks
                       must, on every exit path (including exception
                       edges), either transfer ownership (stored /
                       returned / passed on) or free them — a bare exit
                       leaks physical blocks until engine restart.
  atomic-write         file writes under serving/ go through
                       ``serving/export.atomic_write_text`` — a crash
                       mid-write must never leave truncated JSON where an
                       exporter/consumer will parse it.
  clock-injection      no ambient clock (``time.time``/``perf_counter``/
                       ...) in serving/ — all timestamps come from the
                       injectable engine clock, or TTFT/TPOT are
                       fabricated from mixed clocks (the PR 5 bug class).

Static-analysis honesty: these are linters, not proofs.  Each rule's
docstring states what it can and cannot see; the runtime
``analysis/sanitizer.py`` covers the dynamic remainder (e.g. incref/
decref pairing across functions, which no intraprocedural pass can
check, is cross-validated against live block tables every engine step).
"""
from __future__ import annotations

import ast
import re
from typing import Iterable, Optional

from repro.analysis.lint import Finding, LintContext, ModuleInfo

# top-level factory functions whose nested defs are jit-traced: the step
# builders (runtime/steps.py), the fused sampler factory, and any future
# make_* factory that returns a function destined for jax.jit
BUILDER_RE = re.compile(r"^make_\w*$")

# attributes that read static metadata off a tracer — deriving from these
# does NOT taint (shapes are compile-time constants under jit)
SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "sharding"}

# builtins that only inspect a value: passing an alloc-result to these
# does not transfer ownership
INSPECTOR_FUNCS = {"len", "bool", "repr", "str", "print", "isinstance",
                   "type", "sorted", "sum", "min", "max", "any", "all",
                   "iter", "reversed", "enumerate", "id", "format", "hash"}


class Rule:
    name = ""
    description = ""

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        raise NotImplementedError

    def finding(self, module: ModuleInfo, node: ast.AST, message: str) \
            -> Finding:
        return Finding(path=module.path, line=node.lineno,
                       col=node.col_offset + 1, rule=self.name,
                       message=message)


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def dotted_name(node: ast.expr, module: Optional[ModuleInfo] = None) \
        -> Optional[str]:
    """``jax.random.fold_in`` for an Attribute chain over Names, with the
    head alias resolved through the module's imports (``import numpy as
    np`` makes ``np.asarray`` read as ``numpy.asarray``)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    head = node.id
    if module is not None:
        if head in module.import_aliases:
            head = module.import_aliases[head]
        elif head in module.from_imports:
            fmod, orig = module.from_imports[head]
            head = f"{fmod}.{orig}"
    parts.append(head)
    return ".".join(reversed(parts))


def _nested_functions(fn: ast.FunctionDef) -> Iterable[ast.FunctionDef]:
    for node in ast.walk(fn):
        if node is not fn and isinstance(node, (ast.FunctionDef,
                                                ast.AsyncFunctionDef)):
            yield node


def _own_statements(fn: ast.FunctionDef) -> Iterable[ast.AST]:
    """Walk a function's AST *excluding* nested function bodies (those are
    analyzed as their own scopes)."""
    stack = list(fn.body)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)


def _jit_static_names(fn: ast.FunctionDef, module: ModuleInfo) \
        -> Optional[frozenset]:
    """If ``fn`` is decorated with jax.jit (bare or via functools.partial),
    return its static_argnames as a frozenset (possibly empty); None when
    it is not jit-decorated."""
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        dn = dotted_name(target, module) or ""
        if dn.endswith("jax.jit") or dn == "jit":
            return frozenset()
        if dn.endswith("functools.partial") or dn == "partial":
            if isinstance(dec, ast.Call) and dec.args:
                inner = dotted_name(dec.args[0], module) or ""
                if inner.endswith("jax.jit") or inner == "jit":
                    static: set[str] = set()
                    for kw in dec.keywords:
                        if kw.arg in ("static_argnames", "static_argnums") \
                                and isinstance(kw.value,
                                               (ast.Tuple, ast.List)):
                            for el in kw.value.elts:
                                if isinstance(el, ast.Constant) \
                                        and isinstance(el.value, str):
                                    static.add(el.value)
                    return frozenset(static)
    return None


def traced_roots(module: ModuleInfo, ctx: LintContext) \
        -> list[tuple[ModuleInfo, ast.FunctionDef, frozenset]]:
    """Jit-traced entry functions in ``module``: nested defs of make_*
    builders (their params are the traced arguments; the builder's own
    params are trace-time constants), @jax.jit-decorated functions (minus
    static_argnames), and module functions passed to ``jax.jit(name)``."""
    roots: list[tuple[ModuleInfo, ast.FunctionDef, frozenset]] = []
    seen: set[int] = set()

    def add(fn: ast.FunctionDef, static: frozenset) -> None:
        if id(fn) not in seen:
            seen.add(id(fn))
            roots.append((module, fn, static))

    for fn in module.functions.values():
        static = _jit_static_names(fn, module)
        if static is not None:
            add(fn, static)
        if BUILDER_RE.match(fn.name):
            for inner in _nested_functions(fn):
                add(inner, frozenset())
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call):
            dn = dotted_name(node.func, module) or ""
            if (dn.endswith("jax.jit") or dn == "jit") and node.args \
                    and isinstance(node.args[0], ast.Name):
                target = module.functions.get(node.args[0].id)
                if target is not None:
                    add(target, frozenset())
    return roots


def jit_reachable(module: ModuleInfo, ctx: LintContext) \
        -> list[tuple[ModuleInfo, ast.FunctionDef, bool]]:
    """Traced roots plus every analyzed function transitively reachable
    from them via resolvable calls (same-module names, imported modules in
    the fileset).  The bool marks roots (where traced-argument taint is
    known) vs transitive callees (host-sync ops only)."""
    out: list[tuple[ModuleInfo, ast.FunctionDef, bool]] = []
    visited: set[int] = set()
    queue: list[tuple[ModuleInfo, ast.FunctionDef]] = []
    for mod, fn, _static in traced_roots(module, ctx):
        if id(fn) not in visited:
            visited.add(id(fn))
            out.append((mod, fn, True))
            queue.append((mod, fn))
    while queue:
        mod, fn = queue.pop()
        for node in _own_statements(fn):
            if not isinstance(node, ast.Call):
                continue
            resolved = ctx.resolve_call(mod, node.func)
            if resolved is None and isinstance(node.func, ast.Name):
                # nested helper defined in an enclosing builder scope
                for inner in _nested_functions(fn):
                    if inner.name == node.func.id:
                        resolved = (mod, inner)
                        break
            if resolved is not None and id(resolved[1]) not in visited:
                visited.add(id(resolved[1]))
                out.append((resolved[0], resolved[1], False))
                queue.append(resolved)
    return out


def compute_taint(fn: ast.FunctionDef, static: frozenset) -> set:
    """Names holding traced values inside a jit-traced function: the
    parameters (minus jit static_argnames) plus anything assigned from an
    expression over them — except pure shape/metadata derivations, which
    are compile-time constants."""
    args = fn.args
    tainted: set[str] = {a.arg for a in (args.posonlyargs + args.args
                                         + args.kwonlyargs)} - set(static)
    for extra in (args.vararg, args.kwarg):
        if extra is not None:
            tainted.add(extra.arg)
    for _ in range(2):                      # fixpoint for chained assigns
        for node in _own_statements(fn):
            targets = []
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AugAssign):
                targets, value = [node.target], node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            elif isinstance(node, ast.For):
                targets, value = [node.target], node.iter
            else:
                continue
            if expr_tainted(value, tainted):
                for t in targets:
                    for name in ast.walk(t):
                        if isinstance(name, ast.Name):
                            tainted.add(name.id)
    return tainted


def expr_tainted(node: ast.expr, tainted: set) -> bool:
    """Does evaluating ``node`` produce a traced value?  Shape/metadata
    accesses and ``len()`` are static under jit and break the taint."""
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in SHAPE_ATTRS:
            return False
        return expr_tainted(node.value, tainted)
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id == "len":
            return False
        parts = list(node.args) + [kw.value for kw in node.keywords]
        if isinstance(node.func, ast.Attribute):
            # method call on a traced value (x.sum(), x.astype(...))
            parts.append(node.func)
        return any(expr_tainted(p, tainted) for p in parts)
    if isinstance(node, (ast.Constant, ast.Lambda)):
        return False
    return any(expr_tainted(child, tainted)
               for child in ast.iter_child_nodes(node)
               if isinstance(child, ast.expr))


# ---------------------------------------------------------------------------
# jit-host-sync
# ---------------------------------------------------------------------------

class JitHostSync(Rule):
    """Host-synchronizing ops inside jit-traced code.

    ``print`` on a tracer prints the abstract value once at trace time
    (silent data loss) or, under ``io_callback`` idioms, blocks the step;
    ``.item()`` / ``np.asarray`` / ``jax.device_get`` force a device->host
    transfer that serializes the fused step the engine's whole throughput
    story rests on.  Checked for the traced roots AND everything they
    transitively call within the analyzed fileset (runtime/steps.py pulls
    in the model stack).  ``float()/int()/bool()`` on traced values are
    flagged in roots, where the traced-argument set is known."""
    name = "jit-host-sync"
    description = ("no print/.item()/np.asarray/device_get (host syncs) in "
                   "jit-traced code or anything it calls")

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        findings = []
        for mod, fn, is_root in jit_reachable(module, ctx):
            taint = None
            if is_root:
                static = _jit_static_names(fn, mod) or frozenset()
                taint = compute_taint(fn, static)
            for node in _own_statements(fn):
                if not isinstance(node, ast.Call):
                    continue
                f = self._host_op(node, mod, taint)
                if f is not None:
                    where = (f"jit-traced `{fn.name}`" if is_root else
                             f"`{fn.name}` (reached from a jitted scope)")
                    findings.append(self.finding(
                        mod, node, f"{f} inside {where} forces a host "
                        f"sync / trace-time side effect"))
        return findings

    def _host_op(self, node: ast.Call, mod: ModuleInfo,
                 taint: Optional[set]) -> Optional[str]:
        func = node.func
        if isinstance(func, ast.Name) and func.id == "print":
            return "print()"
        if isinstance(func, ast.Attribute) and func.attr == "item" \
                and not node.args:
            return ".item()"
        dn = dotted_name(func, mod) or ""
        if dn in ("numpy.asarray", "numpy.array"):
            return f"{dn}()"
        if dn.endswith("jax.device_get"):
            return "jax.device_get()"
        if isinstance(func, ast.Name) and func.id in ("float", "int",
                                                      "bool") \
                and taint is not None and node.args \
                and expr_tainted(node.args[0], taint):
            return f"{func.id}() on a traced value"
        return None


# ---------------------------------------------------------------------------
# jit-recompile-hazard
# ---------------------------------------------------------------------------

class JitRecompileHazard(Rule):
    """Python control flow on traced values inside a jitted scope.

    ``if x > 0`` on a tracer raises ConcretizationTypeError at trace time
    (or, with concrete leaves, silently bakes one branch in and
    recompiles per distinct value).  The engine's fused steps must trace
    exactly once per shape — branch with ``jnp.where``/``lax.cond``
    instead.  Branching on ``.shape``/``.ndim``/``len()`` and on builder
    closure parameters is static and allowed."""
    name = "jit-recompile-hazard"
    description = ("no Python if/while/assert on traced values in jitted "
                   "scopes (use jnp.where / lax.cond)")

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        findings = []
        for mod, fn, static in traced_roots(module, ctx):
            if mod is not module:
                continue
            taint = compute_taint(fn, static)
            for node in _own_statements(fn):
                test = None
                kind = None
                if isinstance(node, (ast.If, ast.While)):
                    test, kind = node.test, type(node).__name__.lower()
                elif isinstance(node, ast.IfExp):
                    test, kind = node.test, "conditional expression"
                elif isinstance(node, ast.Assert):
                    test, kind = node.test, "assert"
                if test is not None and expr_tainted(test, taint):
                    findings.append(self.finding(
                        mod, node,
                        f"Python `{kind}` on a traced value in jit-traced "
                        f"`{fn.name}` — recompiles per value or raises at "
                        f"trace time; use jnp.where/lax.cond"))
        return findings


# ---------------------------------------------------------------------------
# prng-discipline
# ---------------------------------------------------------------------------

class PrngDiscipline(Rule):
    """Serving PRNG keys must be ``fold_in(key, absolute_position)``.

    Preemption-proof determinism (PR 5) requires a token's sampling key
    to be a pure function of (request seed, absolute position) — with no
    dependence on batch row, step count, or scheduling history.  ``split``
    is order-dependent state threading, so it is banned outright in
    serving/; random draws must take a key that is (a name bound to) a
    ``fold_in(...)`` result.  Key material for *initialization* outside
    draw sites is not this rule's concern."""
    name = "prng-discipline"
    description = ("serving/ PRNG keys derive via fold_in(seed, position); "
                   "jax.random.split is banned")

    DRAWS = {"gumbel", "uniform", "normal", "categorical", "bernoulli",
             "randint", "choice", "truncated_normal", "exponential",
             "gamma", "poisson", "laplace", "bits", "permutation"}

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        if not module.in_serving:
            return []
        findings = []
        scopes = list(module.functions.values())
        for fn in list(scopes):
            scopes.extend(_nested_functions(fn))
        for fn in scopes:
            findings.extend(self._check_scope(module, fn))
        return findings

    def _is_random(self, dn: str) -> bool:
        return ".random." in f".{dn}" or dn.startswith("random.")

    def _check_scope(self, module: ModuleInfo, fn: ast.FunctionDef) \
            -> list[Finding]:
        findings = []
        derived: set[str] = set()          # names bound to fold_in results
        for node in _own_statements(fn):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                dn = dotted_name(node.value.func, module) or ""
                if dn.endswith("fold_in"):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            derived.add(t.id)
        for node in _own_statements(fn):
            if not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func, module) or ""
            attr = dn.rsplit(".", 1)[-1]
            if attr == "split" and self._is_random(dn):
                findings.append(self.finding(
                    module, node,
                    "jax.random.split in serving/ — key streams must be "
                    "pure fold_in(seed, absolute_position) derivations or "
                    "preemption replays a different stream"))
            elif attr in self.DRAWS and self._is_random(dn):
                key = node.args[0] if node.args else None
                for kw in node.keywords:
                    if kw.arg == "key":
                        key = kw.value
                if not self._key_ok(key, module, derived):
                    findings.append(self.finding(
                        module, node,
                        f"jax.random.{attr} with a key not derived via "
                        f"fold_in(seed, absolute_position) — sampling must "
                        f"be a pure function of (seed, position) to stay "
                        f"preemption/restart deterministic"))
        return findings

    def _key_ok(self, key: Optional[ast.expr], module: ModuleInfo,
                derived: set) -> bool:
        if key is None:
            return False
        if isinstance(key, ast.Name):
            return key.id in derived
        if isinstance(key, ast.Call):
            dn = dotted_name(key.func, module) or ""
            return dn.endswith("fold_in")
        return False


# ---------------------------------------------------------------------------
# refcount-pairing
# ---------------------------------------------------------------------------

class RefcountPairing(Rule):
    """Alloc-result ownership on every exit path.

    Tracks locals assigned from ``<allocator>.alloc(...)`` through a
    simplified per-function control-flow walk.  On every exit (return,
    raise, end of body) the blocks must have been *consumed*: stored into
    a table/field, returned, passed to a non-inspecting call (ownership
    transfer), or freed (``free``/``decref`` — including a loop over the
    list that decrefs).  Statements that can raise while blocks are
    unconsumed and no enclosing ``try`` protects them are flagged as
    exception-edge leaks.  ``if x is None: return`` after an alloc is the
    sanctioned OOM path (``alloc`` is all-or-nothing) and never flags.

    Intraprocedural by design: cross-function incref/decref pairing (the
    prefix index holding one ref per committed block, etc.) cannot be
    proven statically and is instead cross-validated at runtime by
    ``analysis/sanitizer.py`` against live block tables every step."""
    name = "refcount-pairing"
    description = ("BlockAllocator.alloc results must be stored, returned "
                   "or freed on every exit path (incl. exception edges)")

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        findings = []
        scopes = []
        for fn in module.functions.values():
            scopes.append(fn)
            scopes.extend(_nested_functions(fn))
        for cls in (n for n in ast.walk(module.tree)
                    if isinstance(n, ast.ClassDef)):
            for item in cls.body:
                if isinstance(item, ast.FunctionDef):
                    scopes.append(item)
                    scopes.extend(_nested_functions(item))
        for fn in scopes:
            findings.extend(_AllocWalker(self, module, fn).run())
        return findings


def _is_alloc_call(node: ast.expr) -> bool:
    return isinstance(node, ast.Call) \
        and isinstance(node.func, ast.Attribute) \
        and node.func.attr == "alloc"


def _names_in(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _contains_call(node: ast.AST) -> bool:
    return any(isinstance(n, ast.Call) for n in ast.walk(node))


class _AllocWalker:
    """Simplified path walker for one function (see RefcountPairing)."""

    def __init__(self, rule: RefcountPairing, module: ModuleInfo,
                 fn: ast.FunctionDef):
        self.rule, self.module, self.fn = rule, module, fn
        self.live: dict[str, int] = {}       # name -> alloc line
        self.findings: list[Finding] = []
        self.reported: set[tuple] = set()
        self.protected = 0                   # inside try with handler/finally

    def run(self) -> list[Finding]:
        terminated = self.block(self.fn.body)
        if not terminated:
            self.leak_all(self.fn, "at the end of the function")
        return self.findings

    # -- reporting ------------------------------------------------------
    def report(self, node: ast.AST, name: str, why: str) -> None:
        key = (name, why.split(" ", 1)[0], node.lineno)
        if key in self.reported:
            return
        self.reported.add(key)
        line = self.live.get(name, node.lineno)
        self.findings.append(self.rule.finding(
            self.module, node,
            f"blocks in `{name}` (allocated line {line}) {why} — every "
            f"exit path must store, return or free an alloc result"))

    def leak_all(self, node: ast.AST, where: str) -> None:
        for name in list(self.live):
            self.report(node, name, f"leak {where}")

    # -- consumption ----------------------------------------------------
    def consume_in(self, stmt: ast.stmt) -> bool:
        """Mark tracked names consumed by this statement; True if any."""
        consumed = False
        if isinstance(stmt, ast.Assign):
            names = _names_in(stmt.value) & set(self.live)
            if names and not _is_alloc_call(stmt.value):
                # storing (table[x] = blocks / self.f = blocks) or
                # aliasing transfers ownership
                for n in names:
                    del self.live[n]
                consumed = True
        elif isinstance(stmt, ast.AugAssign):
            names = _names_in(stmt.value) & set(self.live)
            for n in names:
                del self.live[n]
            consumed = bool(names)
        elif isinstance(stmt, ast.Return) and stmt.value is not None:
            names = _names_in(stmt.value) & set(self.live)
            for n in names:
                del self.live[n]
            consumed = bool(names)
        elif isinstance(stmt, ast.Expr):
            consumed = self._consume_calls(stmt.value)
        elif isinstance(stmt, ast.For):
            # `for b in blocks: ...decref(b)/free(b)...` frees the list
            names = _names_in(stmt.iter) & set(self.live)
            if names and any(
                    isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr in ("decref", "free")
                    for n in ast.walk(stmt)):
                for n in names:
                    del self.live[n]
                consumed = True
        return consumed

    def _consume_calls(self, expr: ast.expr) -> bool:
        consumed = False
        for call in (n for n in ast.walk(expr) if isinstance(n, ast.Call)):
            if isinstance(call.func, ast.Name) \
                    and call.func.id in INSPECTOR_FUNCS:
                continue
            args = list(call.args) + [kw.value for kw in call.keywords]
            names = set()
            for a in args:
                names |= _names_in(a) & set(self.live)
            if names:
                for n in names:
                    del self.live[n]
                consumed = True
        return consumed

    # -- walk -----------------------------------------------------------
    def block(self, stmts: list) -> bool:
        """Process a statement list; True if the path surely terminated."""
        for stmt in stmts:
            if self.statement(stmt):
                return True
        return False

    def statement(self, stmt: ast.stmt) -> bool:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return False                       # separate scope
        consumed = self.consume_in(stmt)

        if isinstance(stmt, ast.Assign) and _is_alloc_call(stmt.value) \
                and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            self.live[stmt.targets[0].id] = stmt.lineno
            return False
        if isinstance(stmt, ast.Expr) and _is_alloc_call(stmt.value):
            self.findings.append(self.rule.finding(
                self.module, stmt,
                "alloc() result discarded — the granted blocks can never "
                "be freed"))
            return False

        if isinstance(stmt, ast.Return):
            self.leak_all(stmt, "at this return")
            return True
        if isinstance(stmt, ast.Raise):
            if not self.protected:
                self.leak_all(stmt, "through this raise")
            return True
        if isinstance(stmt, (ast.Break, ast.Continue)):
            return True

        if isinstance(stmt, ast.If):
            return self._if(stmt)
        if isinstance(stmt, ast.Try) or (hasattr(ast, "TryStar")
                                         and isinstance(stmt, ast.TryStar)):
            return self._try(stmt)
        if isinstance(stmt, (ast.For, ast.While)):
            self.block(stmt.body)
            self.block(stmt.orelse)
            return False
        if isinstance(stmt, ast.With):
            return self.block(stmt.body)

        # exception edge: a raising call while blocks are live and no
        # try protects them
        if not consumed and self.live and not self.protected \
                and _contains_call(stmt):
            for name in list(self.live):
                self.report(stmt, name,
                            "may leak on this exception edge (the call can "
                            "raise before ownership transfers; wrap in "
                            "try/finally or free first)")
        return False

    def _none_guarded(self, test: ast.expr) -> Optional[str]:
        """`x is None` / `not x` test → the alloc-failure guard name."""
        if isinstance(test, ast.Compare) and isinstance(test.left, ast.Name) \
                and len(test.ops) == 1 \
                and isinstance(test.ops[0], (ast.Is, ast.Eq)) \
                and isinstance(test.comparators[0], ast.Constant) \
                and test.comparators[0].value is None:
            return test.left.id
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not) \
                and isinstance(test.operand, ast.Name):
            return test.operand.id
        return None

    def _if(self, stmt: ast.If) -> bool:
        guard = self._none_guarded(stmt.test)
        saved = dict(self.live)
        if guard in self.live:
            del self.live[guard]             # alloc failed: nothing granted
        body_term = self.block(stmt.body)
        body_live = self.live
        self.live = dict(saved)
        else_term = self.block(stmt.orelse) if stmt.orelse else False
        else_live = self.live
        if body_term and (else_term or not stmt.orelse):
            self.live = else_live if body_term and not else_term else {}
            if body_term and not stmt.orelse:
                self.live = else_live
            return body_term and else_term
        # a name stays live if it survives any fall-through branch
        merged: dict[str, int] = {}
        if not body_term:
            merged.update(body_live)
        if not else_term:
            merged.update(else_live)
        self.live = merged
        return False

    def _try(self, stmt) -> bool:
        protected = bool(stmt.handlers) or bool(stmt.finalbody)
        if protected:
            self.protected += 1
        term = self.block(stmt.body)
        if protected:
            self.protected -= 1
        for handler in stmt.handlers:
            saved = dict(self.live)
            self.block(handler.body)
            self.live = saved
        self.block(stmt.finalbody)
        return term and not stmt.finalbody


# ---------------------------------------------------------------------------
# atomic-write
# ---------------------------------------------------------------------------

class AtomicWrite(Rule):
    """Serving file writes route through export.atomic_write_text.

    A metrics/trace/snapshot consumer (CI validators, dashboards, the
    bench) reading a file mid-write must see either the old version or
    the complete new one — never a truncated JSON.  ``atomic_write_text``
    (temp file + fsync + ``os.replace``) is the one sanctioned primitive;
    its own ``os.fdopen`` carries the documented suppression."""
    name = "atomic-write"
    description = ("serving/ file writes must use export.atomic_write_text "
                   "(no bare open(..., 'w'))")

    WRITE_MODES = set("wax+")

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        if not module.in_serving:
            return []
        findings = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            dn = dotted_name(node.func, module) or ""
            if dn in ("open", "io.open", "os.fdopen"):
                mode = node.args[1] if len(node.args) > 1 else None
                for kw in node.keywords:
                    if kw.arg == "mode":
                        mode = kw.value
                if isinstance(mode, ast.Constant) \
                        and isinstance(mode.value, str) \
                        and set(mode.value) & self.WRITE_MODES:
                    findings.append(self.finding(
                        module, node,
                        f"{dn}(..., {mode.value!r}) in serving/ — a crash "
                        f"mid-write leaves a truncated file; use "
                        f"serving/export.atomic_write_text"))
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("write_text", "write_bytes"):
                findings.append(self.finding(
                    module, node,
                    f".{node.func.attr}() in serving/ is not atomic; use "
                    f"serving/export.atomic_write_text"))
        return findings


# ---------------------------------------------------------------------------
# clock-injection
# ---------------------------------------------------------------------------

class ClockInjection(Rule):
    """No ambient clocks in serving/ — the injectable engine clock only.

    PR 5's TTFT-fabrication bug came from exactly this: synthetic submit
    timestamps mixed with real ``perf_counter`` first-token stamps
    produced negative TTFTs.  Every serving timestamp flows from the ONE
    ``clock`` callable the engine was constructed with (tests inject a
    synthetic clock and get coherent latencies end to end).  The two
    sanctioned exceptions — the engine's default clock parameter and the
    metrics' standalone fallback — carry inline suppressions."""
    name = "clock-injection"
    description = ("no time.time/perf_counter/monotonic in serving/ — use "
                   "the injectable engine clock")

    BANNED = {"time.time", "time.perf_counter", "time.monotonic",
              "time.process_time", "time.clock", "time.time_ns",
              "time.perf_counter_ns", "time.monotonic_ns"}
    BANNED_SUFFIX = ("datetime.now", "datetime.utcnow", "datetime.today")

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        if not module.in_serving:
            return []
        findings = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Attribute):
                continue
            dn = dotted_name(node, module) or ""
            if dn in self.BANNED or dn.endswith(self.BANNED_SUFFIX):
                findings.append(self.finding(
                    module, node,
                    f"`{dn}` in serving/ — all timestamps must come from "
                    f"the injectable engine clock (mixed clocks fabricate "
                    f"TTFT/TPOT; see docs/INVARIANTS.md)"))
        return findings


class NoBareAssert(Rule):
    """Runtime invariant checks in serving/ must be explicit raises.

    ``assert`` disappears under ``python -O`` — a production deployment
    running optimized bytecode silently loses the check, and the failure
    it guarded (a leaked block, an out-of-sync admission) resurfaces
    later as corruption with no pointer back to the violated invariant.
    Two real instances motivated this: ``BlockAllocator``'s minimum-pool
    assert and the engine's reserve-after-can_fit assert, both now
    ``raise`` with diagnostic messages.  Schedcheck compounds the
    stakes: its safety battery drives the *real* implementation objects,
    so an invariant demoted to ``assert`` would also vanish from the
    model checker's view under -O.

    Scope is runtime serving/ code only — tests and analysis tooling
    keep ``assert`` (pytest rewrites it; checkers run unoptimized)."""
    name = "no-bare-assert"
    description = ("serving/ runtime invariants must `raise`, not "
                   "`assert` (asserts vanish under python -O)")

    def check(self, module: ModuleInfo, ctx: LintContext) -> list[Finding]:
        if not module.in_serving:
            return []
        return [self.finding(
                    module, node,
                    "bare `assert` in serving/ runtime code — raise an "
                    "explicit exception instead (asserts are stripped "
                    "under python -O, silently disabling the invariant)")
                for node in ast.walk(module.tree)
                if isinstance(node, ast.Assert)]


def all_rules() -> list[Rule]:
    return [JitHostSync(), JitRecompileHazard(), PrngDiscipline(),
            RefcountPairing(), AtomicWrite(), ClockInjection(),
            NoBareAssert()]
