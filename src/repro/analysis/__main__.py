"""Unified analysis front-end: ``python -m repro.analysis``.

Runs every static-analysis layer — reprolint (AST), tracecheck (jitted
IR) and schedcheck (control-plane state space) — under one CLI with the
shared conventions the individual tools already follow:

* ``--select`` takes a comma-separated list of check ids; each id is
  routed to whichever tool owns it (lint rule / tracecheck analyzer /
  schedcheck property), and an id no tool recognizes is a usage error;
* ``--format text|json|github`` — text and github stream per-tool, json
  is one combined array over the whole run (each entry tagged with its
  originating tool) so stdout stays a single valid JSON document;
* exit 0 clean, 1 on any finding, 2 on usage error.

Tool selection: positional names restrict the run (``python -m
repro.analysis lint schedcheck``).  With no names, every tool runs —
except that a tool whose imports are unavailable in this environment
(tracecheck needs jax; the lint CI job is stdlib-only) is *skipped with
a note* rather than crashing, so the front-end stays usable everywhere.
Naming a tool explicitly makes its import errors fatal again.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.analysis.lint import emit_findings


def _lint_catalogue() -> dict:
    from repro.analysis.rules import all_rules
    return {r.name: r.description for r in all_rules()}


def _lint_run(select, args) -> list:
    from repro.analysis.lint import Linter
    return Linter(select=select or None).lint_paths(args.lint_paths)


def _tracecheck_catalogue() -> dict:
    from repro.analysis.tracecheck import ANALYZERS
    return {name: desc for name, (_, desc) in ANALYZERS.items()}


def _tracecheck_run(select, args) -> list:
    from repro.analysis.tracecheck import run_analyzers
    return run_analyzers(None, select or None)


def _schedcheck_catalogue() -> dict:
    from repro.analysis.schedcheck import PROPERTIES
    return dict(PROPERTIES)


def _schedcheck_run(select, args) -> list:
    from repro.analysis.schedcheck import (CONFIGS, findings_from,
                                           run_config)
    findings = []
    for cfg in CONFIGS.values():
        result = run_config(cfg)
        print(f"schedcheck: {cfg.name}: {result.states} states / "
              f"{'fixpoint' if result.fixpoint else 'TRUNCATED'} / "
              f"{len(result.violations)} violation(s)", file=sys.stderr)
        findings.extend(findings_from(cfg, result, select or None))
    return findings


# name -> (runner, catalogue, one-line description)
TOOLS = {
    "lint": (_lint_run, _lint_catalogue,
             "reprolint — AST rules over the source tree (stdlib-only)"),
    "tracecheck": (_tracecheck_run, _tracecheck_catalogue,
                   "IR-level analysis of the jitted serving steps "
                   "(imports jax)"),
    "schedcheck": (_schedcheck_run, _schedcheck_catalogue,
                   "exhaustive state-space check of the serving "
                   "control plane"),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="run every repro static-analysis layer under one "
                    "CLI (see docs/INVARIANTS.md)")
    ap.add_argument("tools", nargs="*",
                    help=f"tools to run (default: all available): "
                         f"{', '.join(TOOLS)}")
    ap.add_argument("--select", default=None,
                    help="comma-separated check ids, routed to whichever "
                         "tool owns each id")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text")
    ap.add_argument("--list-tools", action="store_true")
    ap.add_argument("--list-checks", action="store_true",
                    help="print every tool's check catalogue and exit")
    ap.add_argument("--lint-paths", nargs="*",
                    default=["src/repro", "benchmarks", "examples"],
                    help="paths for the lint tool (default: src/repro "
                         "benchmarks examples)")
    args = ap.parse_args(argv)

    if args.list_tools:
        for name, (_, _, desc) in TOOLS.items():
            print(f"{name:12s} {desc}")
        return 0

    explicit = bool(args.tools)
    names = args.tools or list(TOOLS)
    bad = [n for n in names if n not in TOOLS]
    if bad:
        print(f"analysis: unknown tool(s) {bad} (have: {list(TOOLS)})",
              file=sys.stderr)
        return 2

    # load each tool's catalogue up front: routes --select and discovers
    # which tools are importable here at all
    catalogues: dict = {}
    skipped: dict = {}
    for name in names:
        try:
            catalogues[name] = TOOLS[name][1]()
        except ImportError as e:
            if explicit:
                print(f"analysis: tool {name!r} unavailable: {e}",
                      file=sys.stderr)
                return 2
            skipped[name] = str(e)

    if args.list_checks:
        for name, cat in catalogues.items():
            for check, desc in cat.items():
                print(f"{name}:{check:22s} {desc}")
        return 0

    per_tool_select: dict = {name: None for name in catalogues}
    if args.select:
        wanted = {s.strip() for s in args.select.split(",") if s.strip()}
        routed: set = set()
        for name, cat in catalogues.items():
            mine = wanted & set(cat)
            per_tool_select[name] = mine
            routed |= mine
        unknown = wanted - routed
        if unknown:
            print(f"analysis: no tool owns check(s) {sorted(unknown)}; "
                  f"see --list-checks", file=sys.stderr)
            return 2

    for name, reason in skipped.items():
        print(f"analysis: skipping {name} (unavailable: {reason})",
              file=sys.stderr)

    combined = []          # (tool, Finding) pairs for the json format
    total = 0
    for name in catalogues:
        select = per_tool_select[name]
        if args.select and not select:
            continue       # --select named nothing this tool owns
        findings = TOOLS[name][0](select, args)
        total += len(findings)
        if args.format == "json":
            combined.extend((name, f) for f in findings)
        else:
            emit_findings(findings, args.format, tool=name)
        print(f"{name}: {len(findings)} finding(s)" if findings
              else f"{name}: clean", file=sys.stderr)

    if args.format == "json":
        json.dump([{"tool": t, **dataclasses.asdict(f)}
                   for t, f in combined], sys.stdout, indent=1)
        sys.stdout.write("\n")
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main())
