"""Step factories: train_step (grad-accum microbatching, remat, clipping,
optimizer), prefill_step, decode_step.  These are what the launcher jits with
the ASA plan's in/out shardings and what the dry-run lowers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import optimizers as O


# --- buffer-donation conventions -------------------------------------------
# Single source of truth for which positional arguments of each step kind
# are donated when jitted.  Every call site (serving/engine.py,
# runtime/trainer.py, launch/dryrun.py) and the tracecheck donation
# analyzer (analysis/tracecheck.py) read THIS table — a jit that donates
# anything else is either leaking HBM (undonated cache doubles the pool)
# or donating a buffer some caller still holds.
#
#   train:         (params, opt_state) are consumed and returned updated
#   prefill/decode + paged/slot variants: the cache is the mutable carry;
#                  params are read-only weights and must NOT be donated
STEP_DONATION: dict[str, tuple[int, ...]] = {
    "train": (0, 1),
    "prefill": (1,),
    "decode": (1,),
    "paged_prefill": (1,),
    "paged_decode": (1,),
    "slot_admit": (1,),
}


def jit_step(kind: str, fn, **jit_kwargs):
    """``jax.jit`` a step function with the donation convention for its
    kind.  ``jit_kwargs`` pass through (out_shardings, static_argnums, ...);
    a caller-supplied ``donate_argnums`` is rejected — the table is the
    convention, not a default."""
    if "donate_argnums" in jit_kwargs:
        raise ValueError("jit_step owns donate_argnums; "
                         f"use STEP_DONATION[{kind!r}]")
    return jax.jit(fn, donate_argnums=STEP_DONATION[kind], **jit_kwargs)


def make_loss_fn(arch: ArchConfig, *, impl="xla", remat="none",
                 act_sharding=None, mtp_weight: float = 0.3):
    def loss_fn(params, tokens, labels, frontend=None):
        out = T.lm_apply(params, arch, tokens, frontend=frontend, impl=impl,
                         remat=remat, act_sharding=act_sharding,
                         return_hidden=arch.mtp)
        loss = T.lm_loss(out.logits, labels, arch.vocab)
        if arch.mtp:
            # depth-1 MTP: hidden_t + emb(token_{t+1}) predicts token_{t+2}
            # = labels shifted left by one (mask the wrapped tail position)
            mtp_lg = T.mtp_logits(params, arch, out.hidden, tokens)
            tgt = jnp.roll(labels, -1, axis=1)
            mask = jnp.ones_like(tgt, jnp.float32).at[:, -1].set(0.0)
            loss = loss + mtp_weight * T.lm_loss(mtp_lg, tgt, arch.vocab, mask)
        return loss + out.aux, loss
    return loss_fn


def make_train_step(arch: ArchConfig, optimizer, *, microbatches: int = 1,
                    impl: str = "xla", remat: str = "none",
                    act_sharding=None, grad_shardings=None,
                    clip_norm: float = 1.0, mtp_weight: float = 0.3):
    """-> train_step(params, opt_state, batch) -> (params, opt_state, metrics)

    batch = {"tokens": (B,S) i32, "labels": (B,S) i32[, "frontend": (B,T,D)]}.
    Gradients are accumulated over `microbatches` slices of the batch via
    lax.scan (only one microbatch's activations live at a time).
    grad_shardings (pytree of NamedSharding, like params) pins per-microbatch
    gradients and the accumulator to the parameter layout — without it GSPMD
    replicates the scan carry and all-reduces full fp32 gradients every
    microbatch (observed: +66 GB/device on qwen3-8b, EXPERIMENTS.md §Perf).
    """
    _, opt_update = optimizer
    loss_fn = make_loss_fn(arch, impl=impl, remat=remat,
                           act_sharding=act_sharding, mtp_weight=mtp_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def one_microbatch(params, mb):
        (total, ce), grads = grad_fn(params, mb["tokens"], mb["labels"],
                                     mb.get("frontend"))
        return _pin(grads), total, ce

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            grads, total, ce = one_microbatch(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = {k: split(v) for k, v in batch.items()}

            def body(acc, mb):
                g_acc, t_acc, c_acc = acc
                g, t, c = one_microbatch(params, mb)
                g_acc = _pin(jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    g_acc, g))
                return (g_acc, t_acc + t / microbatches,
                        c_acc + c / microbatches), 0.0

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, total, ce), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), mbs)

        grads, gnorm = O.clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = O.apply_updates(params, updates)
        metrics = {"loss": total, "ce": ce, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(arch: ArchConfig, *, impl: str = "xla",
                      act_sharding=None):
    """-> prefill(params, cache, tokens[, frontend]) -> (last_logits, cache)."""
    def prefill_step(params, cache, tokens, frontend=None):
        out = T.lm_apply(params, arch, tokens, cache=cache,
                         frontend=frontend, impl=impl,
                         act_sharding=act_sharding)
        return out.logits[:, -1], out.cache
    return prefill_step


def make_decode_step(arch: ArchConfig, *, impl: str = "xla",
                     act_sharding=None):
    """-> decode(params, cache, tokens (B,1)) -> (logits (B,V), cache)."""
    def decode_step(params, cache, tokens):
        out = T.lm_apply(params, arch, tokens, cache=cache, impl=impl,
                         act_sharding=act_sharding)
        return out.logits[:, -1], out.cache
    return decode_step


# --- paged steps (continuous-batching engine, repro/serving/) --------------
# All take the shared serving cache (attn block pools + slot-state pools,
# see transformer.init_paged_cache) plus per-sequence position vectors (B,),
# block tables (B, max_blocks) and slot ids (B,); see layers.paged_attention
# and mamba2.mamba2_slot.
#
# With ``sampler`` (serving.sampling.make_sampler) the steps fuse sampling
# on device: they take per-row (temperature, top_k, top_p, seed) arrays and
# return (token (B,), logprob (B,), cache) instead of logits — only a (B,)
# token vector crosses back to the host, and the sampling key is derived
# inside the jit from the absolute position of the produced token.

def make_paged_prefill_step(arch: ArchConfig, *, impl: str = "xla",
                            act_sharding=None, sampler=None):
    """-> prefill(params, cache, tokens (B,C), positions, block_tables,
    new_lens, slot_ids) -> (last_valid_logits (B,V), cache).  Called once
    per prompt *chunk* — the engine interleaves these with decode steps
    instead of stalling a wave.  ``new_lens`` (B,) is the real token count
    per row; the chunk is padded to a fixed C so the step traces once, and
    the returned logits are taken at row new_lens-1 (the last real token).
    ``slot_ids`` (B,) maps rows to slot-state pool rows (SSM state carried
    as h0 across chunks; cross K/V read-only).

    With ``sampler`` the signature gains (temperature, top_k, top_p, seeds)
    row arrays and returns (token (B,), logprob (B,), cache): the token
    after the chunk is sampled on device at absolute position
    ``positions + new_lens`` (only meaningful — and only consumed — on the
    final chunk of a prompt)."""
    def _last_logits(params, cache, tokens, positions, block_tables,
                     new_lens, slot_ids):
        out = T.lm_apply(params, arch, tokens, cache=cache,
                         positions=positions, block_tables=block_tables,
                         new_lens=new_lens, slot_ids=slot_ids, impl=impl,
                         act_sharding=act_sharding)
        last = jnp.take_along_axis(
            out.logits, (new_lens - 1)[:, None, None], axis=1)
        return last[:, 0], out.cache

    if sampler is None:
        return _last_logits

    def paged_prefill_step(params, cache, tokens, positions, block_tables,
                           new_lens, slot_ids, temperature, top_k, top_p,
                           seeds):
        last, cache = _last_logits(params, cache, tokens, positions,
                                   block_tables, new_lens, slot_ids)
        tok, logp = sampler(last, temperature, top_k, top_p, seeds,
                            positions + new_lens)
        return tok, logp, cache
    return paged_prefill_step


def make_paged_decode_step(arch: ArchConfig, *, impl: str = "xla",
                           act_sharding=None, sampler=None):
    """-> decode(params, cache, tokens (B,1), positions, block_tables,
    slot_ids) -> (logits (B,V), cache).  Every batch row advances at its
    *own* position — slots holding idle/prefilling requests point their
    block tables at the null block, their slot_ids at the null slot row,
    and are masked by the caller.

    With ``sampler`` the signature gains (temperature, top_k, top_p, seeds)
    row arrays and returns (token (B,), logprob (B,), cache): the next
    token is sampled on device at absolute position ``positions + 1`` (the
    input token lives at ``positions``)."""
    def _logits(params, cache, tokens, positions, block_tables, slot_ids):
        out = T.lm_apply(params, arch, tokens, cache=cache,
                         positions=positions, block_tables=block_tables,
                         slot_ids=slot_ids, impl=impl,
                         act_sharding=act_sharding)
        return out.logits[:, -1], out.cache

    if sampler is None:
        return _logits

    def paged_decode_step(params, cache, tokens, positions, block_tables,
                          slot_ids, temperature, top_k, top_p, seeds):
        logits, cache = _logits(params, cache, tokens, positions,
                                block_tables, slot_ids)
        tok, logp = sampler(logits, temperature, top_k, top_p, seeds,
                            positions + 1)
        return tok, logp, cache
    return paged_decode_step


def make_slot_admit_step(arch: ArchConfig):
    """-> admit(params, cache, slot_id[, frontend]) -> cache.  Resets one
    engine slot's rows in every slot-state pool on admission: mamba2 state
    zeroed; cross-attn K/V zeroed or computed once from the request's
    ``frontend`` patch embeddings (1, T, d_model); wdec encoder K/V zeroed
    or computed by running the encoder ONCE over the request's frame
    embeddings (whisper admission — see transformer.admit_slot).  No-op for
    paged block pools."""
    def slot_admit_step(params, cache, slot_id, frontend=None):
        return T.admit_slot(params, arch, cache, slot_id, frontend=frontend)
    return slot_admit_step
