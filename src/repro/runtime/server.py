"""Serving runtime: batched prefill + decode in synchronized waves.

A wave = up to `slots` requests, prompts right-aligned/padded to a common
length, one batched prefill, then lock-step decode until every request in
the wave finished (early finishers are masked).  Wave scheduling keeps the
shared per-layer cache position scalar correct.

True continuous batching (per-slot positions, paged KV cache + slot-state
pools, chunked prefill, admission scheduling) lives in ``repro/serving/`` —
ContinuousBatchingEngine is greedy-parity-tested against this Server and is
the production path for attention-only, hybrid attn+SSM and cross-attention
architectures (SSM state and cross K/V ride the slot-indexed pools, see
serving/cache_manager.py).  This wave Server remains as the comparison
baseline (benchmarks/serve_bench.py) and as the serving path for the
still-excluded archs: zamba2's weight-shared block and whisper's
encoder-decoder.

The ASA plan supplies param/cache shardings (decode picks MP — KV cache
time-sharded over `model`; see core/sharding.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.asa import AdaptiveScheduler
from repro.launch.mesh import mesh_shape_of
from repro.models import transformer as T
from repro.runtime import steps as ST


@dataclasses.dataclass
class Request:
    id: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, arch: ArchConfig, params, mesh, *,
                 slots: int = 4, max_len: int = 512,
                 scheduler: Optional[AdaptiveScheduler] = None):
        self.arch, self.params, self.mesh = arch, params, mesh
        self.slots, self.max_len = slots, max_len
        ms = mesh_shape_of(mesh)
        shape = ShapeSpec("serve", max_len, slots, "decode")
        sched = scheduler or AdaptiveScheduler(faithful=False)
        self.plan = sched.plan(arch, shape, ms)
        self._cache_ns = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      self.plan.cache_specs(slots))
        self._cdtype = jnp.float32 if arch.dtype == "float32" else jnp.bfloat16
        self._prefill = jax.jit(ST.make_prefill_step(arch))
        self._decode = jax.jit(ST.make_decode_step(arch), donate_argnums=(1,))
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.decode_steps = 0
        self.waves = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _sample(self, logits) -> np.ndarray:
        logits = np.asarray(logits, np.float32)[:, : self.arch.vocab]
        return np.argmax(logits, axis=-1).astype(np.int32)

    def _run_wave(self, wave: list[Request]):
        B = self.slots
        lens = {len(r.prompt) for r in wave}
        assert len(lens) == 1, \
            "wave scheduling batches equal-length prompts (pad client-side)"
        S = lens.pop()
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(wave):
            toks[i] = r.prompt
        cache = jax.device_put(
            T.init_cache(self.arch, B, self.max_len, self._cdtype),
            self._cache_ns)
        logits, cache = self._prefill(self.params, cache, jnp.asarray(toks))
        nxt = self._sample(logits)
        for i, r in enumerate(wave):
            r.out_tokens.append(int(nxt[i]))
        active = {i: r for i, r in enumerate(wave)
                  if len(r.out_tokens) < r.max_new_tokens}
        # bound on the *active* requests: a finished slot stops growing, so
        # wave[0]'s length alone would let longer requests decode past
        # max_len and clamp-overwrite the last cache position
        while active and S + max(len(r.out_tokens)
                                 for r in active.values()) < self.max_len:
            last = np.zeros((B, 1), np.int32)
            for i, r in enumerate(wave):
                last[i, 0] = r.out_tokens[-1]
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(last))
            nxt = self._sample(logits)
            self.decode_steps += 1
            for i in list(active):
                r = active[i]
                r.out_tokens.append(int(nxt[i]))
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    del active[i]
        for r in wave:
            r.done = True
            self.completed.append(r)
        self.waves += 1

    def run_until_drained(self) -> float:
        t0 = time.perf_counter()
        while self.queue:
            wave, self.queue = self.queue[:self.slots], self.queue[self.slots:]
            self._run_wave(wave)
        return time.perf_counter() - t0
