"""DEPRECATED compatibility shim: the wave-synchronized Server is gone.

This module used to implement wave-synchronized serving — up to ``slots``
equal-length prompts batched per wave, one full-cache prefill, lock-step
decode until the slowest request finished.  That path (and its per-wave
full-cache prefill) has been deleted: ``repro/serving/
ContinuousBatchingEngine`` now serves every architecture in the zoo —
attention-only, MoE, MLA latent attention, pure-SSM, hybrid, cross-attention
VLM, zamba2's weight-shared block and whisper's encoder-decoder — through
the unified paged-KV / slot-state cache (serving/cache_manager.py), with
greedy outputs pinned token-for-token against the retired wave
implementation (tests/goldens_serving.json) and a sharded multi-host decode
test (tests/test_serving.py::test_multihost_decode_parity_and_cache_placement).

``Server`` survives only as a thin shim preserving the old API —
``submit(Request)`` then ``run_until_drained()``, with the caller's Request
objects mutated in place — while delegating every token to the engine.  New
code should construct ``ContinuousBatchingEngine`` directly: it exposes the
v2 generation API (per-request ``SamplingParams``, typed ``RequestOutput``
with finish reasons and latency, ``generate()``/``stream()``/``on_token``),
the request scheduler (priorities, token budgets), per-request frontends,
streaming admission via ``step()``, and JSON serving metrics, none of which
fit the legacy interface.  The shim is greedy-only: the legacy Request has
no sampling field, and every token it serves decodes at temperature 0.  Restrictions the wave path never enforced now
apply here too: max_new_tokens >= 1, non-empty prompts shorter than
max_len (the wave loop admitted a prompt of exactly max_len and served a
single token; the engine needs the position for that token's KV), and
unique in-flight request ids.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.asa import AdaptiveScheduler
from repro.serving.engine import ContinuousBatchingEngine
from repro.serving.engine import Request as EngineRequest


@dataclasses.dataclass
class Request:
    """Legacy request shape (no priority / frontend / scheduler fields)."""
    id: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Thin delegate to ContinuousBatchingEngine keeping the wave-era API.

    Extra keyword arguments (block_size, num_blocks, prefill_chunk, ...)
    pass straight through to the engine.
    """

    def __init__(self, arch: ArchConfig, params, mesh, *,
                 slots: int = 4, max_len: int = 512,
                 scheduler: Optional[AdaptiveScheduler] = None,
                 **engine_kwargs):
        warnings.warn(
            "runtime.server.Server is a deprecated compatibility shim over "
            "repro.serving.ContinuousBatchingEngine — the wave decode path "
            "has been removed; construct the engine directly",
            DeprecationWarning, stacklevel=2)
        self.arch, self.mesh = arch, mesh
        self.slots, self.max_len = slots, max_len
        self.engine = ContinuousBatchingEngine(
            arch, params, mesh, slots=slots, max_len=max_len, asa=scheduler,
            **engine_kwargs)
        self.completed: list[Request] = []
        self._submitted: dict[int, Request] = {}

    @property
    def params(self):
        return self.engine.params

    @property
    def plan(self):
        return self.engine.plan

    @property
    def decode_steps(self) -> int:
        return self.engine.metrics.decode_steps

    @property
    def waves(self) -> int:
        """Always 0 — wave scheduling no longer exists."""
        return 0

    def submit(self, req: Request) -> None:
        self.engine.submit(EngineRequest(
            id=req.id, prompt=np.asarray(req.prompt, np.int32),
            max_new_tokens=req.max_new_tokens))
        self._submitted[req.id] = req

    def run_until_drained(self) -> float:
        wall = self.engine.run_until_drained()
        # mirror engine RequestOutputs back onto the caller's legacy
        # objects — the v2 engine never mutates its own Request inputs,
        # but in-place mutation IS the legacy contract this shim preserves
        for out in self.engine.completed:
            legacy = self._submitted.pop(out.request_id, None)
            if legacy is not None:
                legacy.out_tokens = list(out.token_ids)
                legacy.done = True
                self.completed.append(legacy)
        self.engine.completed.clear()
        return wall
