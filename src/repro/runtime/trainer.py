"""Trainer — the paper's Algorithm 1 as a production loop.

Integrates: ASA planning + periodic re-planning (re-profile -> re-solve ->
reshard -> re-jit), grad-accum microbatching, checkpoint/restart (exact
resume: step, rng, data offset), elastic mesh resize, straggler-aware input
dispatch (data.HostShardedLoader), and live step-time monitoring.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import sharding as SH
from repro.core.asa import AdaptiveScheduler, SchedulePlan
from repro.launch.mesh import mesh_shape_of
from repro.models import transformer as T
from repro.optim import optimizers as O
from repro.optim.schedules import cosine_schedule
from repro.runtime import steps as ST


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    clip_norm: float = 1.0
    microbatches: int = 0            # 0 = take from the ASA plan
    remat: str = "none"
    impl: str = "xla"
    checkpoint_every: int = 200
    replan_every: int = 0            # 0 = only on monitor trigger
    quantized_opt: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, arch: ArchConfig, shape: ShapeSpec, mesh,
                 cfg: TrainConfig = TrainConfig(), *,
                 scheduler: Optional[AdaptiveScheduler] = None,
                 checkpoint_dir: Optional[str] = None):
        self.arch, self.shape, self.mesh, self.cfg = arch, shape, mesh, cfg
        self.sched = scheduler or AdaptiveScheduler(faithful=False)
        self.ckpt = (CheckpointManager(checkpoint_dir)
                     if checkpoint_dir else None)
        self.opt = O.adamw(
            cosine_schedule(cfg.lr, cfg.warmup_steps, cfg.total_steps),
            quantized=cfg.quantized_opt)
        self.step = 0
        self.data_offset = 0
        self.plan: Optional[SchedulePlan] = None
        self._jitted = None
        self._replan(init=True)

    # ------------------------------------------------------------------
    def _specs(self):
        ms = mesh_shape_of(self.mesh)
        pspecs = self.plan.param_specs()
        pns = jax.tree.map(lambda s: NamedSharding(self.mesh, s), pspecs)
        act_ns = NamedSharding(
            self.mesh, P(SH.batch_axes(ms, self.shape.global_batch), None, None))
        return pspecs, pns, act_ns

    def _replan(self, init: bool = False):
        ms = mesh_shape_of(self.mesh)
        new_plan = self.sched.plan(self.arch, self.shape, ms)
        changed = (self.plan is None
                   or new_plan.assignment != self.plan.assignment)
        self.plan = new_plan
        if not (changed or init):
            return False
        pspecs, pns, act_ns = self._specs()
        mb = self.cfg.microbatches or self.plan.microbatches
        step_fn = ST.make_train_step(
            self.arch, self.opt, microbatches=mb, impl=self.cfg.impl,
            remat=self.cfg.remat, act_sharding=act_ns,
            clip_norm=self.cfg.clip_norm)
        def opt_specs_fn(osds):
            return SH.opt_state_specs(osds, pspecs, ms)
        self._jitted = None          # rebuilt lazily with opt specs
        self._step_fn, self._pns, self._opt_specs_fn = step_fn, pns, opt_specs_fn
        return changed

    def _jit(self, params, opt_state):
        opt_sds = jax.eval_shape(lambda o: o, opt_state)
        ons = jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                           self._opt_specs_fn(opt_sds))
        self._jitted = ST.jit_step("train", self._step_fn,
                                   out_shardings=(self._pns, ons, None))

    # ------------------------------------------------------------------
    def init_state(self, rng: Optional[jax.Array] = None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.cfg.seed)
        _, pns, _ = self._specs()
        params = jax.jit(
            lambda k: T.init_lm(k, self.arch), out_shardings=pns)(rng)
        opt_init, _ = self.opt
        opt_state = jax.jit(opt_init)(params)
        return params, opt_state

    def maybe_restore(self, params, opt_state):
        """Restart-from-checkpoint (reshards to the current mesh)."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return params, opt_state
        _, pns, _ = self._specs()
        state = {"params": params, "opt": opt_state}
        sh = {"params": pns,
              "opt": jax.tree.map(lambda _: NamedSharding(self.mesh, P()),
                                  opt_state)}
        restored, manifest = self.ckpt.restore(state, shardings=sh)
        self.step = manifest["step"]
        self.data_offset = manifest.get("data_offset", self.step)
        return restored["params"], restored["opt"]

    # ------------------------------------------------------------------
    def train(self, params, opt_state, data_iter, *, steps: int,
              log_every: int = 10, on_metrics: Optional[Callable] = None):
        if self._jitted is None:
            self._jit(params, opt_state)
        metrics_hist = []
        for _ in range(steps):
            batch = next(data_iter)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = self._jitted(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1
            self.data_offset += 1

            if self.sched.record_step(dt) or (
                    self.cfg.replan_every
                    and self.step % self.cfg.replan_every == 0):
                if self._replan():     # strategy switch: reshard + re-jit
                    params = jax.device_put(params, self._pns)
                    self._jit(params, opt_state)

            if self.ckpt and self.step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(self.step, {"params": params, "opt": opt_state},
                               extra={"data_offset": self.data_offset})
            m = {k: float(v) for k, v in metrics.items()}
            m["step_time_s"] = dt
            metrics_hist.append(m)
            if on_metrics and self.step % log_every == 0:
                on_metrics(self.step, m)
        return params, opt_state, metrics_hist

    # ------------------------------------------------------------------
    def resize(self, new_mesh, params, opt_state):
        """Elastic rescale: re-plan on the new mesh and reshard live state."""
        self.mesh = new_mesh
        self._replan(init=True)
        _, pns, _ = self._specs()
        params = jax.device_put(params, pns)
        # optimizer state: reshard step scalar + moments like params
        opt_sds = jax.eval_shape(lambda o: o, opt_state)
        ons = jax.tree.map(lambda s: NamedSharding(new_mesh, s),
                           self._opt_specs_fn(opt_sds))
        opt_state = jax.device_put(opt_state, ons)
        self._jit(params, opt_state)
        return params, opt_state
