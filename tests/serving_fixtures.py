"""Tiny serving architectures + golden-parity scenarios, shared between
tests/test_serving.py and tests/gen_serving_goldens.py.

One tiny config per serving cache class the continuous engine supports:

  TINY         attention-only (paged KV block pools)
  TINY_SSM     pure mamba2 (slot-state pools only)
  TINY_HYBRID  attn + mamba2 (both state classes)
  TINY_CROSS   attn + gated cross-attn (llama-vision shape)
  TINY_SHARED  zamba2 shape: weight-shared 2*d attention block + mamba2
               (per-application paged KV pools for the shared block)
  TINY_ENCDEC  whisper shape: enc-dec wdec blocks (paged self-attn KV +
               slot-state cross K/V, encoder run once at admission)
  TINY_MLA     deepseek shape: latent-attention blocks with MoE FFNs
               (paged c_kv/k_rope latent pools)

All configs are float32 so greedy argmax parity is exact on CPU.
TINY_MLA's capacity_factor is set high enough that MoE token dropping can
never trigger: capacity is computed per (row, chunk) so a binding capacity
would make outputs depend on how a prompt is chunked — real deployments
accept that; the parity suite must not.

Each SCENARIOS entry pins the request set (prompts, per-request max_new,
slots, max_len) whose greedy outputs are frozen in goldens_serving.json —
captured from the pre-shim wave Server (see gen_serving_goldens.py).
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.configs.base import (ArchConfig, EncoderSpec, MLASpec, MoESpec,
                                Segment, SSMSpec)

GOLDENS_PATH = pathlib.Path(__file__).resolve().parent / "goldens_serving.json"

TINY = ArchConfig(name="tiny-serve", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  pattern=(Segment(("attn",), 2),), dtype="float32",
                  param_dtype="float32")

TINY_SSM = ArchConfig(name="tiny-ssm", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                      ssm=SSMSpec(d_state=16, head_dim=16, chunk=16),
                      pattern=(Segment(("mamba2",), 2),), dtype="float32",
                      param_dtype="float32")

TINY_HYBRID = ArchConfig(name="tiny-hybrid", family="hybrid", n_layers=4,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab=256,
                         ssm=SSMSpec(d_state=16, head_dim=16, d_conv=4,
                                     chunk=4),
                         pattern=(Segment(("attn", "mamba2"), 2),),
                         dtype="float32", param_dtype="float32")

TINY_CROSS = ArchConfig(name="tiny-cross", family="vlm", n_layers=4,
                        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab=256, frontend="vision", n_img_tokens=8,
                        pattern=(Segment(("attn", "cross_attn"), 2),),
                        dtype="float32", param_dtype="float32")

TINY_SHARED = ArchConfig(name="tiny-shared", family="hybrid", n_layers=4,
                         d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                         vocab=256, act="geglu", tie_embeddings=True,
                         ssm=SSMSpec(d_state=16, head_dim=16, d_conv=4,
                                     chunk=4),
                         pattern=(Segment(("shared_attn", "mamba2"), 2),),
                         dtype="float32", param_dtype="float32")

TINY_ENCDEC = ArchConfig(name="tiny-encdec", family="audio", n_layers=2,
                         d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                         vocab=256, act="gelu", norm="layernorm",
                         attn_bias=True, tie_embeddings=True,
                         pattern=(Segment(("wdec",), 2),),
                         encoder=EncoderSpec(n_layers=2, seq_len=8, d_ff=128),
                         frontend="audio", dtype="float32",
                         param_dtype="float32")

TINY_MLA = ArchConfig(name="tiny-mla", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                      mla=MLASpec(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16),
                      moe=MoESpec(n_experts=2, top_k=1, d_ff=32,
                                  capacity_factor=16.0),
                      pattern=(Segment(("mla_dense",), 1),
                               Segment(("mla",), 1)),
                      dtype="float32", param_dtype="float32")

ARCH_BY_KEY = {"tiny": TINY, "ssm": TINY_SSM, "hybrid": TINY_HYBRID,
               "cross": TINY_CROSS, "shared": TINY_SHARED,
               "encdec": TINY_ENCDEC, "mla": TINY_MLA}


def scenario_prompts(plen: int, n: int) -> list[np.ndarray]:
    return [np.arange(1, plen + 1, dtype=np.int32) + i for i in range(n)]


# name -> request set + serving geometry.  max_new is a scalar (all requests)
# or a per-request list.  The wave Server that froze the goldens batched
# `slots` equal-length prompts per wave with decode bound `max_len`.
SCENARIOS: dict[str, dict] = {
    "tiny/base":      dict(arch="tiny", plen=8, n=5, max_new=6,
                           slots=2, max_len=64),
    "tiny/preempt":   dict(arch="tiny", plen=8, n=4, max_new=8,
                           slots=2, max_len=64),
    "tiny/victims":   dict(arch="tiny", plen=16, n=6, max_new=8,
                           slots=4, max_len=64),
    "tiny/mixed":     dict(arch="tiny", plen=8, n=2, max_new=[2, 20],
                           slots=2, max_len=12),
    "ssm/base":       dict(arch="ssm", plen=8, n=3, max_new=6,
                           slots=2, max_len=64),
    "hybrid/base":    dict(arch="hybrid", plen=8, n=4, max_new=6,
                           slots=2, max_len=64),
    "hybrid/preempt": dict(arch="hybrid", plen=8, n=4, max_new=8,
                           slots=2, max_len=64),
    "cross/base":     dict(arch="cross", plen=8, n=4, max_new=6,
                           slots=2, max_len=64),
    "shared/base":    dict(arch="shared", plen=8, n=4, max_new=6,
                           slots=2, max_len=64),
    "shared/preempt": dict(arch="shared", plen=8, n=4, max_new=8,
                           slots=2, max_len=64),
    "encdec/base":    dict(arch="encdec", plen=8, n=4, max_new=6,
                           slots=2, max_len=64),
    "encdec/preempt": dict(arch="encdec", plen=8, n=4, max_new=8,
                           slots=2, max_len=64),
    "mla/base":       dict(arch="mla", plen=8, n=4, max_new=6,
                           slots=2, max_len=64),
    "mla/preempt":    dict(arch="mla", plen=8, n=4, max_new=8,
                           slots=2, max_len=64),
}


def scenario_requests(name: str):
    """-> (arch, [(rid, prompt, max_new)], slots, max_len)."""
    sc = SCENARIOS[name]
    arch = ARCH_BY_KEY[sc["arch"]]
    prompts = scenario_prompts(sc["plen"], sc["n"])
    mn = sc["max_new"]
    max_news = mn if isinstance(mn, list) else [mn] * sc["n"]
    reqs = [(i, p, m) for i, (p, m) in enumerate(zip(prompts, max_news))]
    return arch, reqs, sc["slots"], sc["max_len"]


def load_goldens(name: str) -> dict[int, list[int]]:
    """Pinned greedy outputs for one scenario: {request id -> tokens}."""
    with open(GOLDENS_PATH) as f:
        data = json.load(f)
    return {int(k): v for k, v in data["scenarios"][name].items()}
