"""Paper claim (Fig 4 / ±0.5%): parallelism strategies do not change model
quality.  In GSPMD terms: sharded and unsharded training are the SAME math —
validated by running identical steps on a 1-device mesh vs an 8-virtual-
device mesh (DP and TP shardings) in a subprocess and comparing losses."""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ArchConfig, Segment
from repro.models import transformer as T
from repro.optim import optimizers as O
from repro.runtime import steps as ST
from repro.data import SyntheticLM

arch = ArchConfig(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=4, d_ff=128, vocab=256,
                  pattern=(Segment(("attn",), 2),), dtype="float32",
                  param_dtype="float32")

def run(mesh_shape, axes, tok_spec, w_col, w_row):
    mesh = jax.make_mesh(mesh_shape, axes)
    opt = O.adamw(1e-3)
    step = ST.make_train_step(arch, opt)
    params = T.init_lm(jax.random.PRNGKey(0), arch)
    ostate = opt[0](params)

    def spec_of(path, leaf):
        name = path[-2].key if hasattr(path[-2], "key") else ""
        if leaf.ndim >= 2 and name in ("wq", "wk", "wv", "w_in", "w_gate"):
            return NamedSharding(mesh, P(*([None]*(leaf.ndim-1) + [w_col])))
        if leaf.ndim >= 2 and name in ("wo", "w_out"):
            return NamedSharding(mesh, P(*([None]*(leaf.ndim-2) + [w_row, None])))
        return NamedSharding(mesh, P())
    pspecs = jax.tree_util.tree_map_with_path(spec_of, params)
    params = jax.device_put(params, pspecs)
    ostate = jax.device_put(ostate, jax.tree.map(
        lambda _: NamedSharding(mesh, P()), ostate))
    data = SyntheticLM(arch.vocab, 32, 8, seed=3)
    losses = []
    jstep = jax.jit(step)
    for _ in range(8):
        b = next(data)
        batch = {k: jax.device_put(jnp.asarray(v),
                                   NamedSharding(mesh, P(tok_spec, None)))
                 for k, v in b.items()}
        params, ostate, m = jstep(params, ostate, batch)
        losses.append(float(m["ce"]))
    return losses

single = run((1, 1), ("data", "model"), None, None, None)
dp = run((8, 1), ("data", "model"), "data", None, None)
tp = run((1, 8), ("data", "model"), None, "model", "model")
print(json.dumps({"single": single, "dp": dp, "tp": tp}))
"""


@pytest.mark.slow
def test_sharded_training_is_same_math():
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT, src],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    single, dp, tp = res["single"], res["dp"], res["tp"]
    np.testing.assert_allclose(single, dp, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(single, tp, rtol=2e-3, atol=2e-3)
