"""Optimizer substrate: AdamW/SGD correctness, int8 state quantization,
schedules, clipping, error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.optim import optimizers as O
from repro.optim.compression import (compress, decompress_and_update_error,
                                     init_error_state)
from repro.optim.quantized import QLeaf
from repro.optim.schedules import cosine_schedule, linear_warmup


def test_adamw_minimizes_quadratic():
    init, update = O.adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        upd, state = update(grads, state, params)
        params = O.apply_updates(params, upd)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_quantized_adamw_tracks_fp32():
    def run(quantized):
        init, update = O.adamw(0.05, weight_decay=0.0, quantized=quantized)
        params = {"w": jnp.linspace(-2, 2, 512)}
        state = init(params)
        for _ in range(50):
            grads = {"w": 2 * params["w"]}
            upd, state = update(grads, state, params)
            params = O.apply_updates(params, upd)
        return params["w"]
    w_fp, w_q = run(False), run(True)
    assert float(jnp.mean(jnp.abs(w_fp - w_q))) < 0.05


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(st.integers(1, 4000), st.booleans())
def test_qleaf_roundtrip_error_bounded(n, signed):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    if not signed:
        x = jnp.abs(x)     # unsigned stores the non-negative second moment
    q = QLeaf.from_dense(x, signed)
    err = jnp.max(jnp.abs(q.dense() - x))
    scale = jnp.max(jnp.abs(x)) + 1e-12
    assert float(err / scale) < (1 / 127 if signed else 2 / 255) + 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = O.clip_by_global_norm(g, 1.0)
    assert abs(float(O.global_norm(clipped)) - 1.0) < 1e-5
    assert abs(float(norm) - np.sqrt(90)) < 1e-4
    small = {"a": jnp.ones((4,)) * 0.01}
    same, _ = O.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, rtol=1e-6)


def test_schedules():
    cs = cosine_schedule(1e-3, 10, 100)
    assert float(cs(jnp.array(0))) == 0.0
    assert abs(float(cs(jnp.array(10))) - 1e-3) < 1e-9
    assert float(cs(jnp.array(100))) < float(cs(jnp.array(50)))
    lw = linear_warmup(1e-3, 10)
    assert abs(float(lw(jnp.array(5))) - 5e-4) < 1e-9


def test_error_feedback_compression_converges():
    """EF compression: accumulated compressed sum tracks the exact sum."""
    key = jax.random.PRNGKey(0)
    grads_seq = [{"w": jax.random.normal(jax.random.fold_in(key, i), (256,))}
                 for i in range(30)]
    err = init_error_state(grads_seq[0])
    exact = jnp.zeros((256,))
    approx = jnp.zeros((256,))
    for g in grads_seq:
        q, corrected = compress(g, err)
        deq, err = decompress_and_update_error(q, corrected)
        exact = exact + g["w"]
        approx = approx + deq["w"]
    # error feedback keeps the drift bounded by one quantization step
    drift = float(jnp.max(jnp.abs(exact - approx)))
    scale = float(jnp.max(jnp.abs(exact)))
    assert drift < 0.1 * scale + 0.1
