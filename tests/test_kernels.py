"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.mamba2 import Mamba2Config

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 256, 4, 2, 64),
    (1, 300, 2, 2, 128),     # non-multiple-of-block seq
    (2, 128, 8, 1, 32),      # MQA
    (1, 512, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, S, H, Hkv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v)
    kx = jnp.repeat(k, H // Hkv, axis=2)
    vx = jnp.repeat(v, H // Hkv, axis=2)
    expected = ref.flash_attention_ref(q, kx, vx, scale=1.0 / np.sqrt(D))
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,S,H,P,N,G,chunk", [
    (2, 64, 4, 16, 8, 1, 32),
    (1, 100, 2, 8, 16, 2, 32),    # ragged seq, multi-group
    (2, 33, 4, 32, 64, 1, 16),
])
def test_ssd_scan_matches_sequential_ref(B, S, H, P, N, G, chunk):
    cfg = Mamba2Config(d_model=H * P // 2, d_state=N, head_dim=P,
                       n_groups=G, chunk=chunk)
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, G, N))
    Cm = jax.random.normal(ks[2], (B, S, G, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[4], (B, S, H))) * dt
    y, hf = ops.ssd_scan(cfg, x, Bm, Cm, dt, a)
    hg = jnp.arange(H) // (H // G)
    yr, hr = ref.ssd_scan_ref(x, Bm[:, :, hg], Cm[:, :, hg], dt, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               atol=2e-3, rtol=2e-3)


def test_ssd_scan_carries_state():
    """Chunked scan over [0:S] == scan [0:k] then [k:S] with carried state."""
    cfg = Mamba2Config(d_model=32, d_state=8, head_dim=16, chunk=16)
    B, S, H, P, N = 1, 64, 4, 16, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, 1, N))
    Cm = jax.random.normal(ks[2], (B, S, 1, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[4], (B, S, H))) * dt
    y_full, h_full = ops.ssd_scan(cfg, x, Bm, Cm, dt, a)
    k = 32
    y1, h1 = ops.ssd_scan(cfg, x[:, :k], Bm[:, :k], Cm[:, :k],
                          dt[:, :k], a[:, :k])
    y2, h2 = ops.ssd_scan(cfg, x[:, k:], Bm[:, k:], Cm[:, k:],
                          dt[:, k:], a[:, k:], h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, k:]), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(h2),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("shape", [(4, 37, 128), (2, 256), (1, 7, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],)) + 1.0
    out = ops.rmsnorm(x, s)
    expected = ref.rmsnorm_ref(x, s)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)
