"""Data pipeline + checkpoint substrate tests."""
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data import HostShardedLoader, Prefetcher, SyntheticLM, SyntheticImages


def test_synthetic_lm_deterministic_and_restartable():
    a = SyntheticLM(1000, 16, 4, seed=7)
    b1, b2 = next(a), next(a)
    c = SyntheticLM(1000, 16, 4, seed=7).skip(1)
    np.testing.assert_array_equal(next(c)["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 1000
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_synthetic_images_learnable_structure():
    d = SyntheticImages(n_classes=4, batch=64, seed=0)
    b = next(d)
    assert b["images"].shape == (64, 32, 32, 3)
    assert set(np.unique(b["labels"])).issubset(set(range(4)))


def test_prefetcher_yields_everything():
    items = list(Prefetcher(iter(range(20)), depth=3))
    assert items == list(range(20))


def test_straggler_shard_reassignment():
    """When a host's heartbeat goes stale its shards move to live hosts."""
    loader = HostShardedLoader(
        lambda shard, n: SyntheticLM(100, 8, 2, seed=shard),
        n_hosts=4, host_id=0, heartbeat_timeout_s=0.05)
    assert loader.assigned == [0]
    # hosts 2,3 go silent
    now = time.monotonic()
    loader.heartbeat(0, now)
    loader.heartbeat(1, now)
    loader.heartbeat(2, now - 10)
    loader.heartbeat(3, now - 10)
    batches = next(loader)
    assert loader.assigned == [0, 2]       # host0 picked up shard 2
    assert len(batches) == 2


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4))}}
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, tree, extra={"data_offset": step * 2})
    assert mgr.steps() == [20, 30]         # keep-2 GC
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 30
    assert manifest["data_offset"] == 60
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_async_save(tmp_path):
    tree = {"w": jnp.ones((128,))}
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomic_no_partial(tmp_path):
    tree = {"w": jnp.ones((8,))}
    save_pytree(tmp_path / "x", tree)
    restored, _ = restore_pytree(tmp_path / "x", tree)
    assert not (tmp_path / "x.tmp").exists()
    np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)
