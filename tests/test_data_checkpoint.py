"""Data pipeline + checkpoint substrate tests."""
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data import HostShardedLoader, Prefetcher, SyntheticLM, SyntheticImages


def test_synthetic_lm_deterministic_and_restartable():
    a = SyntheticLM(1000, 16, 4, seed=7)
    b1, b2 = next(a), next(a)
    c = SyntheticLM(1000, 16, 4, seed=7).skip(1)
    np.testing.assert_array_equal(next(c)["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 1000
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_synthetic_images_learnable_structure():
    d = SyntheticImages(n_classes=4, batch=64, seed=0)
    b = next(d)
    assert b["images"].shape == (64, 32, 32, 3)
    assert set(np.unique(b["labels"])).issubset(set(range(4)))


def test_prefetcher_yields_everything():
    items = list(Prefetcher(iter(range(20)), depth=3))
    assert items == list(range(20))


def test_straggler_shard_reassignment():
    """When a host's heartbeat goes stale its shards move to live hosts."""
    loader = HostShardedLoader(
        lambda shard, n: SyntheticLM(100, 8, 2, seed=shard),
        n_hosts=4, host_id=0, heartbeat_timeout_s=0.05)
    assert loader.assigned == [0]
    # hosts 2,3 go silent
    now = time.monotonic()
    loader.heartbeat(0, now)
    loader.heartbeat(1, now)
    loader.heartbeat(2, now - 10)
    loader.heartbeat(3, now - 10)
    batches = next(loader)
    assert loader.assigned == [0, 2]       # host0 picked up shard 2
    assert len(batches) == 2


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "nested": {"b": jnp.ones((3, 4))}}
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for step in (10, 20, 30):
        mgr.save(step, tree, extra={"data_offset": step * 2})
    assert mgr.steps() == [20, 30]         # keep-2 GC
    restored, manifest = mgr.restore(tree)
    assert manifest["step"] == 30
    assert manifest["data_offset"] == 60
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_async_save(tmp_path):
    tree = {"w": jnp.ones((128,))}
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_atomic_no_partial(tmp_path):
    tree = {"w": jnp.ones((8,))}
    save_pytree(tmp_path / "x", tree)
    restored, _ = restore_pytree(tmp_path / "x", tree)
    assert not (tmp_path / "x.tmp").exists()
    np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)


# ---------------------------------------------------------------------------
# control-plane snapshots through the store (ROADMAP item 4 groundwork):
# scheduler + paged-cache host state rides the JSON manifest next to the
# array pytree, so an engine checkpoint restores mid-flight admission
# state, block tables, prefix index and LRU order exactly
# ---------------------------------------------------------------------------

from repro.serving.paged_cache import PagedCacheConfig, PagedKVCache  # noqa: E402
from repro.serving.scheduler import RequestScheduler                  # noqa: E402


def _midflight_control_plane():
    """A scheduler + host-only cache driven to a nontrivial state:
    queued work, in-flight budget, shared prefix blocks, an LRU-retired
    block and a mid-chunk committed cursor."""
    from repro.analysis.schedcheck import CONFIGS, ControlPlaneModel
    model = ControlPlaneModel(CONFIGS["priority-prefix"])
    state = model.initial_state()
    for _ in range(9):
        events = model.enabled_events(state)
        if not events:
            break
        state = model.apply(state, events[0])
    sched, cache, recs, _slots, _sub, _fin = model._materialize(state)
    return sched, cache, recs


def test_store_roundtrips_scheduler_and_cache_state(tmp_path):
    sched, cache, recs = _midflight_control_plane()
    sd, cd = sched.state_dict(), cache.host_state_dict()
    assert sd["queue"] or sd["in_flight_tokens"]       # state is nontrivial
    assert cd["tables"] and cd["prefix_index"]

    tree = {"w": jnp.arange(4.0)}
    save_pytree(tmp_path / "ckpt", tree,
                manifest_extra={"scheduler": sd, "cache": cd})
    _restored, manifest = restore_pytree(tmp_path / "ckpt", tree)

    sched2 = RequestScheduler()
    sched2.load_state_dict(manifest["scheduler"], recs)
    cache2 = PagedKVCache.host_only(cache.cfg)
    cache2.load_host_state_dict(manifest["cache"])

    # canonical snapshots are bit-identical after the JSON round trip
    # (tuples->lists is normalized away because state_dict regenerates)
    assert sched2.state_dict() == sd
    assert cache2.host_state_dict() == cd
    # behavioral check, not just structural: the restored prefix index
    # still answers match_prefix exactly as the original does
    probe = recs[3].prompt
    assert cache2.match_prefix(tuple(probe)) == \
        cache.match_prefix(tuple(probe))


def test_store_roundtrip_survives_empty_control_plane(tmp_path):
    """Degenerate snapshot: fresh objects, nothing queued or cached."""
    sched = RequestScheduler(max_tokens_in_flight=7, footprint_cap=5)
    cfg = PagedCacheConfig(block_size=2, num_blocks=4,
                           max_blocks_per_seq=4, share_prefix=True)
    cache = PagedKVCache.host_only(cfg)
    tree = {"w": jnp.zeros((2,))}
    save_pytree(tmp_path / "ckpt", tree,
                manifest_extra={"scheduler": sched.state_dict(),
                                "cache": cache.host_state_dict()})
    _r, manifest = restore_pytree(tmp_path / "ckpt", tree)
    sched2 = RequestScheduler()
    sched2.load_state_dict(manifest["scheduler"], {})
    cache2 = PagedKVCache.host_only(cfg)
    cache2.load_host_state_dict(manifest["cache"])
    assert sched2.state_dict() == sched.state_dict()
    assert sched2.max_tokens_in_flight == 7
    assert cache2.host_state_dict() == cache.host_state_dict()
    assert cache2.allocator.num_free == cache.allocator.num_free
