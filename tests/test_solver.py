"""ASA solver property tests (hypothesis) — the paper's core invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.components import Component
from repro.core.costmodel import CostModel, MeshShape
from repro.core.hardware import TPU_V5E
from repro.core.solver import (solve, solve_exhaustive, solve_greedy,
                               solve_uniform)
from repro.core.strategy import ALL_STRATEGIES, Strategy


@st.composite
def component_lists(draw, max_comps=6):
    n = draw(st.integers(2, max_comps))
    comps = []
    for i in range(n):
        params = draw(st.floats(1e6, 5e10))
        flops = draw(st.floats(1e9, 1e15))
        act = draw(st.floats(1e5, 1e9))
        comps.append(Component(
            name=f"c{i}", kind="attn", count=draw(st.integers(1, 8)),
            params=params, shared_params=False, flops_fwd=flops,
            act_bytes=act, n_model_allreduce=draw(st.integers(1, 3)),
            moe_a2a_bytes=0.0, kv_bytes=act))
    return comps


def _cm(mode="train", faithful=True):
    return CostModel(hw=TPU_V5E, mesh=MeshShape(16, 16), mode=mode,
                     faithful=faithful)


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(component_lists())
def test_adaptive_never_loses_to_static(comps):
    """cost(ASA) <= cost(best feasible uniform) — the paper's headline."""
    cm = _cm()
    plan = solve(cm, comps)
    for s in ALL_STRATEGIES:
        u = solve_uniform(cm, comps, s)
        if u.cost["mem_per_device"] <= cm.hw.hbm_bytes and plan.feasible:
            assert plan.cost["time"] <= u.cost["time"] * (1 + 1e-9)


@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(component_lists())
def test_solver_respects_memory_when_possible(comps):
    cm = _cm()
    limit = cm.hw.hbm_bytes
    any_feasible = any(
        cm.assignment_cost(comps, {c.name: s for c in comps})["mem_per_device"]
        <= limit for s in ALL_STRATEGIES)
    plan = solve(cm, comps, mem_limit=limit)
    if any_feasible:
        assert plan.feasible
        assert plan.cost["mem_per_device"] <= limit * (1 + 1e-9)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(component_lists(max_comps=5))
def test_greedy_matches_exhaustive_when_unconstrained(comps):
    """With no memory pressure, greedy == exhaustive == per-comp argmin."""
    cm = _cm()
    g = solve_greedy(cm, comps, mem_limit=float("inf"))
    e = solve_exhaustive(cm, comps, mem_limit=float("inf"))
    assert abs(g.cost["time"] - e.cost["time"]) <= 1e-9 * e.cost["time"] + 1e-12


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(component_lists(max_comps=4))
def test_greedy_within_bound_of_exhaustive(comps):
    cm = _cm()
    g = solve_greedy(cm, comps)
    e = solve_exhaustive(cm, comps)
    if g.feasible and e.feasible:
        assert g.cost["time"] <= 2.0 * e.cost["time"] + 1e-12


def test_memory_ordering():
    """Per-component memory: DP >= MP >= HP (the repair direction)."""
    c = Component("c", "attn", 4, params=1e9, shared_params=False,
                  flops_fwd=1e12, act_bytes=1e8, n_model_allreduce=2)
    cm = _cm()
    mems = {s: (cm.component_cost(c, s).mem_params
                + cm.component_cost(c, s).mem_act) for s in ALL_STRATEGIES}
    assert mems[Strategy.DP] >= mems[Strategy.MP] >= mems[Strategy.HP]


def test_faithful_mode_has_no_transition_costs():
    cm = _cm(faithful=True)
    assert cm.transition_cost(Strategy.DP, Strategy.MP, 1e9) == 0.0
    cm2 = _cm(faithful=False)
    assert cm2.transition_cost(Strategy.DP, Strategy.MP, 1e9) > 0.0
    assert cm2.transition_cost(Strategy.MP, Strategy.MP, 1e9) == 0.0
