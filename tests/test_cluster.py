"""Cluster serving tests: detok/stop-strings, wire protocol, router
placement + health (fake transports, injected clock — no subprocesses,
no jax), in-process cluster parity (real engines over InProcTransport),
and the subprocess/HTTP end-to-end battery (marked slow; the CI
serving-cluster job runs it).

The subprocess e2e fixture boots ONE 2-replica cluster for the whole
module; the SIGTERM/teardown test is deliberately the last test in the
file — it kills that cluster and asserts clean worker reaping.
"""
from __future__ import annotations

import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time
import urllib.request

import pytest

from repro.serving.cluster.protocol import (ClusterError, ConnectionClosed,
                                            InProcTransport, MessageStream,
                                            ProtocolError, ReplicaDeadError,
                                            SubmitRejectedError,
                                            decode_message, encode_message,
                                            sampling_to_wire)
from repro.serving.cluster.affinity import PrefixAffinity
from repro.serving.cluster.router import ReplicaHandle, Router
from repro.serving.detok import StopStringMatcher, default_detokenizer
from repro.serving.export import parse_prometheus_text
from repro.serving.prefix_hash import chain_keys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# detok / stop strings
# ---------------------------------------------------------------------------

def _stream_invariant(stops, pieces):
    """Feed ``pieces`` and check the emission invariant after every feed:
    concatenated emissions never contain a stop string."""
    m = StopStringMatcher(stops)
    emitted = ""
    for piece in pieces:
        emitted += m.feed(piece)
        for s in stops:
            assert s not in emitted
    return m, emitted


def test_stop_matcher_basic_match_and_trim():
    m = StopStringMatcher(["STOP"])
    out = [m.feed(p) for p in ["he", "llo S", "TO", "P world"]]
    assert "".join(out) == "hello "
    assert m.matched == "STOP"
    assert m.feed("more") == ""          # dead after match


def test_stop_matcher_never_streams_partial_suffix():
    # the partial suffix "S", "ST", "STO" must be withheld until resolved
    m = StopStringMatcher(["STOP"])
    assert m.feed("abcS") == "abc"
    assert m.held == "S"
    assert m.feed("T") == ""
    assert m.feed("Oz") == "STOz"        # resolved: not a stop, released
    assert m.matched is None


def test_stop_matcher_flush_releases_tail():
    m = StopStringMatcher(["xyz"])
    assert [m.feed("ab"), m.feed("cx"), m.feed("y")] == ["ab", "c", ""]
    assert m.flush() == "xy"
    assert m.matched is None


def test_stop_matcher_earliest_match_wins():
    m = StopStringMatcher(["bb", "abc"])
    # "aabcbb": "abc" starts at 1, "bb" at 4 -> "abc" fires, text "a"
    assert m.feed("aabcbb") == "a"
    assert m.matched == "abc"


def test_stop_matcher_match_across_many_tokens():
    detok = default_detokenizer()
    stop = detok.decode(7) + detok.decode(9)       # "t7 t9 "
    m = StopStringMatcher([stop])
    emitted = "".join(m.feed(detok.decode(t)) for t in [1, 7, 9, 2])
    assert m.matched == stop
    assert emitted == "t1 "


@pytest.mark.parametrize("stops", [["ab"], ["aba", "bab"], ["aa", "b"]])
def test_stop_matcher_fuzz_chunkings(stops):
    import random
    rng = random.Random(0)
    for trial in range(50):
        text = "".join(rng.choice("ab") for _ in range(30))
        # random chunking of the same text must match deterministically
        pieces, i = [], 0
        while i < len(text):
            n = rng.randint(1, 4)
            pieces.append(text[i:i + n])
            i += n
        m, emitted = _stream_invariant(stops, pieces)
        whole = StopStringMatcher(stops)
        whole_out = whole.feed(text)
        assert (m.matched is None) == (whole.matched is None)
        if m.matched is not None:
            assert emitted == whole_out     # trim point chunking-invariant
        else:
            assert emitted + m.flush() == text


def test_stop_matcher_rejects_bad_stops():
    with pytest.raises(ValueError):
        StopStringMatcher([""])
    with pytest.raises(ValueError):
        StopStringMatcher([7])


def test_sampling_params_stop_string_validation():
    from repro.serving.sampling import SamplingParams
    SamplingParams(stop=("done",)).validate(100)
    with pytest.raises(ValueError):
        SamplingParams(stop=("",)).validate(100)
    with pytest.raises(ValueError):
        SamplingParams(stop=(3,)).validate(100)


# ---------------------------------------------------------------------------
# prefix hash chain + affinity index
# ---------------------------------------------------------------------------

def test_chain_keys_incremental_extension_composes():
    toks = list(range(40))
    full = chain_keys(toks, 8)
    head = chain_keys(toks, 8, 0, 3)
    tail = chain_keys(toks, 8, 3, 5, prev=head[-1])
    assert head + tail == full
    assert len(full) == 5


def test_chain_keys_match_paged_cache_keys():
    """The affinity index and the paged cache must key identically —
    equal prompts produce equal chain keys regardless of consumer."""
    toks = list(range(32))
    a = chain_keys(toks, 16)
    b = chain_keys(tuple(toks), 16)       # sequence type must not matter
    assert a == b
    # a different final chunk changes only the final key
    toks2 = toks[:-1] + [99]
    c = chain_keys(toks2, 16)
    assert c[0] == a[0] and c[1] != a[1]


def test_affinity_longest_prefix_wins():
    af = PrefixAffinity(4)
    af.commit(list(range(8)), 0)            # blocks 0,1 -> replica 0
    replica, n = af.route(list(range(16)), [0, 1])
    assert (replica, n) == (0, 2)           # partial chain still routes
    af.commit(list(range(16)), 1)           # blocks 0..3 -> replica 1
    replica, n = af.route(list(range(16)), [0, 1])
    assert (replica, n) == (1, 4)           # longest chain owns the route
    # commit overwrote the shared blocks' owner, so dropping replica 1
    # leaves no affinity signal: route declines and the router falls
    # back to least-loaded (the index is a hint, not ground truth)
    af.drop_replica(1)
    replica, n = af.route(list(range(16)), [0])
    assert (replica, n) == (None, 0)


def test_affinity_lru_cap_evicts_coldest():
    af = PrefixAffinity(2, max_keys=4)
    af.commit([1, 2, 3, 4], 0)              # 2 keys
    af.commit([5, 6, 7, 8], 1)              # +2 keys (at cap)
    af.commit([9, 10], 0)                   # +1 -> evicts coldest
    assert len(af) == 4
    assert af.stats["keys_evicted"] == 1


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------

def test_ndjson_roundtrip_and_errors():
    msg = {"type": "submit", "rid": 3, "prompt": [1, 2], "sampling": {}}
    assert decode_message(encode_message(msg)[:-1]) == msg
    with pytest.raises(ProtocolError):
        decode_message(b"{not json")
    with pytest.raises(ProtocolError):
        decode_message(b'["no", "type"]')


def test_message_stream_reassembles_split_frames():
    a, b = socket.socketpair()
    try:
        sa, sb = MessageStream(a), MessageStream(b)
        payload = encode_message({"type": "token", "rid": 1, "token": 5}) \
            + encode_message({"type": "token", "rid": 1, "token": 6})
        a.sendall(payload[:10])             # mid-frame split
        got = sb.poll(0.2)                  # nothing complete yet
        a.sendall(payload[10:])
        for _ in range(10):
            got += sb.poll(0.2)
            if len(got) == 2:
                break
        assert [m["token"] for m in got] == [5, 6]
        sa.send({"type": "ping", "seq": 1})
        assert sb.poll(0.2)[0]["type"] == "ping"
    finally:
        a.close()
        b.close()


def test_message_stream_eof_after_buffered_messages():
    a, b = socket.socketpair()
    sb = MessageStream(b)
    a.sendall(encode_message({"type": "drained"}))
    a.close()
    try:
        got = []
        for _ in range(10):
            try:
                got += sb.poll(0.2)
            except ConnectionClosed:
                break
        assert got and got[0]["type"] == "drained"   # message not lost
        with pytest.raises(ConnectionClosed):
            sb.poll(0.0)
    finally:
        b.close()


def test_message_stream_send_timeout_escalates():
    """A peer that never drains its socket must not block send forever —
    the router calls send under its lock, so an unbounded sendall there
    would wedge the poll thread too.  The timeout escalates to
    ConnectionClosed (-> mark dead at the call sites)."""
    a, b = socket.socketpair()
    try:
        a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 8192)
        stream = MessageStream(a, send_timeout=0.2)
        big = {"type": "submit", "rid": 0, "prompt": [7] * 20000}
        with pytest.raises(ConnectionClosed):
            for _ in range(64):            # peer never reads: buffers fill
                stream.send(big)
    finally:
        a.close()
        b.close()


def test_sampling_from_wire_rejects_bare_string_seqs():
    from repro.serving.cluster.protocol import sampling_from_wire
    # a bare string would silently become per-character entries
    with pytest.raises(ValueError):
        sampling_from_wire({"stop": "END"})
    with pytest.raises(ValueError):
        sampling_from_wire({"stop_token_ids": "12"})
    assert sampling_from_wire({"stop": ["END"]}).stop == ("END",)


def test_sampling_from_wire_wrong_types_raise_catchable():
    """Wrong-typed wire JSON raises ValueError or TypeError — both of
    which the worker's submit handler catches (a null temperature once
    crashed the replica process)."""
    from repro.serving.cluster.protocol import sampling_from_wire
    for bad in ({"temperature": None}, {"top_k": "x"}, {"seed": "s"},
                {"top_p": [1]}):
        with pytest.raises((TypeError, ValueError)):
            sampling_from_wire(bad)


def test_inproc_transport_close_semantics():
    a, b = InProcTransport.pair()
    a.send({"type": "ping", "seq": 0})
    assert b.poll()[0]["type"] == "ping"
    a.close()
    with pytest.raises(ConnectionClosed):
        b.poll()
    with pytest.raises(ConnectionClosed):
        b.send({"type": "pong", "seq": 0})


# ---------------------------------------------------------------------------
# router unit tests: fake scripted transports, injected clock, no jax
# ---------------------------------------------------------------------------

class FakeTransport:
    """Scripted worker-side view: the test inspects ``sent`` (messages
    the router pushed) and enqueues replies via ``reply``."""

    def __init__(self):
        self.sent: list[dict] = []
        self._inbox: list[dict] = []
        self.closed = False

    def send(self, msg: dict) -> None:
        if self.closed:
            raise ConnectionClosed("closed")
        self.sent.append(decode_message(encode_message(msg)[:-1]))

    def reply(self, msg: dict) -> None:
        self._inbox.append(msg)

    def poll(self, timeout: float = 0.0) -> list[dict]:
        if self.closed and not self._inbox:
            raise ConnectionClosed("closed")
        out, self._inbox = self._inbox, []
        return out

    def close(self) -> None:
        self.closed = True


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_router(n=2, **kw):
    clock = kw.pop("clock", FakeClock())
    transports = [FakeTransport() for _ in range(n)]
    handles = [ReplicaHandle(replica=i, transport=t, max_len=64)
               for i, t in enumerate(transports)]
    kw.setdefault("block_size", 4)
    kw.setdefault("heartbeat_interval", 1.0)
    kw.setdefault("heartbeat_timeout", 5.0)
    router = Router(handles, clock=clock, **kw)
    return router, transports, clock


class Sink:
    def __init__(self):
        self.tokens: list[int] = []
        self.finish = None
        self.error = None

    def cb(self):
        return dict(on_token=lambda rid, tok, lp: self.tokens.append(tok),
                    on_finish=lambda m: setattr(self, "finish", m),
                    on_error=lambda e: setattr(self, "error", e))


def test_router_deterministic_least_loaded_placement():
    router, tr, clock = make_router(2)
    # empty cluster, no affinity: ties break on replica id -> replica 0
    r0 = router.submit([91, 92, 93], 8)
    assert tr[0].sent[-1]["rid"] == r0
    # replica 0 now loaded -> replica 1 (estimates, not stats, decide)
    r1 = router.submit([81, 82, 83], 8)
    assert tr[1].sent[-1]["rid"] == r1
    assert router.aggregate_stats()["affinity"]["routed_fallback"] == 2


def test_router_longest_prefix_same_replica():
    router, tr, clock = make_router(2)
    shared = list(range(100, 112))                       # 3 full blocks of 4
    router.submit(shared + [1], 8)                       # -> replica 0
    first = 0 if tr[0].sent else 1
    # a heavier-loaded replica still wins on prefix affinity
    for suffix in ([2], [3], [4]):
        router.submit(shared + suffix, 8)
    sent_to_first = [m for m in tr[first].sent if m["type"] == "submit"]
    assert len(sent_to_first) == 4                       # all co-located
    assert router.aggregate_stats()["affinity"]["routed_affinity"] == 3


def test_router_token_and_finish_flow():
    router, tr, clock = make_router(1)
    sink = Sink()
    rid = router.submit([1, 2, 3], 4, **sink.cb())
    for t in (10, 11):
        tr[0].reply({"type": "token", "rid": rid, "token": t})
    tr[0].reply({"type": "finish", "rid": rid, "token_ids": [10, 11],
                 "finish_reason": "length", "prompt_len": 3,
                 "ttft_s": 0.1, "tpot_s": 0.01})
    router.poll(0.0)
    assert sink.tokens == [10, 11]
    assert sink.finish["finish_reason"] == "length"
    assert router.pending_count == 0
    assert router.aggregate_stats()["router"]["finished"] == 1


def test_router_submit_rejection_surfaces_typed_error():
    router, tr, clock = make_router(1)
    sink = Sink()
    rid = router.submit([1], 4, **sink.cb())
    tr[0].reply({"type": "error", "rid": rid, "error": "rejected",
                 "message": "prompt too long"})
    router.poll(0.0)
    assert isinstance(sink.error, SubmitRejectedError)
    assert router.pending_count == 0


def test_router_heartbeat_timeout_marks_dead_and_fails_inflight():
    router, tr, clock = make_router(2, heartbeat_timeout=5.0)
    sink = Sink()
    rid = router.submit([1, 2, 3], 4, **sink.cb())
    owner = 0 if any(m.get("rid") == rid for m in tr[0].sent) else 1
    survivor = 1 - owner
    # the survivor answers heartbeats; the owner goes silent
    clock.advance(4.0)
    router.poll(0.0)                       # pings both (interval elapsed)
    tr[survivor].reply({"type": "pong", "seq": 1, "stats": {}})
    router.poll(0.0)                       # survivor's last_seen -> 4.0
    clock.advance(2.0)                     # owner silent for 6s > 5s timeout
    router.poll(0.0)
    assert isinstance(sink.error, ReplicaDeadError)
    assert sink.error.replica == owner
    assert router.replica_states()[owner]["state"] == "dead"
    assert router.replica_states()[survivor]["state"] == "live"
    # dead is absorbing and the survivor keeps serving
    rid2 = router.submit([4, 5, 6], 4)
    assert any(m.get("rid") == rid2 for m in tr[survivor].sent)
    assert router.replica_states()[owner]["state"] == "dead"


def test_router_dead_replica_rebalances_affinity():
    router, tr, clock = make_router(2, heartbeat_timeout=5.0)
    shared = list(range(16))
    router.submit(shared, 4)
    owner = 0 if any(m["type"] == "submit" for m in tr[0].sent) else 1
    tr[owner].closed = True                # EOF instead of timeout
    router.poll(0.0)
    assert router.replica_states()[owner]["state"] == "dead"
    # the shared prefix must re-route to the survivor, not the ghost
    router.submit(shared + [1], 4)
    survivor = 1 - owner
    submits = [m for m in tr[survivor].sent if m["type"] == "submit"]
    assert len(submits) == 1


def test_router_no_live_replicas_raises():
    router, tr, clock = make_router(1)
    tr[0].closed = True
    router.poll(0.0)
    with pytest.raises(ClusterError):
        router.submit([1, 2], 4)


def test_router_heartbeat_pings_and_last_seen_monotone():
    router, tr, clock = make_router(1, heartbeat_interval=1.0)
    seen0 = router.replica_states()[0]["last_seen"]
    clock.advance(1.5)
    router.poll(0.0)
    assert any(m["type"] == "ping" for m in tr[0].sent)
    tr[0].reply({"type": "pong", "seq": 1,
                 "stats": {"outstanding_tokens": 0, "prom": "x 1\n"}})
    router.poll(0.0)
    seen1 = router.replica_states()[0]["last_seen"]
    assert seen1 >= seen0                  # monotone (invariant section 10)
    assert router.replica_states()[0]["stats"]["outstanding_tokens"] == 0


def test_router_cancel_forwards_to_owner():
    router, tr, clock = make_router(1)
    rid = router.submit([1, 2], 4)
    assert router.cancel(rid, reason="stop")
    assert tr[0].sent[-1] == {"type": "cancel", "rid": rid,
                              "reason": "stop"}
    assert not router.cancel(rid + 999)


def test_router_poll_contains_protocol_error_marks_dead():
    """A malformed worker message must never propagate out of poll()
    (it would kill the only poll thread while the HTTP server keeps
    accepting): the offender dies, survivors keep serving."""
    router, tr, clock = make_router(2)
    sink = Sink()
    rid = router.submit([1, 2, 3], 4, **sink.cb())     # -> replica 0
    assert any(m.get("rid") == rid for m in tr[0].sent)
    tr[0].reply({"type": "bogus-type"})
    router.poll(0.0)                                   # must not raise
    assert router.replica_states()[0]["state"] == "dead"
    assert isinstance(sink.error, ReplicaDeadError)
    rid2 = router.submit([4, 5, 6], 4)                 # survivor serves on
    assert any(m.get("rid") == rid2 for m in tr[1].sent)


def test_generate_body_rejects_wrong_typed_sampling():
    """Type errors become a 400 at the HTTP boundary — the frontend must
    never forward JSON a worker would choke on."""
    from repro.serving.cluster.frontend import _parse_generate_body
    bad = [{"temperature": None}, {"temperature": "hot"}, {"top_k": 1.5},
           {"top_p": "x"}, {"seed": "s"}, {"logprobs": 1},
           {"stop_token_ids": "12"}, {"stop_token_ids": [1, "2"]}]
    for fields in bad:
        with pytest.raises(ValueError):
            _parse_generate_body({"prompt": [1, 2], **fields})
        with pytest.raises(ValueError):                # nested form too
            _parse_generate_body({"prompt": [1, 2], "sampling": fields})


def test_generate_body_rejects_bare_string_stop():
    """'stop': 'END' must be a 400, not per-character stops 'E','N','D'
    silently truncating at the first matching letter."""
    from repro.serving.cluster.frontend import _parse_generate_body
    for fields in ({"stop": "END"}, {"stop": [""]}, {"stop": [1]},
                   {"stop": {"s": 1}}):
        with pytest.raises(ValueError):
            _parse_generate_body({"prompt": [1, 2], **fields})
    *_, stops = _parse_generate_body({"prompt": [1, 2], "stop": ["END"]})
    assert stops == ("END",)


def test_generate_body_sampling_nested_or_top_level():
    from repro.serving.cluster.frontend import _parse_generate_body
    # top-level form (what the e2e tests use)
    _, _, _, sampling, _, stops = _parse_generate_body(
        {"prompt": [1, 2], "temperature": 0.5, "stop": ["t3 "]})
    assert sampling == {"temperature": 0.5} and stops == ("t3 ",)
    # nested form (what docs/SERVING.md leads with); nested wins
    _, _, _, sampling, _, stops = _parse_generate_body(
        {"prompt": [1, 2], "temperature": 0.9,
         "sampling": {"temperature": 0.5, "seed": 7, "stop": ["t3 "]}})
    assert sampling == {"temperature": 0.5, "seed": 7}
    assert stops == ("t3 ",)
    with pytest.raises(ValueError):
        _parse_generate_body({"prompt": [1, 2], "sampling": "greedy"})


def test_router_prometheus_text_parses():
    router, tr, clock = make_router(2)
    router.submit([1, 2], 4)
    tr[0].reply({"type": "pong", "seq": 1, "stats": {
        "prom": '# TYPE repro_serving_tokens_total counter\n'
                'repro_serving_tokens_total{replica="0"} 7\n'}})
    router.poll(0.0)
    series = parse_prometheus_text(router.prometheus_text())
    assert series["repro_serving_router_requests_routed_total"] == [({}, "1")]
    assert series["repro_serving_router_replicas_live"] == [({}, "2")]
    assert series["repro_serving_tokens_total"] == [({"replica": "0"}, "7")]


# ---------------------------------------------------------------------------
# engine.cancel / outstanding_tokens (real engine, tiny arch)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_cluster_pieces():
    import jax

    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from tests.serving_fixtures import TINY
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    return TINY, params, make_host_mesh()


def make_engine(pieces, **kw):
    from repro.analysis.sanitizer import CacheSanitizer
    from repro.serving import ContinuousBatchingEngine
    arch, params, mesh = pieces
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 8)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("sanitizer", CacheSanitizer())
    return ContinuousBatchingEngine(arch, params, mesh, **kw)


def test_engine_cancel_running_request(tiny_cluster_pieces):
    from repro.serving import Request
    eng = make_engine(tiny_cluster_pieces)
    eng.submit(Request(id=0, prompt=[1, 2, 3, 4], max_new_tokens=16))
    for _ in range(3):
        eng.step()                      # prefill + a couple of tokens
    assert eng.cancel(0, reason="client_disconnect")
    assert eng.completed[-1].request_id == 0
    assert eng.completed[-1].finish_reason == "client_disconnect"
    assert not eng.has_work
    eng.run_until_drained()             # sanitizer: no leaked blocks
    assert eng.outstanding_tokens() == 0


def test_engine_cancel_queued_request(tiny_cluster_pieces):
    from repro.serving import Request
    eng = make_engine(tiny_cluster_pieces, slots=1)
    eng.submit(Request(id=0, prompt=[1, 2, 3, 4], max_new_tokens=4))
    eng.submit(Request(id=1, prompt=[5, 6, 7, 8], max_new_tokens=4))
    eng.step()                          # req 0 admitted, req 1 queued
    assert eng.cancel(1)
    out = [o for o in eng.completed if o.request_id == 1]
    assert out and out[0].finish_reason == "cancelled"
    assert out[0].token_ids == []
    eng.run_until_drained()
    assert {o.request_id for o in eng.completed} == {0, 1}
    assert eng.scheduler.queue_depth == 0


def test_engine_cancel_unknown_rid(tiny_cluster_pieces):
    eng = make_engine(tiny_cluster_pieces)
    assert not eng.cancel(123)


def test_engine_outstanding_tokens_decreases(tiny_cluster_pieces):
    from repro.serving import Request
    eng = make_engine(tiny_cluster_pieces)
    eng.submit(Request(id=0, prompt=[1, 2, 3, 4], max_new_tokens=8))
    est0 = eng.outstanding_tokens()
    assert est0 == 8
    for _ in range(4):
        eng.step()
    assert eng.outstanding_tokens() < est0
    eng.run_until_drained()
    assert eng.outstanding_tokens() == 0


# ---------------------------------------------------------------------------
# in-process cluster: real engines + Router over InProcTransport
# ---------------------------------------------------------------------------

def drive(router, workers, done):
    """Pump workers and router until ``done()`` or progress stalls."""
    for _ in range(5000):
        for w in workers:
            w.pump(idle_poll=0.0)
        router.poll(0.0)
        if done():
            return
    raise AssertionError("in-process cluster did not converge")


def make_inproc_cluster(pieces, n=2, **engine_kw):
    from repro.serving.cluster.worker import EngineWorker
    workers, handles = [], []
    for i in range(n):
        wt, rt = InProcTransport.pair()
        workers.append(EngineWorker(make_engine(pieces, **engine_kw), wt, i))
        handles.append(ReplicaHandle(replica=i, transport=rt, max_len=64))
    router = Router(handles, block_size=8, heartbeat_timeout=1e9)
    return router, workers


def test_inproc_cluster_greedy_parity(tiny_cluster_pieces):
    import numpy as np

    from repro.serving import Request
    router, workers = make_inproc_cluster(tiny_cluster_pieces)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 250, size=12).tolist() for _ in range(4)]
    results = {}
    for p in prompts:
        router.submit(p, 8, on_finish=lambda m: results.__setitem__(
            m["rid"], m))
    drive(router, workers, lambda: len(results) == 4)

    ref = make_engine(tiny_cluster_pieces).generate(
        [Request(id=i, prompt=p, max_new_tokens=8)
         for i, p in enumerate(prompts)])
    for i, o in enumerate(ref):
        assert results[i]["token_ids"] == o.token_ids, \
            f"replica output diverged from single-process on request {i}"
        assert results[i]["finish_reason"] == o.finish_reason


def test_inproc_cluster_shared_prefix_affinity(tiny_cluster_pieces):
    """Shared-prefix traffic must co-locate on one replica and keep the
    prefix cache hot there — the hit signal survives clustering."""
    router, workers = make_inproc_cluster(tiny_cluster_pieces,
                                          share_prefix=True)
    shared = list(range(100, 116))                     # two full blocks
    results = {}
    for i in range(4):
        router.submit(shared + [1 + i], 6,
                      on_finish=lambda m: results.__setitem__(m["rid"], m))
        # serialize: let each request land (and commit blocks) before the
        # next routes, as a live cluster would under a Poisson trace
        drive(router, workers, lambda: len(results) == i + 1)
    assert router.aggregate_stats()["affinity"]["routed_affinity"] == 3
    hits = [w.engine.metrics.summary()["prefix_hit_rate"] for w in workers]
    assert max(hits) > 0.5                 # the co-located replica is hot
    busy = [i for i, w in enumerate(workers) if w.engine.completed]
    assert len(busy) == 1                  # all four on one replica


def test_inproc_cluster_stop_token_and_cancel(tiny_cluster_pieces):
    from repro.serving.sampling import GREEDY
    router, workers = make_inproc_cluster(tiny_cluster_pieces)
    results = {}
    streamed = []
    rid = router.submit([1, 2, 3, 4], 32,
                        sampling=sampling_to_wire(GREEDY),
                        on_token=lambda r, t, lp: streamed.append(t),
                        on_finish=lambda m: results.__setitem__(
                            m["rid"], m))
    # let a couple of tokens stream, then cancel mid-flight
    drive(router, workers, lambda: len(streamed) >= 2)
    router.cancel(rid, reason="stop")
    drive(router, workers, lambda: rid in results)
    assert results[rid]["finish_reason"] == "stop"
    assert 0 < len(results[rid]["token_ids"]) < 32


def test_worker_bad_typed_sampling_rejects_not_crash(tiny_cluster_pieces):
    """Wrong-typed sampling JSON ("temperature": null) reaching a worker
    must reject the one request with a typed error — pre-fix it raised
    TypeError out of the pump loop and killed the replica process."""
    router, workers = make_inproc_cluster(tiny_cluster_pieces, n=1)
    sink = Sink()
    router.submit([1, 2, 3], 4, sampling={"temperature": None},
                  **sink.cb())
    drive(router, workers, lambda: sink.error is not None)
    assert isinstance(sink.error, SubmitRejectedError)
    assert router.replica_states()[0]["state"] == "live"
    # the worker survived: a well-typed request still completes on it
    results = {}
    router.submit([1, 2, 3, 4], 4,
                  on_finish=lambda m: results.__setitem__(m["rid"], m))
    drive(router, workers, lambda: results)


def test_frontend_disconnect_cancels_request(tiny_cluster_pieces):
    """A client that drops mid-SSE must cancel its rid upstream — the
    engine must not generate the remaining tokens as wasted work."""
    from repro.serving.cluster.frontend import ClusterHTTPServer
    router, workers = make_inproc_cluster(tiny_cluster_pieces, n=1)
    http = ClusterHTTPServer(router)
    stop_pump = threading.Event()

    def pump():
        while not stop_pump.is_set():
            for w in workers:
                w.pump(idle_poll=0.0)
            router.poll(0.0)
            time.sleep(0.001)

    threading.Thread(target=pump, daemon=True).start()
    threading.Thread(target=http.serve_forever, daemon=True).start()
    try:
        host, port = http.server_address[:2]
        body = json.dumps({"prompt": [1, 2, 3, 4], "max_new_tokens": 48,
                           "stream": True}).encode()
        conn = socket.create_connection((host, port))
        conn.sendall(b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                     b"Content-Type: application/json\r\n"
                     + f"Content-Length: {len(body)}\r\n\r\n".encode()
                     + body)
        buf = b""
        while b"data: " not in buf:        # first streamed token arrived
            chunk = conn.recv(4096)
            assert chunk, "server closed before streaming any token"
            buf += chunk
        conn.close()                       # client vanishes mid-stream
        deadline = time.time() + 60
        while time.time() < deadline and router.pending_count:
            time.sleep(0.01)
        assert router.pending_count == 0, "rid never left the router"
        assert router.stats["cancelled"] >= 1
        done = workers[0].engine.completed
        assert done and done[-1].finish_reason == "disconnect"
        assert len(done[-1].token_ids) < 48    # generation actually stopped
    finally:
        stop_pump.set()
        http.shutdown()
        http.server_close()


# ---------------------------------------------------------------------------
# launch/serve.py crash-flush regression (injected failing step)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_crash_flushes_artifacts(tmp_path, monkeypatch):
    from repro.serving import ContinuousBatchingEngine
    from repro.launch import serve

    calls = {"n": 0}
    real = ContinuousBatchingEngine._decode_step

    def failing(self):
        calls["n"] += 1
        if calls["n"] > 3:
            raise RuntimeError("injected mid-drain failure")
        return real(self)

    monkeypatch.setattr(ContinuousBatchingEngine, "_decode_step", failing)
    trace = tmp_path / "trace.json"
    prom = tmp_path / "metrics.prom"
    mout = tmp_path / "metrics.json"
    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "qwen3-8b", "--smoke", "--requests", "2",
        "--prompt-len", "8", "--max-new", "8", "--max-len", "64",
        "--block-size", "8", "--prefill-chunk", "16",
        "--trace-out", str(trace), "--prom-out", str(prom),
        "--metrics-out", str(mout), "--metrics-every", "0.001"])
    with pytest.raises(SystemExit) as exc:
        serve.main()
    assert exc.value.code == 1             # non-zero exit on engine failure
    # every artifact flushed complete through the atomic paths
    assert json.loads(trace.read_text())["traceEvents"]
    assert parse_prometheus_text(prom.read_text())
    assert len(json.loads(mout.read_text())["requests"]) == 2
    snap = tmp_path / "metrics.json.jsonl"
    assert snap.exists()
    for line in snap.read_text().splitlines():
        json.loads(line)                   # no stranded half-written cycle


# ---------------------------------------------------------------------------
# subprocess end-to-end: real cluster, HTTP/SSE (CI serving-cluster job)
# ---------------------------------------------------------------------------

def _http(url, body=None, timeout=240.0):
    req = urllib.request.Request(
        url, data=None if body is None else json.dumps(body).encode(),
        method="GET" if body is None else "POST")
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        return resp.status, resp.read().decode()


def _sse_events(url, body, timeout=240.0):
    req = urllib.request.Request(url, data=json.dumps(body).encode(),
                                 method="POST")
    events = []
    with urllib.request.urlopen(req, timeout=timeout) as resp:
        assert resp.headers["Content-Type"].startswith("text/event-stream")
        for raw in resp:
            line = raw.decode().strip()
            if line.startswith("data: "):
                events.append(json.loads(line[len("data: "):]))
    return events


@pytest.fixture(scope="module")
def live_cluster():
    """One real 2-replica cluster for the whole module.  Yields
    (proc, url, worker_pids).  The SIGTERM test kills it; teardown
    tolerates that."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_cluster",
         "--arch", "qwen3-8b", "--smoke", "--replicas", "2",
         "--max-len", "64", "--block-size", "8", "--prefill-chunk", "16"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    url, pids = None, []
    deadline = time.time() + 600
    while time.time() < deadline:
        line = proc.stdout.readline()
        if not line:
            raise RuntimeError(f"cluster died at boot "
                               f"(rc={proc.poll()})")
        if line.startswith("serving on "):
            url = line.split()[2]
        if line.startswith("worker pids: "):
            pids = [int(p) for p in line.split(":")[1].split()]
            break
    if url is None or not pids:
        proc.kill()
        raise RuntimeError("cluster never reported ready")
    yield proc, url, pids
    if proc.poll() is None:
        proc.terminate()
        proc.wait(timeout=60)


@pytest.mark.slow
def test_e2e_healthz_and_metrics(live_cluster):
    proc, url, pids = live_cluster
    status, body = _http(url + "/healthz")
    health = json.loads(body)
    assert status == 200 and health["status"] == "ok"
    assert set(health["replicas"].values()) == {"live"}
    status, body = _http(url + "/metrics")
    series = parse_prometheus_text(body)
    assert series["repro_serving_router_replicas_live"] == [({}, "2")]


@pytest.mark.slow
def test_e2e_generate_parity_with_single_process(live_cluster):
    """Greedy cluster outputs bit-identical to a single-process engine on
    the same trace — determinism makes this a hard assertion."""
    import jax
    import numpy as np

    from repro.configs import get_arch, reduce_for_smoke
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.serving import ContinuousBatchingEngine, Request

    proc, url, pids = live_cluster
    rng = np.random.default_rng(7)
    arch = reduce_for_smoke(get_arch("qwen3-8b"))
    prompts = [rng.integers(1, arch.vocab, size=10).tolist()
               for _ in range(4)]
    cluster_out = []
    for p in prompts:
        status, body = _http(url + "/v1/generate",
                             {"prompt": p, "max_new_tokens": 8})
        assert status == 200
        cluster_out.append(json.loads(body))

    params = T.init_lm(jax.random.PRNGKey(0), arch)
    eng = ContinuousBatchingEngine(arch, params, make_host_mesh(),
                                   slots=4, max_len=64, block_size=8,
                                   prefill_chunk=16)
    ref = eng.generate([Request(id=i, prompt=p, max_new_tokens=8)
                        for i, p in enumerate(prompts)])
    for got, want in zip(cluster_out, ref):
        assert got["token_ids"] == want.token_ids, \
            "cluster output diverged from single-process engine"
        assert got["finish_reason"] == want.finish_reason


@pytest.mark.slow
def test_e2e_sse_stream_with_stop_string(live_cluster):
    proc, url, pids = live_cluster
    # learn this prompt's greedy continuation, then stop on token #3's text
    status, body = _http(url + "/v1/generate",
                         {"prompt": [5, 6, 7, 8], "max_new_tokens": 6})
    toks = json.loads(body)["token_ids"]
    assert len(toks) == 6
    stop = f"t{toks[2]} "
    events = _sse_events(url + "/v1/generate",
                         {"prompt": [5, 6, 7, 8], "max_new_tokens": 6,
                          "stream": True, "stop": [stop]})
    done = events[-1]
    assert done["done"] and done["finish_reason"] == "stop"
    assert done["matched_stop"] == stop
    assert done["token_ids"] == toks[:2]       # trimmed at the match
    streamed = "".join(e.get("text", "") for e in events[:-1])
    assert stop not in streamed                # never streamed the match...
    for n in range(1, len(stop)):
        assert not streamed.endswith(stop[:n])  # ...nor a partial suffix
    assert streamed == done["text"]


@pytest.mark.slow
def test_e2e_sigterm_clean_teardown(live_cluster):
    """MUST run last in this module: kills the shared cluster.  SIGTERM
    to the router => exit 0, no orphan workers."""
    proc, url, pids = live_cluster
    proc.send_signal(signal.SIGTERM)
    rc = proc.wait(timeout=120)
    assert rc == 0, f"router exited {rc} on SIGTERM"
    deadline = time.time() + 30
    alive = list(pids)
    while alive and time.time() < deadline:
        alive = [p for p in alive if _pid_alive(p)]
        time.sleep(0.2)
    assert not alive, f"orphan worker processes: {alive}"


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


# ---------------------------------------------------------------------------
# bench acceptance criteria (boots its own clusters; independent of
# live_cluster, so running after the SIGTERM teardown test is fine)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cluster_bench_criteria():
    """Run the serve_bench cluster section at smoke size and check the
    acceptance criteria: clustering must not cost prefix locality (hit
    rate within 0.05 of a single-process engine on the same grouped
    shared-prefix trace), and — only where the host actually has cores to
    scale onto (CI sets REPRO_ASSERT_CLUSTER_SCALING=1; a 1-core box
    time-slices both replicas over one CPU) — 2 replicas must deliver
    >= 1.7x aggregate tok/s."""
    import argparse
    import importlib.util

    from repro.launch.mesh import make_host_mesh

    spec = importlib.util.spec_from_file_location(
        "serve_bench",
        os.path.join(REPO, "benchmarks", "serve_bench.py"))
    sb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(sb)
    args = argparse.Namespace(requests=16, rate=50.0, slots=4, max_len=128,
                              block_size=16, prefill_chunk=32,
                              prefix_len=64, cluster_replicas=2,
                              sanitize=False)
    row = sb.bench_cluster("qwen3-8b", args, make_host_mesh())
    assert abs(row["hit_rate_delta_vs_single_process"]) <= 0.05, row
    assert row["affinity"]["total_tokens"] > 0
    assert row["saturated_2_replica"]["total_tokens"] > 0
    if os.environ.get("REPRO_ASSERT_CLUSTER_SCALING") == "1" \
            and (os.cpu_count() or 1) >= 4:
        assert row["scaling_tokens_per_sec"] >= 1.7, row
