"""Dry-run tooling: trip-count-aware HLO collective parser + roofline
analytics (no device work — pure parsing/math)."""
import pytest

SYNTH_HLO = """\
HloModule synth

%body.1 (arg: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %p = (s32[], f32[16,128]) parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  ROOT %t = (s32[], f32[16,128]) tuple(%i, %ar)
}

%cond.1 (arg: (s32[], f32[16,128])) -> pred[] {
  %p2 = (s32[], f32[16,128]) parameter(0)
  %k = s32[] constant(36)
  ROOT %cmp = pred[] compare(%i2, %k), direction=LT
}

ENTRY %main (a: f32[16,128]) -> f32[16,128] {
  %a = f32[16,128] parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[16,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[16,128] get-tuple-element(%w), index=1
}
"""


def test_parse_collectives_scales_by_trip_count():
    from repro.launch.dryrun import parse_collectives
    out = parse_collectives(SYNTH_HLO)
    # all-reduce inside the 36-trip while: 16*128*4 bytes * 36
    assert out["all-reduce"]["bytes"] == 16 * 128 * 4 * 36
    assert out["all-reduce"]["count"] == 36
    # top-level all-gather counted once
    assert out["all-gather"]["bytes"] == 256 * 128 * 4
    assert out["all-gather"]["count"] == 1
    assert out["total_bytes"] == out["all-reduce"]["bytes"] + \
        out["all-gather"]["bytes"]


def test_bytes_of_shape_str_tuples_and_dtypes():
    from repro.launch.dryrun import _bytes_of_shape_str
    assert _bytes_of_shape_str("f32[2,3]") == 24
    assert _bytes_of_shape_str("(s32[], bf16[4,4])") == 4 + 32
    assert _bytes_of_shape_str("pred[8]") == 8


def test_model_flops_train_vs_decode():
    from repro.launch.dryrun import model_flops
    train = model_flops("qwen3-8b", "train_4k")
    dec = model_flops("qwen3-8b", "decode_32k")
    # 6*N*D for ~8.2B params x 1.05M tokens ~ 5e16
    assert 1e16 < train < 1e17
    assert dec < train / 1000


def test_roofline_analytics_sane():
    from benchmarks.roofline import hbm_bytes_analytic, hlo_flops_analytic
    f_xla = hlo_flops_analytic("qwen3-8b", "train_4k")
    f_pallas = hlo_flops_analytic("qwen3-8b", "train_4k",
                                  pallas_attention=True)
    assert f_pallas < f_xla          # kernel removes the 2x causal waste
    assert hbm_bytes_analytic("qwen3-8b", "train_4k") > 0
    tr = hlo_flops_analytic("qwen3-8b", "train_4k")
    pf = hlo_flops_analytic("qwen3-8b", "prefill_32k")
    assert tr > 0 and pf > 0


def test_shape_applicability_rules():
    from repro.configs import ARCHS, SHAPES, shape_applicable
    ok, _ = shape_applicable(ARCHS["mamba2-780m"], SHAPES["long_500k"])
    assert ok
    ok, why = shape_applicable(ARCHS["qwen3-8b"], SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        for a in ARCHS.values():
            assert shape_applicable(a, SHAPES[s])[0]
