"""Per-assigned-architecture smoke tests (deliverable f): reduced same-family
config, one forward + one train step on CPU, asserting shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.models import transformer as T
from repro.optim import optimizers as O
from repro.runtime import steps as ST


def _frontend(arch, B):
    if arch.frontend == "vision":
        return jnp.ones((B, arch.n_img_tokens, arch.d_model), jnp.float32)
    if arch.frontend == "audio":
        return jnp.ones((B, arch.encoder.seq_len, arch.d_model), jnp.float32)
    return None


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_smoke(name):
    arch = reduce_for_smoke(ARCHS[name])
    params = T.init_lm(jax.random.PRNGKey(0), arch)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, arch.vocab)
    out = T.lm_apply(params, arch, toks, frontend=_frontend(arch, B))
    assert out.logits.shape == (B, S, arch.padded_vocab)
    assert not np.any(np.isnan(np.asarray(out.logits))), name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name):
    arch = reduce_for_smoke(ARCHS[name])
    opt = O.adamw(1e-3)
    step = ST.make_train_step(arch, opt)
    params = T.init_lm(jax.random.PRNGKey(0), arch)
    ostate = opt[0](params)
    B, S = 2, 16
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, arch.vocab),
             "labels": jax.random.randint(key, (B, S), 0, arch.vocab)}
    fe = _frontend(arch, B)
    if fe is not None:
        batch["frontend"] = fe
    params2, ostate2, metrics = jax.jit(step)(params, ostate, batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert np.isfinite(float(metrics["grad_norm"])), name
    # parameters changed
    delta = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params2)))
    assert delta > 0, name


@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-780m", "zamba2-2.7b"])
def test_decode_step_smoke(name):
    arch = reduce_for_smoke(ARCHS[name])
    params = T.init_lm(jax.random.PRNGKey(0), arch)
    B = 2
    cache = T.init_cache(arch, B, 24, jnp.float32)
    pre = ST.make_prefill_step(arch)
    dec = ST.make_decode_step(arch)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, arch.vocab)
    logits, cache = jax.jit(pre)(params, cache, toks)
    assert logits.shape == (B, arch.padded_vocab)
    logits2, cache = jax.jit(dec)(params, cache, toks[:, :1])
    assert not np.any(np.isnan(np.asarray(logits2)))
