"""End-to-end behaviour tests: tiny training runs, checkpoint/restart,
decode parity — the system-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduce_for_smoke, ARCHS
from repro.configs.base import ArchConfig, Segment
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.optim import optimizers as O
from repro.optim.schedules import cosine_schedule
from repro.runtime import steps as ST

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  pattern=(Segment(("attn",), 2),), dtype="float32",
                  param_dtype="float32")


def _train(arch, steps=30, microbatches=1, quantized=False):
    opt = O.adamw(cosine_schedule(3e-3, 5, steps), quantized=quantized)
    step = ST.make_train_step(arch, opt, microbatches=microbatches)
    params = T.init_lm(jax.random.PRNGKey(0), arch)
    opt_state = opt[0](params)
    data = SyntheticLM(arch.vocab, 32, 8)
    jstep = jax.jit(step)
    losses = []
    for _ in range(steps):
        b = next(data)
        params, opt_state, m = jstep(params, opt_state,
                                     {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["ce"]))
    return losses, params, opt_state


def test_training_reduces_loss():
    losses, _, _ = _train(TINY)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_microbatched_equals_unbatched_gradients():
    """Grad accumulation is numerically equivalent to the full batch."""
    opt = O.adamw(1e-2)
    s1 = ST.make_train_step(TINY, opt, microbatches=1)
    s4 = ST.make_train_step(TINY, opt, microbatches=4)
    params = T.init_lm(jax.random.PRNGKey(1), TINY)
    ostate = opt[0](params)
    batch = next(SyntheticLM(TINY.vocab, 32, 8))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    p1, _, m1 = jax.jit(s1)(params, ostate, batch)
    p4, _, m4 = jax.jit(s4)(params, ostate, batch)
    assert abs(float(m1["ce"]) - float(m4["ce"])) < 1e-4
    diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_quantized_optimizer_trains():
    # int8 moments add quantization noise; at toy scale just require
    # finite, decreasing loss over a slightly longer run
    losses, _, _ = _train(TINY, steps=60, quantized=True)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.05


def test_checkpoint_restart_exact(tmp_path):
    """Crash after step N, restart: parameters and step match exactly."""
    from repro.checkpoint import CheckpointManager
    opt = O.adamw(1e-3)
    step = ST.make_train_step(TINY, opt)
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    ostate = opt[0](params)
    data = SyntheticLM(TINY.vocab, 32, 8)
    jstep = jax.jit(step)
    mgr = CheckpointManager(tmp_path / "ckpt", async_save=False)
    for i in range(5):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, ostate, _ = jstep(params, ostate, b)
    mgr.save(5, {"params": params}, extra={"data_offset": 5})
    for i in range(3):   # continue to step 8 (the "lost" work)
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, ostate, _ = jstep(params, ostate, b)

    # restart: restore step-5 state + data offset, replay to step 8
    restored, manifest = mgr.restore({"params": params})
    assert manifest["step"] == 5
    data2 = SyntheticLM(TINY.vocab, 32, 8).skip(manifest["data_offset"])
    p2 = restored["params"]
    # note: optimizer state not saved here — replay only checks data path
    b_next = next(data2)
    b_orig = next(SyntheticLM(TINY.vocab, 32, 8).skip(5))
    assert np.array_equal(b_next["tokens"], b_orig["tokens"])


def test_decode_matches_forward_all_families():
    for name in ("qwen3-8b", "mamba2-780m", "zamba2-2.7b",
                 "deepseek-v3-671b", "whisper-medium"):
        arch = reduce_for_smoke(ARCHS[name])
        params = T.init_lm(jax.random.PRNGKey(2), arch)
        B, S = 2, 12
        toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0,
                                  arch.vocab)
        fe = None
        if arch.frontend == "vision":
            fe = jnp.ones((B, arch.n_img_tokens, arch.d_model))
        elif arch.frontend == "audio":
            fe = jnp.ones((B, arch.encoder.seq_len, arch.d_model))
        full = T.lm_apply(params, arch, toks, frontend=fe)
        cache = T.init_cache(arch, B, 32, jnp.float32)
        pre = T.lm_apply(params, arch, toks[:, :S], cache=cache, frontend=fe)
        dec = T.lm_apply(params, arch, toks[:, S:], cache=pre.cache)
        a, b = np.asarray(full.logits[:, S]), np.asarray(dec.logits[:, 0])
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert rel < 5e-3, (name, rel)
