"""Telemetry layer tests: histogram percentiles against numpy,
sliding-window expiry under a synthetic clock, Chrome trace-event schema
validation on a forced-preemption engine run, Prometheus exposition
round-trips, snapshot cadence, atomic writes, and the ServingMetrics
summary()-keys regression (the facade must keep every pre-telemetry key).
"""
import json

import jax
import numpy as np
import pytest

from repro.core.profiler import StepMonitor
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serving import (ChromeTracer, ContinuousBatchingEngine, Counter,
                           Gauge, LogHistogram, Request, ServingMetrics,
                           SlidingWindow, SnapshotWriter, Telemetry,
                           atomic_write_text, prometheus_text,
                           validate_chrome_trace)
from repro.serving.export import parse_prometheus_text
from repro.serving.telemetry import quantile
from serving_fixtures import load_goldens, scenario_requests

_PARAMS_CACHE: dict[str, dict] = {}


def _params_for(arch):
    if arch.name not in _PARAMS_CACHE:
        _PARAMS_CACHE[arch.name] = T.init_lm(jax.random.PRNGKey(0), arch)
    return _PARAMS_CACHE[arch.name]


# ---------------------------------------------------------------------------
# exact quantiles (the TTFT/TPOT path) vs numpy
# ---------------------------------------------------------------------------

def test_quantile_matches_numpy_linear_interpolation():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 10, 97):
        xs = rng.exponential(1.0, size=n).tolist()
        for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
            assert quantile(xs, q) == pytest.approx(
                float(np.quantile(xs, q)), rel=1e-12), (n, q)


def test_quantile_empty_is_none_not_nan():
    assert quantile([], 0.5) is None


# ---------------------------------------------------------------------------
# counters / gauges
# ---------------------------------------------------------------------------

def test_counter_and_gauge_semantics():
    c = Counter()
    assert c.value == 0
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):           # counters only go up
        c.inc(-1)
    g = Gauge()
    assert g.value is None                    # unset is "no data", not 0
    g.set(2.5)
    assert g.value == 2.5
    g.set(None)                               # explicit reset to "no data"
    assert g.value is None


# ---------------------------------------------------------------------------
# log-bucketed histograms
# ---------------------------------------------------------------------------

def test_histogram_percentiles_within_bucket_error_of_numpy():
    """p50/p95/p99 from the log-bucketed histogram agree with exact numpy
    quantiles to within the geometric bucket's relative error."""
    rng = np.random.default_rng(1)
    growth = 1.1
    for xs in (rng.lognormal(-4.0, 1.0, size=5000),
               rng.exponential(0.01, size=5000),
               np.full(100, 0.125)):
        h = LogHistogram(growth=growth)
        for x in xs:
            h.record(float(x))
        for q in (0.5, 0.95, 0.99):
            exact = float(np.quantile(xs, q))
            got = h.percentile(q)
            assert got == pytest.approx(exact, rel=growth - 1 + 0.01), q


def test_histogram_exact_stats_and_bitforbit_mean():
    """count/total/min/max are exact, and the mean is bit-for-bit what the
    old unbounded-list implementation computed (same accumulation order)."""
    xs = [0.3, 0.001, 7.5, 0.3, 2.25e-5, 0.9999]
    h = LogHistogram()
    for x in xs:
        h.record(x)
    assert h.count == len(xs)
    assert h.vmin == min(xs) and h.vmax == max(xs)
    assert h.mean == sum(xs) / len(xs)        # exact equality, not approx
    assert h.total == sum(xs)
    s = h.summary()
    assert s["count"] == len(xs) and s["mean"] == sum(xs) / len(xs)
    assert set(s) == {"count", "mean", "min", "max", "p50", "p95", "p99"}


def test_histogram_empty_and_edge_values():
    h = LogHistogram()
    assert h.count == 0 and h.mean is None and h.percentile(0.5) is None
    h.record(0.0)                             # underflow bucket, exact stats
    h.record(1e9)                             # overflow bucket
    assert h.count == 2 and h.vmin == 0.0 and h.vmax == 1e9
    # percentiles stay clamped to observed values even from the open-ended
    # overflow / underflow buckets
    assert 0.0 <= h.percentile(0.01) <= 1e9
    assert 0.0 <= h.percentile(0.99) <= 1e9


def test_histogram_fixed_memory():
    """The whole point of the refactor: recording a million samples must
    not grow storage (the old *_samples lists grew one entry per step)."""
    h = LogHistogram()
    n_buckets = len(h.counts)
    rng = np.random.default_rng(2)
    for x in rng.exponential(0.05, size=100_000):
        h.record(float(x))
    assert len(h.counts) == n_buckets
    assert h.count == 100_000


# ---------------------------------------------------------------------------
# sliding windows under a synthetic clock
# ---------------------------------------------------------------------------

def test_sliding_window_expiry_synthetic_clock():
    w = SlidingWindow(window_s=10.0)
    for t in range(8):                        # t = 0..7, one value each
        w.record(float(t), float(t))
    assert w.count(7.0) == 8
    assert w.total(7.0) == sum(range(8))
    # advance "now": entries at or before now - 10 fall out
    assert w.count(10.5) == 7                 # t=0 expired (0 <= 0.5)
    assert w.count(16.5) == 1                 # only t=7 left
    assert w.values(16.5) == [7.0]
    assert w.mean(16.5) == 7.0
    assert w.count(100.0) == 0
    assert w.mean(100.0) is None and w.vmax(100.0) is None
    assert w.rate(100.0) == 0.0


def test_sliding_window_rate_and_quantile():
    w = SlidingWindow(window_s=5.0)
    for t in (0.0, 1.0, 2.0, 3.0):
        w.record(t, 10.0 * t)
    assert w.rate(3.0) == pytest.approx(4 / 5.0)
    assert w.quantile(0.5, now=3.0) == pytest.approx(
        float(np.quantile([0.0, 10.0, 20.0, 30.0], 0.5)))


def test_telemetry_registry_snapshot():
    t = Telemetry(window_s=4.0)
    t.counter("hits").inc(3)
    t.gauge("ema").set(0.25)
    t.histogram("lat").record(0.5)
    t.window("arr").record(1.0, 7.0)
    snap = t.snapshot(now=2.0)
    assert snap["counters"]["hits"] == 3
    assert snap["gauges"]["ema"] == 0.25
    assert snap["histograms"]["lat"]["count"] == 1
    assert snap["windows"]["arr"]["count"] == 1
    # re-registering a name returns the SAME primitive (facade + exporters
    # may both ask for it), never a fresh zeroed one
    assert t.counter("hits") is t.counters["hits"]
    assert t.counter("hits").value == 3


# ---------------------------------------------------------------------------
# tracing: schema validation on a forced-preemption engine run
# ---------------------------------------------------------------------------

def _synthetic_clock():
    state = {"t": 0.0}

    def clk():
        state["t"] += 1e-3
        return state["t"]
    return clk


def test_trace_schema_valid_on_forced_preemption_run(tmp_path):
    """Drive the tiny/preempt scenario with an 8-block pool (forces
    recompute-preemption) and a tracer attached: the emitted Chrome trace
    must validate (required keys, monotonic ts, balanced B/E, closed async
    request spans), carry preempt+resume annotations, and the goldens must
    still hold with tracing on."""
    arch, reqs, slots, max_len = scenario_requests("tiny/preempt")
    mesh = make_host_mesh()
    tracer = ChromeTracer()
    eng = ContinuousBatchingEngine(
        arch, _params_for(arch), mesh, slots=slots, max_len=max_len,
        block_size=4, num_blocks=8, prefill_chunk=8,
        clock=_synthetic_clock(), tracer=tracer)
    outs = eng.generate([Request(id=rid, prompt=p.copy(), max_new_tokens=mn)
                         for rid, p, mn in reqs])
    assert eng.metrics.preemptions > 0        # the scenario forces it
    assert {o.request_id: o.token_ids for o in outs} == \
        load_goldens("tiny/preempt")          # tracing changes no tokens

    trace = tracer.write(tmp_path / "trace.json")
    on_disk = json.loads((tmp_path / "trace.json").read_text())
    assert on_disk == trace
    stats = validate_chrome_trace(trace)
    assert stats["n_request_spans"] == len(reqs)
    assert stats["n_phase_spans"] > 0
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"admission", "prefill", "decode", "sample_sync",
            "preempt", "resume", "first_token", "admitted"} <= names
    # every phase track got a thread_name metadata record
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"]
    assert any(e["name"] == "thread_name" for e in meta)
    # the per-step counters rode along
    assert any(e["ph"] == "C" and e["name"] == "queue_depth"
               for e in trace["traceEvents"])
    # phase histograms saw the same phases the tracer did
    assert eng.metrics.phase["decode"].count > 0
    assert eng.metrics.phase["sample_sync"].count > 0


def test_trace_validator_rejects_malformed_traces():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({})
    base = {"pid": 0, "tid": 1, "ts": 0.0}
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace({"traceEvents": [{"ph": "B", "ts": 0.0}]})
    with pytest.raises(ValueError, match="unbalanced"):
        validate_chrome_trace(
            {"traceEvents": [dict(base, name="p", ph="B")]})
    with pytest.raises(ValueError, match="time-sorted"):
        validate_chrome_trace({"traceEvents": [
            dict(base, name="p", ph="B", ts=5.0),
            dict(base, name="p", ph="E", ts=1.0)]})
    with pytest.raises(ValueError, match="no open B"):
        validate_chrome_trace({"traceEvents": [
            dict(base, name="p", ph="E")]})
    # E closing the wrong B
    with pytest.raises(ValueError, match="closes"):
        validate_chrome_trace({"traceEvents": [
            dict(base, name="p", ph="B"),
            dict(base, name="q", ph="E", ts=1.0)]})
    # async end without begin
    with pytest.raises(ValueError, match="no open begin"):
        validate_chrome_trace({"traceEvents": [
            dict(base, name="r", ph="e", cat="request", id=1)]})


def test_tracer_disabled_is_free_on_the_engine():
    """tracer=None must add zero per-step objects: the engine only touches
    the tracer behind `is not None` checks."""
    arch, reqs, slots, max_len = scenario_requests("tiny/base")
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(arch, _params_for(arch), mesh,
                                   slots=slots, max_len=max_len,
                                   clock=_synthetic_clock())
    assert eng.tracer is None
    outs = eng.generate([Request(id=rid, prompt=p.copy(), max_new_tokens=mn)
                         for rid, p, mn in reqs])
    assert {o.request_id: o.token_ids for o in outs} == \
        load_goldens("tiny/base")


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_atomic_write_overwrites_and_leaves_no_temp(tmp_path):
    p = tmp_path / "out.json"
    atomic_write_text(p, "first\n")
    atomic_write_text(p, "second\n")
    assert p.read_text() == "second\n"
    assert [f.name for f in tmp_path.iterdir()] == ["out.json"]  # no *.tmp


def test_metrics_write_is_atomic(tmp_path):
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.1)
    m.on_finish(0, n_tokens=2, now=0.5)
    path = tmp_path / "metrics.json"
    m.write(str(path), engine="test")
    rep = json.loads(path.read_text())
    assert rep["engine"] == "test" and rep["completed"] == 1
    assert [f.name for f in tmp_path.iterdir()] == ["metrics.json"]


def test_prometheus_text_round_trip():
    m = ServingMetrics()
    m.on_submit(0, now=0.0, prompt_len=8)
    m.on_first_token(0, now=0.2)
    m.on_step(queue_depth=3, busy_slots=1, slots=2, block_utilization=0.5,
              now=0.3)
    m.on_phase("decode", 0.01)
    m.on_step_time(0.02, ema=0.02, drift=0.0)
    m.on_finish(0, n_tokens=4, now=0.5, reason="length")
    text = prometheus_text(m)
    parsed = parse_prometheus_text(text)      # raises on any malformed line
    assert parsed["repro_serving_requests_completed_total"][0][1] == "1.0"
    assert parsed["repro_serving_tokens_generated_total"][0][1] == "4.0"
    # histogram series: cumulative buckets end at +Inf == _count
    buckets = parsed["repro_serving_queue_depth_bucket"]
    assert buckets[-1][0]["le"] == "+Inf" and buckets[-1][1] == "1"
    assert parsed["repro_serving_queue_depth_count"][0][1] == "1"
    cums = [int(v) for lab, v in buckets]
    assert cums == sorted(cums)               # cumulative => nondecreasing
    # a fresh registry renders gauges-without-data as NaN, still parseable
    empty = prometheus_text(ServingMetrics())
    parsed_empty = parse_prometheus_text(empty)
    assert parsed_empty["repro_serving_step_time_ema_s"][0][1] == "NaN"


def test_prometheus_labels():
    m = ServingMetrics()
    m.on_step(1, 1, 2, now=0.0)
    text = prometheus_text(m, labels={"arch": "tiny-serve"})
    parsed = parse_prometheus_text(text)
    labels, _ = parsed["repro_serving_engine_steps_total"][0]
    assert labels == {"arch": "tiny-serve"}


def test_snapshot_writer_cadence_and_atomicity(tmp_path):
    m = ServingMetrics()
    path = tmp_path / "snap.jsonl"
    w = SnapshotWriter(path, every_s=1.0)
    assert w.maybe_write(m, 0.0)              # first call always writes
    assert not w.maybe_write(m, 0.5)          # cadence not elapsed
    assert not w.maybe_write(m, 0.99)
    assert w.maybe_write(m, 1.0)
    assert w.maybe_write(m, 5.0)
    assert w.n_snapshots == 3
    lines = path.read_text().splitlines()
    assert len(lines) == 3
    for line in lines:                        # every line parses standalone
        snap = json.loads(line)
        assert "window" in snap and "engine_steps" in snap
    with pytest.raises(ValueError):
        SnapshotWriter(path, every_s=0.0)


# ---------------------------------------------------------------------------
# StepMonitor drift gauge through the facade
# ---------------------------------------------------------------------------

def test_step_monitor_drift_exported_as_telemetry():
    mon = StepMonitor(alpha=1.0, drift_threshold=0.25, min_steps=2)
    m = ServingMetrics()
    for _ in range(2):                        # establish the baseline
        trig = mon.update(0.010)
        m.on_step_time(0.010, ema=mon.ema, drift=mon.drift_fraction(),
                       triggered=trig)
    sig = m.window_signals(now=0.0)
    assert sig["step_time_ema_s"] == pytest.approx(0.010)
    assert sig["step_time_drift"] == pytest.approx(0.0)
    assert sig["replan_triggers"] == 0
    trig = mon.update(0.020)                  # 2x slower: drift trips
    assert trig
    m.on_step_time(0.020, ema=mon.ema, drift=mon.drift_fraction(),
                   triggered=trig)
    sig = m.window_signals(now=0.0)
    assert sig["replan_triggers"] == 1
    assert m.step_time.count == 3


def test_engine_runs_step_monitor_and_phase_histograms():
    arch, reqs, slots, max_len = scenario_requests("tiny/base")
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(arch, _params_for(arch), mesh,
                                   slots=slots, max_len=max_len,
                                   clock=_synthetic_clock())
    eng.generate([Request(id=rid, prompt=p.copy(), max_new_tokens=mn)
                  for rid, p, mn in reqs])
    s = eng.metrics.summary()
    assert eng.step_monitor.steps == s["engine_steps"] > 0
    assert s["step_time"]["count"] == s["engine_steps"]
    assert s["window"]["step_time_ema_s"] is not None
    assert s["phases"]["prefill"]["count"] == s["prefill_chunks"]
    assert s["phases"]["decode"]["count"] == s["decode_steps"]
    assert s["phases"]["sample_sync"]["count"] == s["decode_steps"]
    # live scheduler/cache references surfaced through the facade
    assert s["scheduler"]["admitted"] >= len(reqs)
    assert s["cache"]["num_blocks"] == eng.cache.cfg.num_blocks
    assert s["cache"]["pool_bytes"] > 0


# ---------------------------------------------------------------------------
# summary() regression: the facade keeps every pre-telemetry key
# ---------------------------------------------------------------------------

PRE_TELEMETRY_KEYS = {
    "requests", "completed", "in_flight", "total_tokens", "tokens_per_sec",
    "ttft_mean_s", "ttft_max_s", "tpot_mean_s", "queue_depth_mean",
    "queue_depth_max", "slot_occupancy_mean", "block_utilization_mean",
    "block_utilization_max", "prefix_hit_rate", "preemptions",
    "engine_steps", "prefill_chunks", "decode_steps",
}

NEW_KEYS = {
    "ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
    "tpot_p50_s", "tpot_p95_s", "tpot_p99_s",
    "finish_reasons", "phases", "step_time", "window",
}


def test_summary_keeps_every_pre_telemetry_key():
    m = ServingMetrics()
    s = m.summary()
    missing = (PRE_TELEMETRY_KEYS | NEW_KEYS) - set(s)
    assert not missing, missing
    # populated run: the old keys still mean what they meant
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.5)
    m.on_step(queue_depth=2, busy_slots=1, slots=2, block_utilization=0.25,
              now=0.6)
    m.on_step(queue_depth=4, busy_slots=2, slots=2, block_utilization=0.75,
              now=0.7)
    m.on_finish(0, n_tokens=3, now=1.5, reason="length")
    s = m.summary()
    assert s["tokens_per_sec"] == pytest.approx(2.0)      # 3 tok / 1.5 s
    assert s["ttft_mean_s"] == pytest.approx(0.5)
    assert s["ttft_p50_s"] == pytest.approx(0.5)
    assert s["queue_depth_mean"] == pytest.approx(3.0)    # exact: (2+4)/2
    assert s["queue_depth_max"] == 4
    assert s["slot_occupancy_mean"] == pytest.approx(0.75)
    assert s["block_utilization_max"] == pytest.approx(0.75)
    assert s["finish_reasons"] == {"length": 1}
    assert json.loads(m.to_json())["completed"] == 1      # stays JSON-able


def test_window_signals_vector_under_synthetic_clock():
    """The adaptive scheduler's signal vector: recent-window rates and
    mixes, deterministic under a synthetic clock, with old entries expiring
    out of every signal."""
    m = ServingMetrics(window_s=10.0)
    m.on_submit(0, now=0.0, prompt_len=100)
    m.on_submit(1, now=1.0, prompt_len=200)
    m.on_prefix_match(50, 100, now=1.5)
    m.on_step(queue_depth=2, busy_slots=2, slots=2, block_utilization=0.5,
              now=2.0)
    m.on_finish(0, n_tokens=20, now=3.0)
    sig = m.window_signals()                  # now defaults to last stamp
    assert sig["t"] == 3.0
    assert sig["arrival_rate_hz"] == pytest.approx(2 / 10.0)
    assert sig["prompt_len_mean"] == pytest.approx(150.0)
    assert sig["prompt_len_max"] == 200.0
    assert sig["prefix_hit_rate"] == pytest.approx(0.5)
    assert sig["block_pressure_mean"] == pytest.approx(0.5)
    assert sig["tokens_per_sec"] == pytest.approx(20 / 10.0)
    # 30 seconds later everything has expired: no data, not zeros
    sig = m.window_signals(now=33.0)
    assert sig["arrival_rate_hz"] == 0.0
    assert sig["prompt_len_mean"] is None
    assert sig["prefix_hit_rate"] is None
    assert sig["block_pressure_mean"] is None
