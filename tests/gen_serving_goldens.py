"""Golden generator for the serving parity suite.

Run ONCE against the pre-shim wave Server (commit 17a83e0's
runtime/server.py — batched full-cache prefill + lock-step decode waves) to
freeze its greedy outputs for every scenario in serving_fixtures.SCENARIOS:

    PYTHONPATH=src:tests python tests/gen_serving_goldens.py

The continuous engine's parity tests then compare against the pinned JSON,
NOT against a live wave run — after the wave Server became a shim over the
engine, a live comparison would be circular.  Do not regenerate this file
from a post-shim checkout (it would capture the engine's own outputs and
silently erase the baseline); the checked-in goldens_serving.json is the
falsifiable artifact.

The goldens are greedy-only and stay that way under the v2 generation API:
the default ``SamplingParams()`` is temperature-0 argmax, so every parity
test exercises the new submit/SamplingParams/RequestOutput surface against
these same sequences.  Stochastic decode (temperature > 0) is deliberately
NOT pinned here — the wave Server never sampled, so no baseline exists;
its contract is determinism (bit-identical reruns, invariance under forced
recompute-preemption), pinned by the sampling tests in test_serving.py.

Where the no-cache forward has identical semantics (attention-only, SSM,
hybrid, shared-block and MLA configs), the script also greedy-decodes each
request with plain full-context ``lm_apply`` calls and asserts the wave
Server matched that independent oracle.  (Cross-attn / enc-dec configs are
excluded from the oracle: without a cache the cross-attention falls back to
self-attention, which is not what serving-with-zero-cross-K/V computes.)
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT / "tests"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.runtime.server import Request as WaveRequest, Server
from serving_fixtures import (GOLDENS_PATH, SCENARIOS, ARCH_BY_KEY,
                              scenario_requests)

# configs whose cache-free forward equals the serving computation (oracle)
ORACLE_OK = {"tiny", "ssm", "hybrid", "shared", "mla"}


def reference_decode(params, arch, prompt, n_new: int) -> list[int]:
    ctx = list(int(t) for t in prompt)
    out = []
    for _ in range(n_new):
        logits = T.lm_apply(params, arch,
                            jnp.asarray([ctx], jnp.int32)).logits
        nxt = int(jnp.argmax(logits[0, -1, : arch.vocab]))
        out.append(nxt)
        ctx.append(nxt)
    return out


def main():
    mesh = make_host_mesh()
    params_cache = {}
    scenarios_out = {}
    for name in SCENARIOS:
        arch, reqs, slots, max_len = scenario_requests(name)
        if arch.name not in params_cache:
            params_cache[arch.name] = T.init_lm(jax.random.PRNGKey(0), arch)
        params = params_cache[arch.name]

        srv = Server(arch, params, mesh, slots=slots, max_len=max_len)
        if hasattr(srv, "engine"):
            sys.exit(
                "REFUSING to regenerate goldens: this checkout's Server is "
                "the post-shim delegate to ContinuousBatchingEngine, so the "
                "output would be the engine's own tokens and every parity "
                "test would become circular.  The checked-in "
                "goldens_serving.json (captured at 17a83e0) is the "
                "baseline; do not overwrite it.")
        for rid, prompt, max_new in reqs:
            srv.submit(WaveRequest(id=rid, prompt=prompt.copy(),
                                   max_new_tokens=max_new))
        srv.run_until_drained()
        wave = {r.id: list(map(int, r.out_tokens)) for r in srv.completed}

        key = SCENARIOS[name]["arch"]
        if key in ORACLE_OK:
            for rid, prompt, max_new in reqs:
                n_new = len(wave[rid])
                ref = reference_decode(params, arch, prompt, n_new)
                assert wave[rid] == ref, (
                    f"{name} req {rid}: wave {wave[rid]} != oracle {ref}")
        scenarios_out[name] = {str(k): v for k, v in sorted(wave.items())}
        print(f"{name}: {[len(v) for v in scenarios_out[name].values()]} "
              f"tokens per request")

    data = {
        "_meta": {
            "source": "pre-shim wave Server (runtime/server.py @ 17a83e0): "
                      "batched full-cache prefill + lock-step decode waves",
            "params": "T.init_lm(jax.random.PRNGKey(0), arch), float32",
            "oracle_checked": sorted(ORACLE_OK),
        },
        "scenarios": scenarios_out,
    }
    with open(GOLDENS_PATH, "w") as f:
        json.dump(data, f, indent=1)
        f.write("\n")
    print(f"-> {GOLDENS_PATH}")


if __name__ == "__main__":
    main()
