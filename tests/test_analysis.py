"""reprolint rule corpus + paged-cache sanitizer mutation tests.

Part 1 drives ``Linter.lint_sources`` with a minimal good/bad snippet per
rule: every rule must fire on its bad fixture and stay silent on the good
one (the false-positive half is as load-bearing as the detection half —
a noisy gate gets disabled).  Part 2 runs the real engine under the
sanitizer (clean under preemption + prefix sharing), then injects each
bug class the sanitizer exists to catch — leak, double-free, stale
incref, refcount/table mismatch, null-block write — and asserts the
report fires *with the allocation site* of the offending blocks.
Finally, the merged tree itself must lint clean: the CI gate in
executable form.
"""
from __future__ import annotations

import pathlib

import numpy as np
import pytest

from repro.analysis.lint import Linter, ModuleInfo, main as lint_main
from repro.analysis.sanitizer import CacheSanitizer, SanitizerError

REPO = pathlib.Path(__file__).resolve().parents[1]


def findings_for(path, src, rule=None):
    select = {rule} if rule else None
    return Linter(select=select).lint_sources({path: src})


def rules_hit(path, src, rule=None):
    return {f.rule for f in findings_for(path, src, rule)}


# ---------------------------------------------------------------------------
# rule corpus: one bad + one good snippet per rule
# ---------------------------------------------------------------------------

def test_jit_host_sync_bad_builder():
    src = """
def make_paged_decode_step(arch):
    def step(params, pools, tok):
        v = tok.sum()
        print(v)
        return v.item()
    return step
"""
    fs = findings_for("src/repro/runtime/bad.py", src, "jit-host-sync")
    assert len(fs) == 2
    assert any("print()" in f.message for f in fs)
    assert any(".item()" in f.message for f in fs)


def test_jit_host_sync_transitive_callee():
    """np.asarray in a helper the jitted step calls — the closure matters,
    not just the builder body."""
    src = """
import numpy as np

def helper(x):
    return np.asarray(x)

def make_decode_step(arch):
    def step(params, tok):
        return helper(tok)
    return step
"""
    fs = findings_for("src/repro/runtime/bad.py", src, "jit-host-sync")
    assert len(fs) == 1
    assert "numpy.asarray" in fs[0].message
    assert "reached from a jitted scope" in fs[0].message


def test_jit_host_sync_good():
    src = """
import jax.numpy as jnp

def make_decode_step(arch):
    def step(params, tok):
        return jnp.sum(tok)
    return step
"""
    assert not findings_for("src/repro/runtime/ok.py", src, "jit-host-sync")


def test_jit_recompile_hazard_bad_vs_shape_branch():
    """Branching on a traced value fires; branching on .shape (static
    under jit) must not — kernels/ops.py lives on that distinction."""
    src = """
def make_step(arch):
    def step(params, x):
        B, S = x.shape
        if S > 4:                 # static: fine
            x = x * 2
        if x.sum() > 0:           # traced: recompile/Concretization
            return x
        return -x
    return step
"""
    fs = findings_for("src/repro/runtime/bad.py", src,
                      "jit-recompile-hazard")
    assert len(fs) == 1
    assert fs[0].line == 7


def test_jit_recompile_hazard_respects_static_argnames():
    src = """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("causal",))
def f(q, causal):
    if causal:
        return q
    return -q
"""
    assert not findings_for("src/repro/kernels/ok.py", src,
                            "jit-recompile-hazard")


def test_jit_recompile_hazard_closure_params_are_static():
    """A make_* builder's own parameters are trace-time constants — the
    inner function may branch on them freely (make_train_step's
    microbatches switch)."""
    src = """
def make_train_step(arch, microbatches):
    def train_step(params, batch):
        if microbatches == 1:
            return batch
        return batch * 2
    return train_step
"""
    assert not findings_for("src/repro/runtime/ok.py", src,
                            "jit-recompile-hazard")


def test_prng_discipline_bad():
    src = """
import jax

def bad(seed, pos, vocab):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return jax.random.gumbel(k1, (vocab,))
"""
    fs = findings_for("src/repro/serving/bad.py", src, "prng-discipline")
    assert len(fs) == 2                       # the split AND the raw-key draw
    assert any("split" in f.message for f in fs)
    assert any("gumbel" in f.message for f in fs)


def test_prng_discipline_good_fold_in():
    src = """
import jax

def draw(seed, pos, vocab):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
    return jax.random.gumbel(key, (vocab,))
"""
    assert not findings_for("src/repro/serving/ok.py", src,
                            "prng-discipline")


def test_prng_discipline_scoped_to_serving():
    """split outside serving/ (e.g. training init) is legitimate."""
    src = """
import jax

def init(seed):
    return jax.random.split(jax.random.PRNGKey(seed))
"""
    assert not findings_for("src/repro/core/ok.py", src, "prng-discipline")


def test_refcount_pairing_leak_on_early_return():
    src = """
def leak(alloc, table, n):
    blocks = alloc.alloc(n)
    if blocks is None:
        return False
    if n > 4:
        return True
    table.extend(blocks)
    return True
"""
    fs = findings_for("src/repro/serving/bad.py", src, "refcount-pairing")
    assert len(fs) == 1
    assert "allocated line 3" in fs[0].message
    assert fs[0].line == 7                    # the leaking return


def test_refcount_pairing_exception_edge():
    """The ISSUE's exception-edge case: a call that can raise between
    alloc and ownership transfer, with no try protecting the blocks."""
    src = """
def edge(alloc, risky, n):
    blocks = alloc.alloc(n)
    if blocks is None:
        return None
    risky(n)
    return blocks
"""
    fs = findings_for("src/repro/serving/bad.py", src, "refcount-pairing")
    assert len(fs) == 1
    assert "exception edge" in fs[0].message


def test_refcount_pairing_discarded_result():
    src = """
def drop(alloc):
    alloc.alloc(1)
"""
    fs = findings_for("src/repro/serving/bad.py", src, "refcount-pairing")
    assert len(fs) == 1
    assert "discarded" in fs[0].message


def test_refcount_pairing_good_patterns():
    """The three sanctioned shapes: try/finally, immediate store (the
    real reserve()), and a decref loop."""
    src = """
def ok_finally(alloc, risky, n):
    blocks = alloc.alloc(n)
    if blocks is None:
        return None
    try:
        risky(n)
    finally:
        alloc.free(blocks)
    return True

def ok_store(self, rid, n):
    got = self.allocator.alloc(n)
    if got is None:
        return False
    self.tables.setdefault(rid, []).extend(got)
    return True

def ok_loop(alloc, n):
    blocks = alloc.alloc(n)
    if blocks is None:
        return
    for b in blocks:
        alloc.decref(b)
"""
    assert not findings_for("src/repro/serving/ok.py", src,
                            "refcount-pairing")


def test_atomic_write_bad():
    src = """
import json
import pathlib

def dump(path, data):
    with open(path, "w") as f:
        json.dump(data, f)
    pathlib.Path(path).write_text("x")
"""
    fs = findings_for("src/repro/serving/bad.py", src, "atomic-write")
    assert len(fs) == 2
    assert all("atomic_write_text" in f.message for f in fs)


def test_atomic_write_reads_are_fine():
    src = """
def load(path):
    with open(path) as f:
        return f.read()
"""
    assert not findings_for("src/repro/serving/ok.py", src, "atomic-write")


def test_clock_injection_bad():
    src = """
import time

def stamp():
    return time.time()
"""
    fs = findings_for("src/repro/serving/bad.py", src, "clock-injection")
    assert len(fs) == 1
    assert "time.time" in fs[0].message


def test_clock_injection_scoped_to_serving():
    src = """
import time

def stamp():
    return time.perf_counter()
"""
    assert not findings_for("src/repro/benchmarks_like/ok.py", src,
                            "clock-injection")


def test_inline_pragma_suppresses_exactly_that_rule():
    src = """
import time

def stamp():
    return time.perf_counter()  # reprolint: disable=clock-injection
"""
    assert not findings_for("src/repro/serving/ok.py", src)
    # a pragma for a different rule must NOT suppress
    src2 = src.replace("clock-injection", "atomic-write")
    assert rules_hit("src/repro/serving/bad.py", src2) == {"clock-injection"}


def test_module_info_serving_scope_detection():
    assert ModuleInfo("src/repro/serving/x.py", "").in_serving
    assert not ModuleInfo("src/repro/runtime/x.py", "").in_serving


def test_serving_scope_covers_cluster_subpackage():
    """The cluster subsystem sits under serving/, so every serving-scoped
    rule applies to it automatically — no per-rule path lists to keep in
    sync as the package grows."""
    assert ModuleInfo("src/repro/serving/cluster/router.py", "").in_serving
    assert ModuleInfo("src/repro/serving/cluster/worker.py", "").in_serving


def test_cluster_paths_hit_serving_scoped_rules():
    clocky = "import time\n\ndef f():\n    return time.monotonic()\n"
    assert rules_hit("src/repro/serving/cluster/router.py", clocky,
                     "clock-injection") == {"clock-injection"}
    asserty = "def f(x):\n    assert x, 'no'\n    return x\n"
    assert rules_hit("src/repro/serving/cluster/frontend.py", asserty,
                     "no-bare-assert") == {"no-bare-assert"}
    writey = ("def dump(path, text):\n"
              "    with open(path, 'w') as f:\n"
              "        f.write(text)\n")
    assert rules_hit("src/repro/serving/cluster/worker.py", writey,
                     "atomic-write") == {"atomic-write"}


def test_cluster_clock_pragma_suppresses_default_arg_line():
    """The Router takes ``clock=time.monotonic`` as an injectable default —
    the sanctioned pattern — and suppresses the banned-name finding with
    the per-line pragma, exactly as serving/metrics.py does."""
    src = ("import time\n\n\n"
           "class Router:\n"
           "    def __init__(self, handles, *,\n"
           "                 clock=time.monotonic):"
           "  # reprolint: disable=clock-injection\n"
           "        self._clock = clock\n")
    assert not findings_for("src/repro/serving/cluster/router.py", src,
                            "clock-injection")


def test_no_bare_assert_bad():
    src = """
def reserve(self, n):
    assert n >= 0, "negative reservation"
    return n
"""
    fs = findings_for("src/repro/serving/bad.py", src, "no-bare-assert")
    assert len(fs) == 1
    assert "python -O" in fs[0].message


def test_no_bare_assert_scoped_to_serving():
    src = "def f(x):\n    assert x\n    return x\n"
    assert not findings_for("src/repro/analysis/ok.py", src,
                            "no-bare-assert")
    assert not findings_for("tests/test_ok.py", src, "no-bare-assert")


def test_no_bare_assert_explicit_raise_is_clean():
    src = """
def reserve(self, n):
    if n < 0:
        raise ValueError("negative reservation")
    return n
"""
    assert not findings_for("src/repro/serving/ok.py", src,
                            "no-bare-assert")


# ---------------------------------------------------------------------------
# the gate itself: merged tree lints clean; CLI exit codes
# ---------------------------------------------------------------------------

def test_merged_tree_is_clean():
    """The CI gate in test form: the full lint target — src/repro plus
    the benchmarks/ and examples/ trees — has zero unsuppressed
    findings.  (benchmarks/examples joined the target when their serving
    drivers started holding BlockAllocator results and timestamps of
    their own; this test pins the wider scope so CI and local runs
    cannot silently diverge.)"""
    targets = [REPO / "src" / "repro", REPO / "benchmarks",
               REPO / "examples"]
    findings = Linter().lint_paths([str(t) for t in targets if t.exists()])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "serving" / "bad.py"
    bad.parent.mkdir()
    bad.write_text("import time\n\ndef f():\n    return time.time()\n")
    assert lint_main([str(bad)]) == 1
    assert "clock-injection" in capsys.readouterr().out
    good = tmp_path / "serving" / "ok.py"
    good.write_text("def f():\n    return 1\n")
    assert lint_main([str(good)]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_lists_all_seven_rules(capsys):
    assert lint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("jit-host-sync", "jit-recompile-hazard", "prng-discipline",
                 "refcount-pairing", "atomic-write", "clock-injection",
                 "no-bare-assert"):
        assert rule in out


# ---------------------------------------------------------------------------
# runtime sanitizer: clean runs, then one injection per bug class
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.launch.mesh import make_host_mesh        # noqa: E402
from repro.models import transformer as T           # noqa: E402
from repro.serving import (ContinuousBatchingEngine,  # noqa: E402
                           Request, SamplingParams)
from repro.serving.paged_cache import (NULL_BLOCK,  # noqa: E402
                                       PagedCacheConfig, PagedKVCache)
from serving_fixtures import TINY                   # noqa: E402

_PARAMS = {}


def _params():
    if "p" not in _PARAMS:
        _PARAMS["p"] = T.init_lm(jax.random.PRNGKey(0), TINY)
    return _PARAMS["p"]


def _engine(**kw):
    kw.setdefault("sanitizer", CacheSanitizer())
    return ContinuousBatchingEngine(
        TINY, _params(), make_host_mesh(), slots=kw.pop("slots", 2),
        max_len=kw.pop("max_len", 64), block_size=kw.pop("block_size", 4),
        prefill_chunk=kw.pop("prefill_chunk", 8), **kw)


def _reqs(n, plen=10, max_new=8, shared=0):
    common = np.arange(1, shared + 1, dtype=np.int32)
    return [Request(id=i,
                    prompt=np.concatenate(
                        [common, np.arange(40 + 5 * i, 40 + 5 * i + plen,
                                           dtype=np.int32) % 250 + 1]),
                    max_new_tokens=max_new,
                    sampling=SamplingParams(temperature=0.7, seed=i))
            for i in range(n)]


def _cache():
    return PagedKVCache(TINY, PagedCacheConfig(
        block_size=4, num_blocks=10, max_blocks_per_seq=8))


def test_sanitizer_clean_under_preemption_and_sharing():
    """The hardest legitimate path — prefix sharing, LRU retirement and
    recompute-preemption under a tight pool — must produce ZERO reports:
    a sanitizer that cries wolf on correct code is worse than none."""
    eng = _engine(slots=3, num_blocks=13, share_prefix=True)
    outs = eng.generate(_reqs(8, shared=12, max_new=10))
    assert len(outs) == 8
    assert eng.metrics.preemptions > 0, \
        "pool not tight enough — preemption path went unexercised"
    rep = eng.sanitizer.report()
    assert rep["violations"] == 0
    assert rep["step_checks"] > 0 and rep["allocs"] > 0


def test_sanitizer_env_var_auto_attach(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    eng = _engine(sanitizer=None)
    assert isinstance(eng.sanitizer, CacheSanitizer)
    assert eng.cache.allocator.observer is eng.sanitizer
    monkeypatch.delenv("REPRO_SANITIZE")
    assert _engine(sanitizer=None).sanitizer is None


def test_sanitizer_detects_double_free_with_sites():
    cache = _cache()
    san = CacheSanitizer().attach(cache)
    assert cache.reserve(0, 8)
    victim = cache.tables[0][0]
    cache.release(0)
    with pytest.raises(SanitizerError) as e:
        cache.allocator.decref(victim)
    msg = str(e.value)
    assert "double free" in msg
    # the report must carry backtraces: where the block was allocated
    # (the reserve above) and where it was first freed (the release)
    assert "allocated at" in msg and "previously freed at" in msg
    assert "test_analysis.py" in msg
    assert san.counters["violations"] == 1


def test_sanitizer_detects_stale_incref():
    cache = _cache()
    CacheSanitizer().attach(cache)
    assert cache.reserve(0, 8)
    stale = cache.tables[0][-1]
    cache.release(0)
    with pytest.raises(SanitizerError, match="stale reference"):
        cache.allocator.incref(stale)
    with pytest.raises(SanitizerError, match="null block"):
        cache.allocator.incref(NULL_BLOCK)


def test_sanitizer_detects_refcount_table_mismatch():
    """A reference the ground truth can't account for — e.g. an incref
    with no table or index holding the block — must be caught at the next
    step check, with the allocation site."""
    cache = _cache()
    san = CacheSanitizer().attach(cache)
    assert cache.reserve(0, 8)
    cache.allocator.incref(cache.tables[0][0])     # stranded reference
    with pytest.raises(SanitizerError) as e:
        san.check_cache()
    assert "refcount mismatch" in str(e.value)
    assert "allocated at" in str(e.value)


def test_sanitizer_detects_lost_table_reference():
    """The dual: a block dropped from a table while the allocator still
    counts its reference (the lost-ref flavor of the same class)."""
    cache = _cache()
    san = CacheSanitizer().attach(cache)
    assert cache.reserve(0, 8)
    cache.tables[0].pop()                          # ref lost, count kept
    with pytest.raises(SanitizerError, match="refcount mismatch"):
        san.check_cache()


def test_sanitizer_detects_null_block_write():
    """A slot position past its table's capacity means the next device
    write scatters into reserved block 0."""
    eng = _engine()
    eng.submit(_reqs(1)[0])
    while not any(s.busy for s in eng.slots):
        eng.step()
    slot = next(s for s in eng.slots if s.busy)
    table = eng.cache.tables[slot.req.id]
    slot.pos = len(table) * eng.cache.cfg.block_size + 1
    with pytest.raises(SanitizerError, match="null-block write"):
        eng.sanitizer.check_engine_step(eng)


def test_sanitizer_detects_leak_at_drain():
    """Blocks allocated but owned by nobody once the engine drains — the
    report names the allocation site of every leaked block."""
    eng = _engine()
    eng.generate(_reqs(2))                         # clean drain (checked)
    leaked = eng.cache.allocator.alloc(2)          # stray grant, never freed
    assert leaked is not None
    with pytest.raises(SanitizerError) as e:
        eng.sanitizer.check_drained(eng)
    msg = str(e.value)
    assert "leaked block" in msg
    assert "allocated at" in msg and "test_analysis.py" in msg


def test_sanitizer_zero_cost_when_detached(monkeypatch):
    """Production path: no observer, no sanitizer attribute cost beyond
    one None check — and crucially no behavior change.  REPRO_SANITIZE
    must be cleared: the whole suite also runs under REPRO_SANITIZE=1 in
    CI, which would auto-attach to the engine this test needs bare."""
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    eng = _engine(sanitizer=None)
    assert eng.sanitizer is None
    assert eng.cache.allocator.observer is None
    outs = eng.generate(_reqs(2))
    assert [o.request_id for o in outs] == [0, 1]
