"""Cost-model properties: the physics the solver relies on."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.components import Component
from repro.core.costmodel import CostModel, MeshShape
from repro.core.hardware import (TPU_V5E, allgather_time, alltoall_time,
                                 reducescatter_time, ring_allreduce_time)
from repro.core.strategy import Strategy


def _comp(params=1e9, flops=1e13, act=1e8, count=4, a2a=0.0):
    return Component("c", "attn", count, params=params, shared_params=False,
                     flops_fwd=flops, act_bytes=act, n_model_allreduce=2,
                     moe_a2a_bytes=a2a, kv_bytes=act)


def _cm(**kw):
    base = dict(hw=TPU_V5E, mesh=MeshShape(16, 16), mode="train",
                faithful=False)
    base.update(kw)
    return CostModel(**base)


def test_collective_time_formulas():
    assert ring_allreduce_time(1e9, 1, 50e9) == 0.0
    assert abs(ring_allreduce_time(1e9, 16, 50e9)
               - 2 * 15 / 16 * 1e9 / 50e9) < 1e-12
    assert allgather_time(1e9, 16, 50e9) < ring_allreduce_time(1e9, 16, 50e9)
    assert reducescatter_time(1e9, 16, 50e9) == allgather_time(1e9, 16, 50e9)
    assert alltoall_time(0, 16, 50e9) == 0.0


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(st.floats(1e6, 1e11), st.floats(1e10, 1e16))
def test_more_microbatches_never_increase_act_memory(params, flops):
    c = _comp(params=params, flops=flops)
    m1 = _cm(microbatches=1).component_cost(c, Strategy.HP)
    m8 = _cm(microbatches=8).component_cost(c, Strategy.HP)
    assert m8.mem_act <= m1.mem_act + 1e-9
    # ...but they do increase ZeRO gather traffic
    assert m8.t_comm >= m1.t_comm - 1e-12


def test_seq_sharding_halves_mp_act_comm():
    c = _comp()
    base = _cm(seq_sharded=False).component_cost(c, Strategy.MP)
    sp = _cm(seq_sharded=True).component_cost(c, Strategy.MP)
    assert sp.t_comm < base.t_comm
    assert sp.mem_act <= base.mem_act


def test_fs_shards_params_over_all_chips():
    c = _comp(params=1e10)
    cm = _cm()
    fs = cm.component_cost(c, Strategy.FS)
    hp = cm.component_cost(c, Strategy.HP)
    # single-pod: FS and HP both shard 256-way
    assert abs(fs.mem_params - hp.mem_params) / hp.mem_params < 1e-6
    cm2 = _cm(mesh=MeshShape(16, 16, pod=2))
    fs2 = cm2.component_cost(c, Strategy.FS)
    assert fs2.mem_params < fs.mem_params  # 512-way now


def test_moe_ep_removes_gather_traffic():
    c = _comp(params=5e10, a2a=1e9)
    base = _cm(moe_ep=False).component_cost(c, Strategy.HP)
    ep = _cm(moe_ep=True).component_cost(c, Strategy.HP)
    assert ep.t_comm < base.t_comm
    assert ep.mem_params <= base.mem_params + 1e-9


def test_decode_mode_has_no_grad_traffic():
    c = _comp()
    dec = _cm(mode="decode").component_cost(c, Strategy.MP)
    tr = _cm(mode="train").component_cost(c, Strategy.MP)
    assert dec.t_comm < tr.t_comm
    assert dec.t_comp < tr.t_comp


def test_faithful_mode_is_pure_paper_model():
    """faithful: no bandwidth floor, no pod grad term, no transitions."""
    c = _comp(params=1e10, flops=1e10)   # tiny flops => bw floor would bind
    f = _cm(faithful=True).component_cost(c, Strategy.MP)
    o = _cm(faithful=False).component_cost(c, Strategy.MP)
    assert o.t_comp >= f.t_comp          # bw floor only in optimized mode
