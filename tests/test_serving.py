"""Continuous-batching serving subsystem tests: paged-cache invariants,
scheduler admission/preemption policy, and greedy-decode parity between the
continuous engine and the wave Server baseline — for attention-only,
hybrid attn+SSM and cross-attention architectures (the slot-state pools of
serving/cache_manager.py)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, EncoderSpec, Segment, ShapeSpec, \
    SSMSpec
from repro.core.asa import AdaptiveScheduler
from repro.launch.mesh import make_host_mesh, mesh_shape_of
from repro.models import layers as L
from repro.models import transformer as T
from repro.runtime.server import Request as WaveRequest, Server
from repro.serving import (BlockAllocator, ContinuousBatchingEngine,
                           PagedKVCache, Request, RequestScheduler,
                           ServingMetrics, UnifiedCacheManager)
from repro.serving.paged_cache import NULL_BLOCK, PagedCacheConfig, blocks_for

TINY = ArchConfig(name="tiny-serve", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  pattern=(Segment(("attn",), 2),), dtype="float32",
                  param_dtype="float32")

TINY_SSM = ArchConfig(name="tiny-ssm", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                      ssm=SSMSpec(d_state=16, head_dim=16, chunk=16),
                      pattern=(Segment(("mamba2",), 2),), dtype="float32",
                      param_dtype="float32")

TINY_HYBRID = ArchConfig(name="tiny-hybrid", family="hybrid", n_layers=4,
                         d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                         vocab=256,
                         ssm=SSMSpec(d_state=16, head_dim=16, d_conv=4,
                                     chunk=4),
                         pattern=(Segment(("attn", "mamba2"), 2),),
                         dtype="float32", param_dtype="float32")

TINY_CROSS = ArchConfig(name="tiny-cross", family="vlm", n_layers=4,
                        d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                        vocab=256, frontend="vision", n_img_tokens=8,
                        pattern=(Segment(("attn", "cross_attn"), 2),),
                        dtype="float32", param_dtype="float32")


# ---------------------------------------------------------------------------
# paged cache
# ---------------------------------------------------------------------------

def test_block_allocator_invariants():
    a = BlockAllocator(8)                     # blocks 1..7 usable
    assert a.num_free == 7
    got = a.alloc(3)
    assert len(got) == 3 and NULL_BLOCK not in got
    assert a.num_free == 4 and a.num_used == 3
    # all-or-nothing: over-ask leaves state untouched
    assert a.alloc(5) is None
    assert a.num_free == 4
    a.free(got[:2])
    assert a.num_free == 6
    with pytest.raises(ValueError):           # double free
        a.free(got[:1])
    with pytest.raises(ValueError):           # null block is never freeable
        a.free([NULL_BLOCK])
    # freed blocks are reused
    again = a.alloc(6)
    assert again is not None and set(got[:2]) <= set(again)


def test_paged_cache_reserve_release_reuse():
    cache = PagedKVCache(TINY, PagedCacheConfig(block_size=4, num_blocks=9,
                                                max_blocks_per_seq=4),
                         dtype=np.float32)
    assert cache.reserve(0, 10)               # 3 blocks
    assert cache.allocator.num_used == 3
    assert cache.reserve(0, 12)               # same 3 blocks suffice
    assert cache.allocator.num_used == 3
    assert cache.reserve(0, 13)               # grows by one
    assert cache.allocator.num_used == 4
    assert cache.reserve(1, 16)               # 4 more -> pool full (8 usable)
    assert not cache.reserve(2, 1)            # OOM, state unchanged
    assert 2 not in cache.tables
    cache.release(0)
    assert cache.allocator.num_used == 4
    assert cache.reserve(2, 16)               # reuses request 0's blocks
    row = cache.table_row(2)
    assert row.shape == (4,) and NULL_BLOCK not in row
    assert (cache.table_row(None) == NULL_BLOCK).all()


def test_blocks_for():
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2


def test_paged_cache_specs_match_pool_tree():
    mesh = make_host_mesh()
    for arch in (TINY, TINY_HYBRID, TINY_CROSS, TINY_SSM):
        plan = AdaptiveScheduler(faithful=False).plan(
            arch, ShapeSpec("serve", 64, 2, "decode"), mesh_shape_of(mesh))
        pools = T.init_paged_cache(arch, 8, 4, np.float32, slots=2)
        specs = plan.paged_cache_specs()
        assert jax.tree.structure(pools) == jax.tree.structure(specs), \
            arch.name


def test_unified_cache_manager_slot_rows():
    """Slot-state pools carry one row per engine slot plus the reserved
    null row; inactive batch rows map to the null row."""
    cfg = PagedCacheConfig(block_size=4, num_blocks=9, max_blocks_per_seq=4,
                           slots=3)
    mgr = UnifiedCacheManager(TINY_HYBRID, cfg, dtype=np.float32)
    assert mgr.has_slot_state and mgr.slot_state_kinds == ["mamba2"]
    assert mgr.null_slot == 3
    ssm_pool = mgr.pools[0]["b1"]["ssm"]
    assert ssm_pool.shape[1] == 4                  # slots + null row
    # rows are _Slot.idx values (None -> null row), NOT list positions —
    # the engine's slot list may be reordered relative to pool rows
    assert (mgr.slot_ids_array([2, None, 0])
            == np.array([2, 3, 0], np.int32)).all()
    # block side inherited unchanged
    assert mgr.reserve(0, 10) and mgr.allocator.num_used == 3
    mgr.release(0)
    assert mgr.allocator.num_used == 0
    with pytest.raises(ValueError, match="slots"):
        UnifiedCacheManager(TINY_HYBRID,
                            PagedCacheConfig(4, 9, 4), dtype=np.float32)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _req(i, plen=8, max_new=4, priority=0):
    return Request(id=i, prompt=np.arange(1, plen + 1, dtype=np.int32),
                   max_new_tokens=max_new, priority=priority)


def test_scheduler_fcfs_within_priority_class():
    s = RequestScheduler()
    for i in range(3):
        s.submit(_req(i))
    urgent = _req(99, priority=-1)
    s.submit(urgent)
    order = [s.next_admission().id for _ in range(4)]
    assert order == [99, 0, 1, 2]


def test_scheduler_token_budget_blocks_admission():
    s = RequestScheduler(max_tokens_in_flight=30)
    s.submit(_req(0, plen=8, max_new=4))      # footprint 12
    s.submit(_req(1, plen=8, max_new=4))
    s.submit(_req(2, plen=8, max_new=4))
    a, b = s.next_admission(), s.next_admission()
    assert a.id == 0 and b.id == 1
    assert s.next_admission() is None         # 24 + 12 > 30
    s.on_finish(a)
    assert s.next_admission().id == 2
    with pytest.raises(ValueError):           # can never be admitted
        s.submit(_req(3, plen=40, max_new=4))


def test_scheduler_preemption_victim_and_requeue_order():
    s = RequestScheduler()
    for i in range(3):
        s.submit(_req(i))
    running = [s.next_admission() for _ in range(2)]
    running[0].out_tokens = [1, 2, 3]         # longest-running
    running[1].out_tokens = [1]
    victim = s.pick_preemption_victim(running)
    assert victim.id == 0
    s.preempt(victim)
    # preempted request keeps its original arrival seq: head of its class
    assert s.next_admission().id == 0
    # priority dominates generated length
    hi = _req(7, priority=-1); hi.out_tokens = [1, 2, 3, 4]
    assert s.pick_preemption_victim([hi, running[1]]).id == running[1].id


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _wave_outputs(params, mesh, prompts, max_new, arch=TINY):
    srv = Server(arch, params, mesh, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        srv.submit(WaveRequest(id=i, prompt=p.copy(), max_new_tokens=max_new))
    srv.run_until_drained()
    return {r.id: r.out_tokens for r in srv.completed}


def test_continuous_engine_greedy_parity_with_wave():
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(5)]
    wave = _wave_outputs(params, mesh, prompts, max_new=6)

    # chunked prefill (chunk 3 < prompt 8) + slot churn (5 reqs, 2 slots)
    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=64,
                                   block_size=4, prefill_chunk=3)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=6))
    eng.run_until_drained()
    cont = {r.id: r.out_tokens for r in eng.completed}
    assert cont == wave                       # token-for-token
    assert eng.metrics.summary()["completed"] == 5
    assert eng.cache.allocator.num_used == 0  # every block returned


def test_continuous_engine_parity_under_preemption():
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(4)]
    wave = _wave_outputs(params, mesh, prompts, max_new=8)

    # 7 usable blocks * 4 tokens < 2 slots * 16 tokens -> cache pressure
    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=64,
                                   block_size=4, num_blocks=8,
                                   prefill_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=8))
    eng.run_until_drained()
    cont = {r.id: r.out_tokens for r in eng.completed}
    assert cont == wave                       # recompute-preemption is exact
    assert eng.metrics.preemptions > 0
    assert eng.cache.allocator.num_used == 0


def test_parity_with_multiple_victims_in_one_step():
    """Regression: a slot preempted as a victim for an earlier slot's block
    grab must be skipped by the rest of that decode step (slot.req is None).
    4 decoding slots x 2 blocks each > 6 usable blocks forces it."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 17, dtype=np.int32) + i for i in range(6)]
    srv = Server(TINY, params, mesh, slots=4, max_len=64)
    for i, p in enumerate(prompts):
        srv.submit(WaveRequest(id=i, prompt=p.copy(), max_new_tokens=8))
    srv.run_until_drained()
    wave = {r.id: r.out_tokens for r in srv.completed}

    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=4, max_len=64,
                                   block_size=16, num_blocks=7,
                                   prefill_chunk=16)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=8))
    eng.run_until_drained()
    assert {r.id: r.out_tokens for r in eng.completed} == wave
    assert eng.metrics.preemptions > 0


def test_parity_with_mixed_max_new_tokens():
    """Regression: the wave Server's decode bound must follow the *active*
    requests — with mixed max_new a finished slot 0 used to let longer
    requests decode past max_len into a clamped (corrupting) cache write.
    Both engines must truncate the long request identically."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(2)]
    max_news = [2, 20]                        # 8 + 20 > max_len=12
    srv = Server(TINY, params, mesh, slots=2, max_len=12)
    for i, p in enumerate(prompts):
        srv.submit(WaveRequest(id=i, prompt=p.copy(),
                               max_new_tokens=max_news[i]))
    srv.run_until_drained()
    wave = {r.id: r.out_tokens for r in srv.completed}
    assert len(wave[1]) <= 12 - 8             # truncated at max_len

    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=12,
                                   block_size=4, prefill_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(),
                           max_new_tokens=max_news[i]))
    eng.run_until_drained()
    assert {r.id: r.out_tokens for r in eng.completed} == wave


def test_prefill_serves_oldest_request_first():
    """Regression: chunked prefill must advance the oldest admitted request
    (scheduler FCFS seq), not the lowest slot index."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=64,
                                   block_size=4, prefill_chunk=2)
    older, newer = _req(0, plen=8), _req(1, plen=8)
    eng.submit(older)
    eng.submit(newer)
    eng._admit()
    # simulate slot churn: the older request ends up in the *higher* slot
    eng.slots[0], eng.slots[1] = eng.slots[1], eng.slots[0]
    assert eng.slots[0].req is newer and eng.slots[1].req is older
    eng._prefill_chunk()
    assert eng.slots[1].prefill_pos == 2      # older advanced
    assert eng.slots[0].prefill_pos == 0      # newer waits


def test_hybrid_and_cross_parity_with_wave():
    """Slot-state serving: hybrid attn+SSM and cross-attn configs decode
    token-for-token like the wave Server, through chunked prefill (chunk <
    prompt) and slot churn (more requests than slots)."""
    mesh = make_host_mesh()
    for arch in (TINY_HYBRID, TINY_CROSS):
        params = T.init_lm(jax.random.PRNGKey(0), arch)
        prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(4)]
        wave = _wave_outputs(params, mesh, prompts, max_new=6, arch=arch)
        eng = ContinuousBatchingEngine(arch, params, mesh, slots=2,
                                       max_len=64, block_size=4,
                                       prefill_chunk=4)
        for i, p in enumerate(prompts):
            eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=6))
        eng.run_until_drained()
        assert {r.id: r.out_tokens for r in eng.completed} == wave, arch.name
        assert eng.cache.allocator.num_used == 0


def test_hybrid_parity_under_preemption():
    """Forced preemption (tiny block pool) on the hybrid config: the
    recompute-style resume must rebuild the SSM slot state exactly —
    re-admission zeroes the row and the re-prefill replays prompt+generated
    through the chunked scan with h0 carried."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY_HYBRID)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(4)]
    wave = _wave_outputs(params, mesh, prompts, max_new=8, arch=TINY_HYBRID)
    eng = ContinuousBatchingEngine(TINY_HYBRID, params, mesh, slots=2,
                                   max_len=64, block_size=4, num_blocks=8,
                                   prefill_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=8))
    eng.run_until_drained()
    assert {r.id: r.out_tokens for r in eng.completed} == wave
    assert eng.metrics.preemptions > 0
    assert eng.cache.allocator.num_used == 0


def test_pure_ssm_parity_with_wave():
    """mamba2-only arch (no attention KV at all): served via slot-state
    pools alone."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY_SSM)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(3)]
    wave = _wave_outputs(params, mesh, prompts, max_new=6, arch=TINY_SSM)
    eng = ContinuousBatchingEngine(TINY_SSM, params, mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=3)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=6))
    eng.run_until_drained()
    assert {r.id: r.out_tokens for r in eng.completed} == wave


def test_cross_kv_computed_once_at_admission():
    """A request carrying frontend embeddings gets its cross K/V projected
    into its slot rows at admit time; with nonzero attention gates the
    frontend changes the greedy output vs the text-only (zero cross-K/V)
    serve."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY_CROSS)
    # llama-vision tanh gates init at 0 => open them so cross-attn matters
    for si, seg in enumerate(TINY_CROSS.pattern):
        blk = params["segments"][si]["b1"]
        blk["attn"]["gate"] = jnp.ones_like(blk["attn"]["gate"])
        blk["mlp_gate"] = jnp.ones_like(blk["mlp_gate"])
    fe = np.asarray(20 * jax.random.normal(jax.random.PRNGKey(3), (1, 8, 64)),
                    np.float32)
    prompt = np.arange(1, 7, dtype=np.int32)

    def serve(frontend):
        eng = ContinuousBatchingEngine(TINY_CROSS, params, mesh, slots=2,
                                       max_len=32, block_size=4,
                                       prefill_chunk=4)
        eng.submit(Request(id=0, prompt=prompt.copy(), max_new_tokens=4,
                           frontend=frontend))
        eng.run_until_drained()
        return eng, eng.completed[0].out_tokens

    eng, with_fe = serve(fe)
    # slot 0's cross-K row equals the direct projection of the frontend
    from repro.models import blocks as B
    cfg = B.attn_cfg_for(TINY_CROSS, causal=False, gated=True,
                         use_rope=False)
    attn0 = jax.tree.map(lambda t: t[0], params["segments"][0]["b1"]["attn"])
    k_ref = L.dense(attn0["wk"], jnp.asarray(fe[0])).reshape(
        8, cfg.n_kv_heads, cfg.head_dim)
    got = np.asarray(eng.cache.pools[0]["b1"]["k"][0, 0])
    np.testing.assert_allclose(got, np.asarray(k_ref), rtol=1e-6)
    _, text_only = serve(None)
    assert with_fe != text_only


def test_submit_rejects_duplicate_ids_and_empty_prompts():
    """Regression: block tables are keyed by request id, so a duplicate
    in-flight id silently shared (and corrupted) the live request's table;
    an empty prompt crashed the prefill with a KeyError.  Both must be
    rejected at submit; a finished id may be reused."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=64,
                                   block_size=4, prefill_chunk=8)
    eng.submit(Request(id=7, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2))
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(Request(id=7, prompt=np.arange(1, 5, dtype=np.int32),
                           max_new_tokens=2))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(id=8, prompt=np.array([], np.int32)))
    eng.run_until_drained()
    eng.submit(Request(id=7, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2))         # id free again after finish
    eng.run_until_drained()
    assert len(eng.completed) == 2


def test_engine_rejects_excluded_archs_with_precise_error():
    """zamba2's weight-shared block and whisper's enc-dec stay wave-only;
    the error says why and points at the wave Server."""
    mesh = make_host_mesh()
    shared = ArchConfig(name="tiny-shared", family="hybrid", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                        vocab=256,
                        ssm=SSMSpec(d_state=16, head_dim=16, chunk=16),
                        pattern=(Segment(("shared_attn", "mamba2"), 1),),
                        dtype="float32", param_dtype="float32")
    with pytest.raises(ValueError, match="shared.*wave|wave.*shared"):
        ContinuousBatchingEngine(shared, None, mesh)
    encdec = ArchConfig(name="tiny-encdec", family="audio", n_layers=2,
                        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                        vocab=256, pattern=(Segment(("wdec",), 2),),
                        encoder=EncoderSpec(n_layers=1, seq_len=8, d_ff=128),
                        frontend="audio", dtype="float32",
                        param_dtype="float32")
    with pytest.raises(ValueError, match="wdec|encoder"):
        ContinuousBatchingEngine(encdec, None, mesh)


def test_short_prompt_mamba2_handoff():
    """Regression: a prompt shorter than d_conv-1 used to under-fill the
    conv buffer at the prefill->decode handoff (xr[:, -K:, :] yields < K
    rows).  A 1-token prompt must decode, and greedily continuing from a
    2-token prompt must reproduce the same stream (exact handoff state)."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY_SSM)
    srv = Server(TINY_SSM, params, mesh, slots=1, max_len=32)
    srv.submit(WaveRequest(id=0, prompt=np.array([5], np.int32),
                           max_new_tokens=6))
    srv.run_until_drained()
    first = srv.completed[0].out_tokens
    assert len(first) == 6
    srv2 = Server(TINY_SSM, params, mesh, slots=1, max_len=32)
    srv2.submit(WaveRequest(id=0,
                            prompt=np.array([5, first[0]], np.int32),
                            max_new_tokens=5))
    srv2.run_until_drained()
    assert srv2.completed[0].out_tokens == first[1:]


def test_paged_attention_overrun_diverts_to_null_block():
    """Regression: a write past a request's block-table capacity used to be
    clamped into its *last* block, corrupting live KV.  Overrun writes must
    land in the null block and leave every live block (and prior-token
    logits) bit-identical."""
    cfg = L.AttnConfig(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16)
    p = L.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    BS, NB = 4, 6
    pool = L.init_paged_attention_cache(cfg, NB, BS, jnp.float32)
    xa = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    ta = jnp.asarray([[1, 2]], jnp.int32)          # capacity: 8 tokens
    _, pool = L.paged_attention(p, cfg, xa, cache=pool,
                                positions=jnp.array([0]), block_tables=ta)
    out1, pool1 = L.paged_attention(p, cfg, xa[:, -1:], cache=pool,
                                    positions=jnp.array([7]),
                                    block_tables=ta)
    # another request writes OUT of table: position 9 -> logical block 2
    xb = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 32))
    _, pool2 = L.paged_attention(p, cfg, xb, cache=pool1,
                                 positions=jnp.array([9]),
                                 block_tables=jnp.asarray([[3, 4]],
                                                          jnp.int32))
    perturbed = [b for b in range(NB)
                 if not np.array_equal(np.asarray(pool1["k"][b]),
                                       np.asarray(pool2["k"][b]))]
    assert perturbed in ([], [0])                  # only the null block
    out2, _ = L.paged_attention(p, cfg, xa[:, -1:], cache=pool2,
                                positions=jnp.array([7]), block_tables=ta)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))


def test_sinusoidal_odd_d_model():
    """Regression: odd d_model used to raise a shape error (floor(d/2) cos
    columns assigned ceil(d/2) values)."""
    for d in (5, 7, 64):
        pe = T.sinusoidal_at(jnp.arange(6), d)
        assert pe.shape == (6, d)
    # even path unchanged: interleaved sin/cos
    pe = T.sinusoidal_at(jnp.arange(4), 6)
    np.testing.assert_allclose(np.asarray(pe[:, 0]),
                               np.sin(np.arange(4, dtype=np.float32)),
                               rtol=1e-6)


def test_metrics_json_report():
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.5)
    m.on_first_token(0, now=9.9)              # resumed request: TTFT kept
    m.on_step(queue_depth=1, busy_slots=1, slots=2)
    m.on_finish(0, n_tokens=3, now=1.5)
    rep = json.loads(m.to_json(engine="continuous"))
    assert rep["engine"] == "continuous"
    assert rep["completed"] == 1 and rep["total_tokens"] == 3
    assert rep["requests"][0]["ttft_s"] == pytest.approx(0.5)
    assert rep["requests"][0]["tpot_s"] == pytest.approx(0.5)  # 1.0s / 2
    assert rep["tokens_per_sec"] == pytest.approx(2.0)         # 3 tok / 1.5s
    assert rep["slot_occupancy_mean"] == pytest.approx(0.5)
    for key in ("ttft_mean_s", "tpot_mean_s", "queue_depth_max",
                "preemptions", "decode_steps"):
        assert key in rep
