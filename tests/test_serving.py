"""Continuous-batching serving subsystem tests: paged-cache invariants,
scheduler admission/preemption policy, the v2 generation API
(SamplingParams validation, seeded stochastic decode, stop conditions,
typed RequestOutput, generate/stream/on_token), and greedy-decode parity
for every architecture family the engine serves — attention-only,
pure-SSM, hybrid, cross-attention, zamba2's weight-shared block, whisper's
encoder-decoder and MLA latent attention.

Greedy parity is asserted against tests/goldens_serving.json — token
sequences frozen from the pre-shim wave Server (see
gen_serving_goldens.py).  The wave Server is now a compatibility shim over
the engine, so a live comparison would be circular; the pinned goldens
keep parity falsifiable.  Stochastic decode has no goldens: its contract
is determinism — bit-identical reruns, seed sensitivity, and invariance
under forced recompute-preemption — which the sampling tests pin instead.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ArchConfig, Segment, ShapeSpec
from repro.core.asa import AdaptiveScheduler
from repro.launch.mesh import make_host_mesh, mesh_shape_of
from repro.models import layers as L
from repro.models import transformer as T
from repro.runtime import steps as ST
from repro.serving import (BlockAllocator, ContinuousBatchingEngine,
                           PagedKVCache, Request, RequestOutput,
                           RequestScheduler, SamplingParams, ServingMetrics,
                           UnifiedCacheManager)
from repro.serving.cache_manager import check_servable
from repro.serving.engine import _ReqState
from repro.serving.paged_cache import NULL_BLOCK, PagedCacheConfig, blocks_for
from repro.serving.sampling import apply_top_k, apply_top_p
from serving_fixtures import (ARCH_BY_KEY, TINY, TINY_CROSS, TINY_ENCDEC,
                              TINY_HYBRID, TINY_MLA, TINY_SHARED, TINY_SSM,
                              load_goldens, scenario_requests)

_PARAMS_CACHE: dict[str, dict] = {}


def _params_for(arch):
    if arch.name not in _PARAMS_CACHE:
        _PARAMS_CACHE[arch.name] = T.init_lm(jax.random.PRNGKey(0), arch)
    return _PARAMS_CACHE[arch.name]


def _run_scenario(name, mesh, sampling=None, **engine_kw):
    arch, reqs, slots, max_len = scenario_requests(name)
    eng = ContinuousBatchingEngine(arch, _params_for(arch), mesh,
                                   slots=slots, max_len=max_len, **engine_kw)
    outs = eng.generate([
        Request(id=rid, prompt=prompt.copy(), max_new_tokens=max_new,
                sampling=sampling or SamplingParams())
        for rid, prompt, max_new in reqs])
    return eng, {o.request_id: o.token_ids for o in outs}


# ---------------------------------------------------------------------------
# paged cache
# ---------------------------------------------------------------------------

def test_block_allocator_invariants():
    a = BlockAllocator(8)                     # blocks 1..7 usable
    assert a.num_free == 7
    got = a.alloc(3)
    assert len(got) == 3 and NULL_BLOCK not in got
    assert a.num_free == 4 and a.num_used == 3
    # all-or-nothing: over-ask leaves state untouched
    assert a.alloc(5) is None
    assert a.num_free == 4
    a.free(got[:2])
    assert a.num_free == 6
    with pytest.raises(ValueError):           # double free
        a.free(got[:1])
    with pytest.raises(ValueError):           # null block is never freeable
        a.free([NULL_BLOCK])
    # freed blocks are reused
    again = a.alloc(6)
    assert again is not None and set(got[:2]) <= set(again)


def test_block_allocator_refcount_invariants():
    """Shared blocks: freed only at refcount 0; incref on unallocated /
    null blocks raises; double free still raises after the last ref."""
    a = BlockAllocator(8)
    (b,) = a.alloc(1)
    assert a.refcount(b) == 1
    assert a.incref(b) == 2
    a.free([b])                               # one holder drops out
    assert a.refcount(b) == 1 and a.num_free == 6   # NOT freed yet
    assert a.decref(b) == 0                   # last holder -> free list
    assert a.num_free == 7 and a.refcount(b) == 0
    with pytest.raises(ValueError):           # double free
        a.decref(b)
    with pytest.raises(ValueError):           # incref on a free block
        a.incref(b)
    with pytest.raises(ValueError):           # null block is never refable
        a.incref(NULL_BLOCK)
    with pytest.raises(ValueError):
        a.decref(NULL_BLOCK)


def test_paged_cache_reserve_release_reuse():
    cache = PagedKVCache(TINY, PagedCacheConfig(block_size=4, num_blocks=9,
                                                max_blocks_per_seq=4),
                         dtype=np.float32)
    assert cache.reserve(0, 10)               # 3 blocks
    assert cache.allocator.num_used == 3
    assert cache.reserve(0, 12)               # same 3 blocks suffice
    assert cache.allocator.num_used == 3
    assert cache.reserve(0, 13)               # grows by one
    assert cache.allocator.num_used == 4
    assert cache.reserve(1, 16)               # 4 more -> pool full (8 usable)
    assert not cache.reserve(2, 1)            # OOM, state unchanged
    assert 2 not in cache.tables
    cache.release(0)
    assert cache.allocator.num_used == 4
    assert cache.reserve(2, 16)               # reuses request 0's blocks
    row = cache.table_row(2)
    assert row.shape == (4,) and NULL_BLOCK not in row
    assert (cache.table_row(None) == NULL_BLOCK).all()


def test_blocks_for():
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2


# ---------------------------------------------------------------------------
# shared-prefix block reuse (host-side cache semantics)
# ---------------------------------------------------------------------------

def _prefix_cache(num_blocks=9, block_size=4, mbps=6):
    return PagedKVCache(TINY, PagedCacheConfig(block_size, num_blocks, mbps,
                                               share_prefix=True),
                        dtype=np.float32)


def test_prefix_match_assign_and_refcounts():
    cache = _prefix_cache()
    toks = np.arange(1, 13, dtype=np.int32)        # 3 full blocks
    assert cache.reserve(0, 12)
    cache.commit_prefix(0, toks, 12)               # request 0 wrote them
    t0 = list(cache.tables[0])
    assert all(cache.allocator.refcount(b) == 2 for b in t0)  # req + index
    # a second request with the same prefix + a private tail shares them
    toks2 = np.concatenate([toks, np.asarray([99, 98], np.int32)])
    assert cache.match_prefix(toks2) == t0
    n = cache.assign_prefix(1, toks2)
    assert n == 12 and cache.tables[1] == t0
    assert all(cache.allocator.refcount(b) == 3 for b in t0)
    assert cache.reserve(1, len(toks2))            # grows by one private block
    assert cache.tables[1][:3] == t0 and len(cache.tables[1]) == 4
    # releases peel references one at a time; blocks free only at zero
    cache.release(0)
    assert all(cache.allocator.refcount(b) == 2 for b in t0)
    assert cache.num_cached == 0                   # still referenced by req 1
    cache.release(1)
    assert all(cache.allocator.refcount(b) == 1 for b in t0)  # index holds
    assert cache.num_cached == 3                   # retired into the LRU
    # an identical context re-matches the retired blocks out of the LRU
    assert cache.assign_prefix(2, toks2) == 12
    assert cache.num_cached == 0
    assert cache.prefix_stats()["hit_rate"] > 0


def test_prefix_match_requires_full_blocks_and_leaves_one_token():
    cache = _prefix_cache()
    toks = np.arange(1, 11, dtype=np.int32)        # 2 full blocks + 2 spare
    cache.reserve(0, 10)
    cache.commit_prefix(0, toks, 10)               # only 2 full blocks indexed
    assert len(cache.match_prefix(toks)) == 2
    # a context that IS exactly the cached blocks must leave >= 1 token to
    # prefill (the engine needs logits to sample the first output token)
    assert len(cache.match_prefix(toks[:8])) == 1
    # partial-block prefix: no match below one full block
    assert cache.match_prefix(toks[:3]) == []
    # different first block: chain breaks immediately
    other = toks.copy(); other[0] = 77
    assert cache.match_prefix(other) == []


def test_prefix_lru_eviction_before_oom_never_evicts_referenced():
    cache = _prefix_cache(num_blocks=7)            # 6 usable
    a = np.arange(1, 9, dtype=np.int32)            # 2 blocks
    b = np.arange(101, 109, dtype=np.int32)        # 2 blocks
    cache.reserve(0, 8);  cache.commit_prefix(0, a, 8)
    cache.reserve(1, 8);  cache.commit_prefix(1, b, 8)
    cache.release(0)                               # a's blocks -> LRU
    live = list(cache.tables[1])
    # request 2 needs 4 blocks: 2 free + 2 evicted from the LRU (a's),
    # while request 1's referenced blocks are untouched
    c = np.arange(201, 217, dtype=np.int32)
    assert cache.can_fit(16)
    assert cache.reserve(2, 16)
    assert cache.tables[1] == live
    assert cache.prefix_stats()["evictions"] == 2
    assert cache.match_prefix(np.concatenate([a, [9]])) == []   # a evicted
    assert len(cache.match_prefix(np.concatenate([b, [9]]))) == 2  # b cached
    # pool genuinely exhausted now: no free, no LRU, reserve reports OOM
    assert not cache.reserve(3, 4)
    assert 3 not in cache.tables


def test_prefix_partial_eviction_sacrifices_chain_tail_first():
    """Regression: release() retired a chain head-first into the LRU, so a
    partial eviction removed the head block — match_prefix then broke at
    block 0 while the still-cached tail sat unmatchable.  Eviction must eat
    a retired chain from its tail."""
    cache = _prefix_cache(num_blocks=5)            # 4 usable
    toks = np.arange(1, 13, dtype=np.int32)        # 3 full blocks
    cache.reserve(0, 12)
    cache.commit_prefix(0, toks, 12)
    cache.release(0)                               # whole chain -> LRU
    assert cache.num_cached == 3
    cache.reserve(1, 8)                            # needs 2: 1 free + 1 evict
    assert cache.prefix_stats()["evictions"] == 1
    # the surviving 2 cached blocks are the chain HEAD — still matchable
    assert len(cache.match_prefix(toks)) == 2


def test_prefix_commit_dedups_duplicate_content():
    """Two requests that prefilled the same tokens privately (admitted
    before either committed): first writer wins the index entry, the
    second stays private and frees outright on release."""
    cache = _prefix_cache()
    toks = np.arange(1, 9, dtype=np.int32)
    cache.reserve(0, 8)
    cache.reserve(1, 8)
    cache.commit_prefix(0, toks, 8)
    cache.commit_prefix(1, toks, 8)                # duplicate content
    t0, t1 = cache.tables[0], cache.tables[1]
    assert all(cache.allocator.refcount(x) == 2 for x in t0)
    assert all(cache.allocator.refcount(x) == 1 for x in t1)
    free_before = cache.allocator.num_free
    cache.release(1)                               # private -> freed
    assert cache.allocator.num_free == free_before + 2
    cache.release(0)                               # indexed -> LRU
    assert cache.num_cached == 2


def test_prefix_sharing_rejected_for_slot_state_archs():
    """Slot-state rows (mamba2 recurrent state, cross-attn / wdec K/V) are
    per-request and cannot be content-shared — a precise error, not silent
    corruption."""
    mesh = make_host_mesh()
    for arch in (TINY_SSM, TINY_HYBRID, TINY_CROSS, TINY_SHARED, TINY_ENCDEC):
        with pytest.raises(ValueError, match="slot-state"):
            ContinuousBatchingEngine(arch, _params_for(arch), mesh, slots=2,
                                     max_len=64, share_prefix=True)


def test_paged_cache_specs_match_pool_tree():
    mesh = make_host_mesh()
    for arch in (TINY, TINY_HYBRID, TINY_CROSS, TINY_SSM, TINY_SHARED,
                 TINY_ENCDEC, TINY_MLA):
        plan = AdaptiveScheduler(faithful=False).plan(
            arch, ShapeSpec("serve", 64, 2, "decode"), mesh_shape_of(mesh))
        pools = T.init_paged_cache(arch, 8, 4, np.float32, slots=2)
        specs = plan.paged_cache_specs()
        assert jax.tree.structure(pools) == jax.tree.structure(specs), \
            arch.name


def test_check_servable_accepts_every_registry_arch():
    """The continuous engine serves every config in the zoo — zamba2's
    weight-shared block, whisper's encoder-decoder and deepseek's MLA
    included; check_servable only rejects kinds the serving cache layer has
    never seen."""
    for arch in ARCHS.values():
        check_servable(arch)                  # must not raise
    for arch in ARCH_BY_KEY.values():
        check_servable(arch)
    bogus = ArchConfig(name="tiny-unknown", family="dense", n_layers=1,
                       d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                       vocab=256, pattern=(Segment(("enc_attn",), 1),),
                       dtype="float32", param_dtype="float32")
    with pytest.raises(ValueError, match="enc_attn"):
        check_servable(bogus)
    # an encoder arch whose pattern has no wdec block would silently serve
    # raw (un-encoded) frontend projections — must be rejected up front
    import dataclasses
    no_wdec = dataclasses.replace(TINY_CROSS, name="tiny-enc-no-wdec",
                                  encoder=TINY_ENCDEC.encoder)
    with pytest.raises(ValueError, match="wdec"):
        check_servable(no_wdec)


def test_unified_cache_manager_slot_rows():
    """Slot-state pools carry one row per engine slot plus the reserved
    null row; inactive batch rows map to the null row."""
    cfg = PagedCacheConfig(block_size=4, num_blocks=9, max_blocks_per_seq=4,
                           slots=3)
    mgr = UnifiedCacheManager(TINY_HYBRID, cfg, dtype=np.float32)
    assert mgr.has_slot_state and mgr.slot_state_kinds == ["mamba2"]
    assert mgr.null_slot == 3
    ssm_pool = mgr.pools[0]["b1"]["ssm"]
    assert ssm_pool.shape[1] == 4                  # slots + null row
    # rows are _Slot.idx values (None -> null row), NOT list positions —
    # the engine's slot list may be reordered relative to pool rows
    assert (mgr.slot_ids_array([2, None, 0])
            == np.array([2, 3, 0], np.int32)).all()
    # block side inherited unchanged
    assert mgr.reserve(0, 10) and mgr.allocator.num_used == 3
    mgr.release(0)
    assert mgr.allocator.num_used == 0
    with pytest.raises(ValueError, match="slots"):
        UnifiedCacheManager(TINY_HYBRID,
                            PagedCacheConfig(4, 9, 4), dtype=np.float32)


def test_wdec_pool_carries_both_state_classes():
    """whisper's wdec block pages its self-attn KV and slot-indexes its
    per-request encoder cross K/V."""
    mgr = UnifiedCacheManager(
        TINY_ENCDEC, PagedCacheConfig(block_size=4, num_blocks=9,
                                      max_blocks_per_seq=4, slots=2),
        dtype=np.float32)
    assert mgr.slot_state_kinds == ["wdec"]
    pool = mgr.pools[0]["b0"]
    assert pool["self"]["k"].shape[1] == 9         # (repeat, NB, BS, H, D)
    assert pool["cross"]["k"].shape[1:3] == (3, 8)  # (slots+1, enc_len)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _req(i, plen=8, max_new=4, priority=0):
    """Scheduler-protocol record: the scheduler queues the engine's
    internal _ReqState (the public Request is input-only and carries no
    out_tokens / bookkeeping fields)."""
    r = Request(id=i, prompt=np.arange(1, plen + 1, dtype=np.int32),
                max_new_tokens=max_new, priority=priority)
    return _ReqState(req=r, seed=i, stop_ids=frozenset())


def test_scheduler_fcfs_within_priority_class():
    s = RequestScheduler()
    for i in range(3):
        s.submit(_req(i))
    urgent = _req(99, priority=-1)
    s.submit(urgent)
    order = [s.next_admission().id for _ in range(4)]
    assert order == [99, 0, 1, 2]


def test_scheduler_token_budget_blocks_admission():
    s = RequestScheduler(max_tokens_in_flight=30)
    s.submit(_req(0, plen=8, max_new=4))      # footprint 12
    s.submit(_req(1, plen=8, max_new=4))
    s.submit(_req(2, plen=8, max_new=4))
    a, b = s.next_admission(), s.next_admission()
    assert a.id == 0 and b.id == 1
    assert s.next_admission() is None         # 24 + 12 > 30
    s.on_finish(a)
    assert s.next_admission().id == 2
    with pytest.raises(ValueError):           # can never be admitted
        s.submit(_req(3, plen=40, max_new=4))


def test_scheduler_footprint_capped_at_max_len():
    """Regression: the scheduler charged len(prompt) + max_new_tokens
    uncapped while the engine truncates every request to max_len, so a
    long-prompt request over-charged the budget and stalled admission.
    With the cap threaded through, a budget sized for capped footprints
    admits them."""
    s = RequestScheduler(max_tokens_in_flight=40, footprint_cap=32)
    # uncapped footprint 20 + 30 = 50 > 40 -> would have been rejected at
    # submit; capped at 32 it fits the budget
    s.submit(_req(0, plen=20, max_new=30))
    assert s._footprint(_req(0, plen=20, max_new=30)) == 32
    assert s.next_admission().id == 0
    # a second capped request must NOT be admitted (32 + 32 > 40) ...
    s.submit(_req(1, plen=20, max_new=30))
    assert s.next_admission() is None
    # ... and accounting symmetry: finish releases exactly the capped charge
    s.on_finish(_req(0, plen=20, max_new=30))
    assert s.next_admission().id == 1

    # the engine threads its max_len into a default scheduler
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(
        TINY, _params_for(TINY), mesh, slots=2, max_len=32, block_size=4,
        prefill_chunk=8, scheduler=RequestScheduler(max_tokens_in_flight=40))
    assert eng.scheduler.footprint_cap == 32
    eng.submit(Request(id=0, prompt=np.arange(1, 21, dtype=np.int32),
                       max_new_tokens=30))
    eng.run_until_drained()
    assert len(eng.completed) == 1
    assert len(eng.completed[0].token_ids) == 12    # truncated at max_len
    assert eng.completed[0].finish_reason == "length"
    # the engine OWNS the cap: a scheduler reused with a second engine must
    # pick up that engine's max_len, not keep the first one's stale cap
    eng2 = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                    max_len=16, block_size=4,
                                    prefill_chunk=8, scheduler=eng.scheduler)
    assert eng2.scheduler.footprint_cap == 16


def test_scheduler_releases_exactly_the_charged_footprint():
    """Regression: a cap change while a request is in flight must not leak
    budget — on_finish releases the footprint charged at admission, not a
    re-computed one under the new cap."""
    s = RequestScheduler(max_tokens_in_flight=40, footprint_cap=32)
    r = _req(0, plen=20, max_new=30)          # charged min(50, 32) = 32
    s.submit(r)
    assert s.next_admission() is r
    s.footprint_cap = 16                      # e.g. reused with a new engine
    s.on_finish(r)                            # releases the recorded 32
    assert s._in_flight_tokens == 0


def test_scheduler_preemption_victim_and_requeue_order():
    s = RequestScheduler()
    for i in range(3):
        s.submit(_req(i))
    running = [s.next_admission() for _ in range(2)]
    running[0].out_tokens = [1, 2, 3]         # largest resident footprint
    running[1].out_tokens = [1]
    victim = s.pick_preemption_victim(running)
    assert victim.id == 0
    s.preempt(victim)
    # preempted request keeps its original arrival seq: head of its class
    assert s.next_admission().id == 0
    # priority dominates footprint
    hi = _req(7, priority=-1); hi.out_tokens = [1, 2, 3, 4]
    assert s.pick_preemption_victim([hi, running[1]]).id == running[1].id


def test_preemption_does_not_inflate_lifecycle_counters():
    """Regression (found while cross-validating sanitizer counters against
    scheduler telemetry): preempt() used to route through on_finish() +
    submit(), so every preemption bumped both `released` and `submitted`
    — the exported Prometheus/JSONL lifecycle counters overstated client
    submissions and completions whenever the engine ran under cache
    pressure.  A preemption is neither: only `preemptions` may move."""
    s = RequestScheduler(max_tokens_in_flight=100)
    r = _req(0)
    s.submit(r)
    assert s.next_admission() is r
    seq = r._sched_seq
    s.preempt(r)
    assert s.stats == {"submitted": 1, "admitted": 1, "budget_refusals": 0,
                       "preemptions": 1, "released": 0}
    assert s._in_flight_tokens == 0           # budget charge still released
    assert r._sched_seq == seq                # head-of-class re-entry kept
    assert s.next_admission() is r
    s.on_finish(r)
    assert s.stats["released"] == 1 and s.stats["submitted"] == 1

    # end-to-end: under forced preemption, submitted == client submissions
    # and released == completions
    eng = ContinuousBatchingEngine(
        TINY, _params_for(TINY), make_host_mesh(), slots=3, max_len=64,
        num_blocks=10, block_size=4, prefill_chunk=8)
    reqs = [Request(id=i, prompt=np.arange(1, 11, dtype=np.int32),
                    max_new_tokens=8) for i in range(6)]
    eng.generate(reqs)
    st = eng.scheduler.stats
    assert st["preemptions"] > 0              # pressure actually happened
    assert st["submitted"] == len(reqs)
    assert st["released"] == len(reqs)
    assert st["admitted"] == len(reqs) + st["preemptions"]  # re-admissions


def test_preemption_victim_ranks_by_resident_footprint():
    """Regression: the docstring promises 'frees the most blocks per
    preemption' but the ranking used len(out_tokens) — a long-prompt
    request mid-prefill (0 generated tokens, many resident blocks) was
    ranked LAST.  Rank by len(context()) = tokens in cache instead."""
    s = RequestScheduler()
    big = _req(0, plen=40, max_new=4)          # mid-prefill: 40 resident
    small = _req(1, plen=4, max_new=16)
    s.submit(big); s.submit(small)
    s.next_admission(); s.next_admission()
    small.out_tokens = list(range(10))         # long-running, 14 resident
    assert s.pick_preemption_victim([small, big]) is big
    # generated tokens still count toward footprint: 4+20 > 8+10
    small2 = _req(2, plen=8, max_new=16); small2.out_tokens = list(range(10))
    grown = _req(3, plen=4, max_new=24); grown.out_tokens = list(range(20))
    assert s.pick_preemption_victim([small2, grown]) is grown


# ---------------------------------------------------------------------------
# engine: greedy parity against the pre-shim wave goldens
# ---------------------------------------------------------------------------

# every arch family, with chunked prefill (chunk < prompt) and slot churn
PARITY_CASES = [
    ("tiny/base",   dict(block_size=4, prefill_chunk=3)),
    ("ssm/base",    dict(block_size=4, prefill_chunk=3)),
    ("hybrid/base", dict(block_size=4, prefill_chunk=4)),
    ("cross/base",  dict(block_size=4, prefill_chunk=4)),
    ("shared/base", dict(block_size=4, prefill_chunk=3)),
    ("encdec/base", dict(block_size=4, prefill_chunk=3)),
    ("mla/base",    dict(block_size=4, prefill_chunk=3)),
]


@pytest.mark.parametrize("scenario,kw", PARITY_CASES,
                         ids=[c[0] for c in PARITY_CASES])
def test_greedy_parity_with_wave_goldens(scenario, kw):
    mesh = make_host_mesh()
    eng, got = _run_scenario(scenario, mesh, **kw)
    assert got == load_goldens(scenario), scenario
    assert eng.cache.allocator.num_used == 0  # every block returned
    assert eng.metrics.summary()["completed"] == len(got)


# tiny block pools force recompute-preemption mid-decode; the resume must
# rebuild paged KV, SSM slot state, latent pools and cross K/V exactly
PREEMPT_CASES = [
    ("tiny/preempt",   dict(block_size=4, num_blocks=8, prefill_chunk=8)),
    ("hybrid/preempt", dict(block_size=4, num_blocks=8, prefill_chunk=8)),
    ("shared/preempt", dict(block_size=4, num_blocks=8, prefill_chunk=8)),
    ("encdec/preempt", dict(block_size=4, num_blocks=8, prefill_chunk=8)),
    ("mla/preempt",    dict(block_size=4, num_blocks=8, prefill_chunk=8)),
]


@pytest.mark.parametrize("scenario,kw", PREEMPT_CASES,
                         ids=[c[0] for c in PREEMPT_CASES])
def test_parity_under_forced_preemption(scenario, kw):
    mesh = make_host_mesh()
    eng, got = _run_scenario(scenario, mesh, **kw)
    assert got == load_goldens(scenario), scenario
    assert eng.metrics.preemptions > 0
    assert eng.cache.allocator.num_used == 0


# prefix sharing must be invisible to greedy outputs: the two purely paged
# families run the pinned-golden scenarios again with sharing ON, including
# forced preemption of a sharing request (its retired blocks re-match at
# re-admission)
SHARING_PARITY_CASES = [
    ("tiny/base",    dict(block_size=4, prefill_chunk=3)),
    ("mla/base",     dict(block_size=4, prefill_chunk=3)),
    ("tiny/preempt", dict(block_size=4, num_blocks=8, prefill_chunk=8)),
    ("mla/preempt",  dict(block_size=4, num_blocks=8, prefill_chunk=8)),
]


@pytest.mark.parametrize("scenario,kw", SHARING_PARITY_CASES,
                         ids=[c[0] for c in SHARING_PARITY_CASES])
def test_greedy_parity_with_prefix_sharing_enabled(scenario, kw):
    mesh = make_host_mesh()
    eng, got = _run_scenario(scenario, mesh, share_prefix=True, **kw)
    assert got == load_goldens(scenario), scenario
    if scenario.endswith("preempt"):
        # the victim was a sharing request: its committed blocks retired to
        # the LRU and re-matched when it was re-admitted
        assert eng.metrics.preemptions > 0
        assert eng.cache.prefix_stats()["hit_tokens"] > 0
    # after drain no request holds blocks; only the content index does
    assert eng.cache.allocator.num_used == eng.cache.num_cached
    assert eng.metrics.summary()["prefix_hit_rate"] \
        == pytest.approx(eng.cache.prefix_stats()["hit_rate"])


def test_shared_prefix_skips_prefill_and_matches_unshared_outputs():
    """Requests sharing a system-prompt prefix: admission hands the second
    request the first's cached blocks and starts prefill at the matched
    boundary, and greedy outputs are identical to the sharing-off serve."""
    mesh = make_host_mesh()
    prefix = np.arange(1, 13, dtype=np.int32)       # 3 full blocks of 4
    prompts = [np.concatenate([prefix, np.asarray([50 + i, 60 + i],
                                                  np.int32)])
               for i in range(4)]

    def serve(share):
        eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh,
                                       slots=2, max_len=64, block_size=4,
                                       prefill_chunk=4, share_prefix=share)
        for i, p in enumerate(prompts):
            eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=5))
        eng.run_until_drained()
        return eng, {o.request_id: o.token_ids for o in eng.completed}

    eng_off, out_off = serve(False)
    eng_on, out_on = serve(True)
    assert out_on == out_off
    stats = eng_on.cache.prefix_stats()
    # requests 0 and 1 fill both slots in the same admission step, before
    # either commits a block, so they prefill privately (first writer wins
    # the index); 2 and 3 match the full prefix
    assert stats["hit_tokens"] == 2 * len(prefix)
    assert eng_off.cache.prefix_stats()["hit_tokens"] == 0
    # the skipped prefix means fewer prefill chunks end to end
    assert eng_on.metrics.prefill_chunks < eng_off.metrics.prefill_chunks


def test_shared_prefix_admission_starts_at_matched_boundary():
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=4,
                                   share_prefix=True)
    prefix = np.arange(1, 9, dtype=np.int32)        # 2 full blocks
    eng.submit(Request(id=0, prompt=prefix.copy(), max_new_tokens=2))
    eng.run_until_drained()
    eng.submit(Request(id=1,
                       prompt=np.concatenate([prefix, [77]]).astype(np.int32),
                       max_new_tokens=2))
    eng._admit()
    slot = next(s for s in eng.slots if s.busy)
    assert slot.prefill_pos == 8                    # prefill skips the prefix
    assert slot.pos == 8
    eng.run_until_drained()
    assert len(eng.completed) == 2


def test_parity_with_multiple_victims_in_one_step():
    """Regression: a slot preempted as a victim for an earlier slot's block
    grab must be skipped by the rest of that decode step (slot.req is None).
    4 decoding slots x 2 blocks each > 6 usable blocks forces it."""
    mesh = make_host_mesh()
    eng, got = _run_scenario("tiny/victims", mesh, block_size=16,
                             num_blocks=7, prefill_chunk=16)
    assert got == load_goldens("tiny/victims")
    assert eng.metrics.preemptions > 0


def test_parity_with_mixed_max_new_tokens():
    """Regression: with mixed max_new the longer request must truncate at
    max_len exactly where the wave Server did (golden req 1: 4 of its 20
    requested tokens at max_len=12)."""
    mesh = make_host_mesh()
    _, got = _run_scenario("tiny/mixed", mesh, block_size=4, prefill_chunk=8)
    want = load_goldens("tiny/mixed")
    assert len(want[1]) == 4                  # truncated: 12 - 8
    assert got == want


def test_prefill_serves_oldest_request_first():
    """Regression: chunked prefill must advance the oldest admitted request
    (scheduler FCFS seq), not the lowest slot index."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=2)
    older = Request(id=0, prompt=np.arange(1, 9, dtype=np.int32))
    newer = Request(id=1, prompt=np.arange(1, 9, dtype=np.int32))
    eng.submit(older)
    eng.submit(newer)
    eng._admit()
    # simulate slot churn: the older request ends up in the *higher* slot
    eng.slots[0], eng.slots[1] = eng.slots[1], eng.slots[0]
    assert eng.slots[0].req.req is newer and eng.slots[1].req.req is older
    eng._prefill_chunk()
    assert eng.slots[1].prefill_pos == 2      # older advanced
    assert eng.slots[0].prefill_pos == 0      # newer waits


# ---------------------------------------------------------------------------
# per-request frontends consumed once at admission
# ---------------------------------------------------------------------------

def test_cross_kv_computed_once_at_admission():
    """A request carrying frontend embeddings gets its cross K/V projected
    into its slot rows at admit time; with nonzero attention gates the
    frontend changes the greedy output vs the text-only (zero cross-K/V)
    serve."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY_CROSS)
    # llama-vision tanh gates init at 0 => open them so cross-attn matters
    for si, seg in enumerate(TINY_CROSS.pattern):
        blk = params["segments"][si]["b1"]
        blk["attn"]["gate"] = jnp.ones_like(blk["attn"]["gate"])
        blk["mlp_gate"] = jnp.ones_like(blk["mlp_gate"])
    fe = np.asarray(20 * jax.random.normal(jax.random.PRNGKey(3), (1, 8, 64)),
                    np.float32)
    prompt = np.arange(1, 7, dtype=np.int32)

    def serve(frontend):
        eng = ContinuousBatchingEngine(TINY_CROSS, params, mesh, slots=2,
                                       max_len=32, block_size=4,
                                       prefill_chunk=4)
        eng.submit(Request(id=0, prompt=prompt.copy(), max_new_tokens=4,
                           frontend=frontend))
        eng.run_until_drained()
        return eng, eng.completed[0].token_ids

    eng, with_fe = serve(fe)
    # slot 0's cross-K row equals the direct projection of the frontend
    from repro.models import blocks as B
    cfg = B.attn_cfg_for(TINY_CROSS, causal=False, gated=True,
                         use_rope=False)
    attn0 = jax.tree.map(lambda t: t[0], params["segments"][0]["b1"]["attn"])
    k_ref = L.dense(attn0["wk"], jnp.asarray(fe[0])).reshape(
        8, cfg.n_kv_heads, cfg.head_dim)
    got = np.asarray(eng.cache.pools[0]["b1"]["k"][0, 0])
    np.testing.assert_allclose(got, np.asarray(k_ref), rtol=1e-6)
    _, text_only = serve(None)
    assert with_fe != text_only


def test_whisper_encoder_runs_once_at_admission():
    """An audio request's frame embeddings run through the encoder stack
    exactly once, at admission: the resulting cross K/V lands in the slot's
    wdec rows (exact content check), and the decoder's logits demonstrably
    read those rows (they shift vs the text-only zero-K/V serve — the tiny
    model's layernormed encoder output is O(1), so asserting on logits, not
    argmax, keeps the check robust)."""
    mesh = make_host_mesh()
    params = _params_for(TINY_ENCDEC)
    enc_len = TINY_ENCDEC.encoder.seq_len
    fe = np.asarray(20 * jax.random.normal(jax.random.PRNGKey(5),
                                           (1, enc_len, 64)), np.float32)
    prompt = np.arange(1, 7, dtype=np.int32)

    # a logits-returning (un-fused) prefill step: the engine's own
    # _prefill now samples on device and returns tokens, not logits
    raw_prefill = jax.jit(ST.make_paged_prefill_step(TINY_ENCDEC))

    def logits_after_admit(frontend):
        """Admit (encoder runs here, once), snapshot slot 0's cross-K row,
        then run a raw prefill step on the post-admission pools and return
        its logits."""
        eng = ContinuousBatchingEngine(TINY_ENCDEC, params, mesh, slots=2,
                                       max_len=32, block_size=4,
                                       prefill_chunk=8)
        eng.submit(Request(id=0, prompt=prompt.copy(), max_new_tokens=4,
                           frontend=frontend))
        eng._admit()
        k_row = np.asarray(eng.cache.pools[0]["b0"]["cross"]["k"][0, 0])
        slot = eng.slots[0]
        ctx = slot.req.context()
        chunk = np.concatenate([ctx, np.zeros(8 - len(ctx), np.int32)])
        table = eng.cache.table_array([slot.req.id])
        logits, eng.cache.pools = raw_prefill(
            eng.params, eng.cache.pools, jnp.asarray(chunk[None, :]),
            jnp.asarray([0], jnp.int32), jnp.asarray(table),
            jnp.asarray([len(ctx)], jnp.int32),
            jnp.asarray([slot.idx], jnp.int32))
        return k_row, np.asarray(logits)

    k_row, with_fe = logits_after_admit(fe)
    # slot 0's cross-K rows equal projecting the encoder output directly
    from repro.models import blocks as B
    enc_out = T.encode_frontend(params, TINY_ENCDEC, jnp.asarray(fe))[0]
    cfg = B.attn_cfg_for(TINY_ENCDEC, causal=False, use_rope=False)
    x0 = jax.tree.map(lambda t: t[0], params["segments"][0]["b0"]["xattn"])
    k_ref = L.dense(x0["wk"], enc_out).reshape(enc_len, cfg.n_kv_heads,
                                               cfg.head_dim)
    np.testing.assert_allclose(k_row, np.asarray(k_ref), rtol=1e-5,
                               atol=1e-5)
    _, text_only = logits_after_admit(None)
    assert np.abs(with_fe - text_only).max() > 0.1   # decoder reads the rows


# ---------------------------------------------------------------------------
# submit-time validation
# ---------------------------------------------------------------------------

def test_submit_rejects_duplicate_ids_and_empty_prompts():
    """Regression: block tables are keyed by request id, so a duplicate
    in-flight id silently shared (and corrupted) the live request's table;
    an empty prompt crashed the prefill with a KeyError.  Both must be
    rejected at submit; a finished id may be reused."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    eng.submit(Request(id=7, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2))
    with pytest.raises(ValueError, match="already in flight"):
        eng.submit(Request(id=7, prompt=np.arange(1, 5, dtype=np.int32),
                           max_new_tokens=2))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(id=8, prompt=np.array([], np.int32)))
    eng.run_until_drained()
    eng.submit(Request(id=7, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2))         # id free again after finish
    eng.run_until_drained()
    assert len(eng.completed) == 2


def test_submit_rejects_zero_max_new_tokens():
    """Regression: a max_new_tokens=0 request still generated one token —
    the prefill path unconditionally samples after the final chunk.  Policy:
    reject at submit (consistently enforced for the Server shim too, which
    delegates here)."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.submit(Request(id=1, prompt=np.arange(1, 5, dtype=np.int32),
                               max_new_tokens=bad))
    assert not eng.has_work                   # nothing was enqueued


def test_request_is_input_only_and_resubmittable():
    """v2 semantics: the engine never mutates a Request (results come back
    as RequestOutput), so a finished Request object may be resubmitted
    verbatim — the v1 recycled-object hazard (stale out_tokens re-prefilled
    as context, stale _sched_seq jumping the FCFS queue) cannot exist."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    req = Request(id=0, prompt=np.arange(1, 5, dtype=np.int32),
                  max_new_tokens=2)
    eng.submit(req)
    eng.run_until_drained()
    assert not hasattr(req, "out_tokens") and not hasattr(req, "done")
    assert req.__dict__.get("_sched_seq") is None   # no bookkeeping stuck on
    eng.submit(req)                                 # same object, second pass
    eng.run_until_drained()
    assert len(eng.completed) == 2
    a, b = eng.completed
    assert a.request_id == b.request_id == 0
    assert a.token_ids == b.token_ids               # deterministic greedy


# ---------------------------------------------------------------------------
# numerics regressions
# ---------------------------------------------------------------------------

def test_short_prompt_mamba2_handoff():
    """Regression: a prompt shorter than d_conv-1 used to under-fill the
    conv buffer at the prefill->decode handoff (xr[:, -K:, :] yields < K
    rows).  A 1-token prompt must decode, and greedily continuing from a
    2-token prompt must reproduce the same stream (exact handoff state)."""
    mesh = make_host_mesh()
    params = _params_for(TINY_SSM)

    def serve(prompt, max_new):
        eng = ContinuousBatchingEngine(TINY_SSM, params, mesh, slots=1,
                                       max_len=32, block_size=4,
                                       prefill_chunk=4)
        eng.submit(Request(id=0, prompt=prompt, max_new_tokens=max_new))
        eng.run_until_drained()
        return eng.completed[0].token_ids

    first = serve(np.array([5], np.int32), 6)
    assert len(first) == 6
    cont = serve(np.array([5, first[0]], np.int32), 5)
    assert cont == first[1:]


def test_paged_attention_overrun_diverts_to_null_block():
    """Regression: a write past a request's block-table capacity used to be
    clamped into its *last* block, corrupting live KV.  Overrun writes must
    land in the null block and leave every live block (and prior-token
    logits) bit-identical."""
    cfg = L.AttnConfig(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16)
    p = L.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    BS, NB = 4, 6
    pool = L.init_paged_attention_cache(cfg, NB, BS, jnp.float32)
    xa = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    ta = jnp.asarray([[1, 2]], jnp.int32)          # capacity: 8 tokens
    _, pool = L.paged_attention(p, cfg, xa, cache=pool,
                                positions=jnp.array([0]), block_tables=ta)
    out1, pool1 = L.paged_attention(p, cfg, xa[:, -1:], cache=pool,
                                    positions=jnp.array([7]),
                                    block_tables=ta)
    # another request writes OUT of table: position 9 -> logical block 2
    xb = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 32))
    _, pool2 = L.paged_attention(p, cfg, xb, cache=pool1,
                                 positions=jnp.array([9]),
                                 block_tables=jnp.asarray([[3, 4]],
                                                          jnp.int32))
    perturbed = [b for b in range(NB)
                 if not np.array_equal(np.asarray(pool1["k"][b]),
                                       np.asarray(pool2["k"][b]))]
    assert perturbed in ([], [0])                  # only the null block
    out2, _ = L.paged_attention(p, cfg, xa[:, -1:], cache=pool2,
                                positions=jnp.array([7]), block_tables=ta)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))


def test_sinusoidal_odd_d_model():
    """Regression: odd d_model used to raise a shape error (floor(d/2) cos
    columns assigned ceil(d/2) values)."""
    for d in (5, 7, 64):
        pe = T.sinusoidal_at(jnp.arange(6), d)
        assert pe.shape == (6, d)
    # even path unchanged: interleaved sin/cos
    pe = T.sinusoidal_at(jnp.arange(4), 6)
    np.testing.assert_allclose(np.asarray(pe[:, 0]),
                               np.sin(np.arange(4, dtype=np.float32)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# multi-host decode (ROADMAP precondition (b))
# ---------------------------------------------------------------------------

needs_8_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(run by the serving-multihost CI job)")


@needs_8_devices
def test_multihost_decode_parity_and_cache_placement():
    """Sharded serving proof on an 8-device (data=4, model=2) host mesh:
    greedy decode stays token-identical to the single-device wave goldens,
    and every paged/slot-state pool actually lands on the axes its
    SchedulePlan.paged_cache_specs() declares (at least one pool leaf
    genuinely sharded over `model`, not everything silently replicated)."""
    from jax.sharding import NamedSharding
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sharded_leaves = 0
    for scenario, kw in [("tiny/base", dict(block_size=4, prefill_chunk=3)),
                         ("hybrid/base", dict(block_size=4, prefill_chunk=4)),
                         ("mla/base", dict(block_size=4, prefill_chunk=3))]:
        arch, reqs, slots, max_len = scenario_requests(scenario)
        eng = ContinuousBatchingEngine(arch, _params_for(arch), mesh,
                                       slots=slots, max_len=max_len, **kw)
        specs = eng.plan.paged_cache_specs()
        pool_leaves = jax.tree.leaves(eng.cache.pools)
        spec_leaves = jax.tree.leaves(specs)
        assert len(pool_leaves) == len(spec_leaves)
        for leaf, spec in zip(pool_leaves, spec_leaves):
            want = NamedSharding(mesh, spec)
            assert leaf.sharding.is_equivalent_to(want, leaf.ndim), \
                (scenario, spec, leaf.sharding)
            if any(ax is not None for ax in spec):
                sharded_leaves += 1
        for rid, prompt, max_new in reqs:
            eng.submit(Request(id=rid, prompt=prompt.copy(),
                               max_new_tokens=max_new))
        eng.run_until_drained()
        got = {o.request_id: o.token_ids for o in eng.completed}
        assert got == load_goldens(scenario), scenario
    assert sharded_leaves > 0


@needs_8_devices
def test_multihost_parity_under_preemption():
    """Recompute-preemption on the sharded mesh: release/re-admit must not
    perturb pool placement or greedy outputs."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    arch, reqs, slots, max_len = scenario_requests("hybrid/preempt")
    eng = ContinuousBatchingEngine(arch, _params_for(arch), mesh,
                                   slots=slots, max_len=max_len,
                                   block_size=4, num_blocks=8,
                                   prefill_chunk=8)
    for rid, prompt, max_new in reqs:
        eng.submit(Request(id=rid, prompt=prompt.copy(),
                           max_new_tokens=max_new))
    eng.run_until_drained()
    assert {o.request_id: o.token_ids for o in eng.completed} \
        == load_goldens("hybrid/preempt")
    assert eng.metrics.preemptions > 0


# ---------------------------------------------------------------------------
# the wave Server compatibility shim
# ---------------------------------------------------------------------------

def test_server_shim_delegates_to_engine():
    """runtime.server.Server is a deprecation shim: same API, every token
    now decoded by the continuous engine — outputs must match the pinned
    pre-shim wave goldens."""
    from repro.runtime.server import Request as WaveRequest, Server
    mesh = make_host_mesh()
    arch, reqs, slots, max_len = scenario_requests("tiny/base")
    with pytest.deprecated_call():
        srv = Server(arch, _params_for(arch), mesh, slots=slots,
                     max_len=max_len)
    legacy = [WaveRequest(id=rid, prompt=p.copy(), max_new_tokens=mn)
              for rid, p, mn in reqs]
    for r in legacy:
        srv.submit(r)
    srv.run_until_drained()
    got = {r.id: r.out_tokens for r in srv.completed}
    assert got == load_goldens("tiny/base")
    assert all(r.done for r in legacy)        # caller's objects mutated
    assert srv.decode_steps > 0
    # the engine's validation applies through the shim
    with pytest.raises(ValueError, match="max_new_tokens"):
        srv.submit(WaveRequest(id=99,
                               prompt=np.arange(1, 5, dtype=np.int32),
                               max_new_tokens=0))


def test_server_shim_serves_formerly_excluded_archs():
    """zamba2-shaped and whisper-shaped configs now serve through the shim
    (they were the wave path's last reason to exist)."""
    from repro.runtime.server import Request as WaveRequest, Server
    mesh = make_host_mesh()
    for scenario in ("shared/base", "encdec/base"):
        arch, reqs, slots, max_len = scenario_requests(scenario)
        with pytest.deprecated_call():
            srv = Server(arch, _params_for(arch), mesh, slots=slots,
                         max_len=max_len)
        for rid, p, mn in reqs:
            srv.submit(WaveRequest(id=rid, prompt=p.copy(),
                                   max_new_tokens=mn))
        srv.run_until_drained()
        got = {r.id: r.out_tokens for r in srv.completed}
        assert got == load_goldens(scenario), scenario


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_json_report():
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.5)
    m.on_first_token(0, now=9.9)              # resumed request: TTFT kept
    m.on_step(queue_depth=1, busy_slots=1, slots=2)
    m.on_finish(0, n_tokens=3, now=1.5)
    rep = json.loads(m.to_json(engine="continuous"))
    assert rep["engine"] == "continuous"
    assert rep["completed"] == 1 and rep["total_tokens"] == 3
    assert rep["in_flight"] == 0
    assert rep["requests"][0]["ttft_s"] == pytest.approx(0.5)
    assert rep["requests"][0]["tpot_s"] == pytest.approx(0.5)  # 1.0s / 2
    assert rep["tokens_per_sec"] == pytest.approx(2.0)         # 3 tok / 1.5s
    assert rep["slot_occupancy_mean"] == pytest.approx(0.5)
    for key in ("ttft_mean_s", "tpot_mean_s", "queue_depth_max",
                "preemptions", "decode_steps"):
        assert key in rep


def test_metrics_preempted_request_keeps_original_ttft():
    """A preempted-then-finished request reports the TTFT of its FIRST
    first-token, not the resume's — preemption may not launder latency."""
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.4)
    m.on_preempt(0)
    m.on_first_token(0, now=5.0)              # re-prefill samples again
    m.on_finish(0, n_tokens=6, now=6.0)
    rep = m.request_report(0)
    assert rep["ttft_s"] == pytest.approx(0.4)
    assert m.preemptions == 1
    # TPOT spans first token -> finish: (6.0 - 0.4) / (6 - 1)
    assert rep["tpot_s"] == pytest.approx(5.6 / 5)


def test_metrics_single_token_request_tpot():
    """n_tokens=1 has no post-first-token decode: TPOT must not divide by
    zero, and equals the (zero-length) decode span."""
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.3)
    m.on_finish(0, n_tokens=1, now=0.3)
    rep = m.request_report(0)
    assert rep["tpot_s"] == pytest.approx(0.0)
    assert rep["ttft_s"] == pytest.approx(0.3)


def test_metrics_in_flight_requests_report_none_not_negative():
    """Regression: request_report defaulted missing timestamps to 0.0, so a
    submitted-not-started request reported ttft_s = -submit_t (large and
    negative) and a started-not-finished one a negative tpot_s.  Missing
    lifecycle points must yield None, and summary() means must skip them."""
    m = ServingMetrics()
    m.on_submit(0, now=100.0)                 # submitted, no first token yet
    rep = m.request_report(0)
    assert rep["ttft_s"] is None and rep["tpot_s"] is None
    m.on_submit(1, now=100.0)                 # started, not finished
    m.on_first_token(1, now=100.5)
    rep = m.request_report(1)
    assert rep["ttft_s"] == pytest.approx(0.5)
    assert rep["tpot_s"] is None
    # an id never submitted at all
    rep = m.request_report(99)
    assert rep["ttft_s"] is None and rep["tpot_s"] is None
    # summary stays total: latencies that exist are aggregated (request 1's
    # TTFT is known even though it hasn't finished), missing ones are
    # skipped rather than fabricated
    m.on_submit(2, now=101.0)
    m.on_first_token(2, now=101.2)
    m.on_finish(2, n_tokens=3, now=102.2)
    s = m.summary()
    assert s["ttft_mean_s"] == pytest.approx((0.5 + 0.2) / 2)
    assert s["tpot_mean_s"] == pytest.approx(0.5)
    assert s["in_flight"] == 2                # requests 0 and 1 still going


def test_metrics_block_utilization_and_prefix_hit_rate():
    """Cache pressure is sampled per step (block_utilization_mean/max) and
    prefix-cache admission matches aggregate into prefix_hit_rate."""
    m = ServingMetrics()
    m.on_step(0, 1, 2, block_utilization=0.25)
    m.on_step(0, 2, 2, block_utilization=0.75)
    m.on_step(0, 2, 2)                        # engines without a sample
    m.on_prefix_match(12, 16)
    m.on_prefix_match(0, 8)
    s = m.summary()
    assert s["block_utilization_mean"] == pytest.approx(0.5)
    assert s["block_utilization_max"] == pytest.approx(0.75)
    assert s["prefix_hit_rate"] == pytest.approx(12 / 24)
    assert ServingMetrics().summary()["prefix_hit_rate"] == 0.0


def test_engine_samples_block_utilization():
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    eng.submit(Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=4))
    eng.run_until_drained()
    s = eng.metrics.summary()
    assert eng.metrics.block_utilization.count == s["engine_steps"]
    assert s["block_utilization_max"] > 0.0


def test_run_until_drained_raises_instead_of_spinning():
    """A wedged engine (work queued, nothing running, admission refusing
    forever) must raise after max_idle_steps, not spin silently."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(
        TINY, _params_for(TINY), mesh, slots=2, max_len=64, block_size=4,
        prefill_chunk=8,
        scheduler=RequestScheduler(max_tokens_in_flight=64))
    eng.submit(Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=4))
    # simulate a leaked budget: admission is refused forever while the
    # queue stays non-empty and every slot is idle
    eng.scheduler._in_flight_tokens = 64
    with pytest.raises(RuntimeError, match="no progress"):
        eng.run_until_drained(max_idle_steps=10)
    # a healthy engine drains fine under the same guard
    eng.scheduler._in_flight_tokens = 0
    eng.run_until_drained(max_idle_steps=10)
    assert len(eng.completed) == 1


def test_metrics_summary_on_empty_and_partial_runs():
    """summary() must be total (no ZeroDivision / max-of-empty) on a fresh
    collector and on a run with submitted-but-unfinished requests."""
    m = ServingMetrics()
    s = m.summary()
    assert s["completed"] == 0 and s["total_tokens"] == 0
    # "no data" is None, not a 0.0 that reads as infinitely-fast/empty
    assert s["tokens_per_sec"] is None and s["ttft_max_s"] is None
    assert s["queue_depth_max"] is None and s["requests"] == []
    # partial: one finished, one still in flight — BOTH must appear in the
    # report (in-flight ids used to vanish because requests iterated
    # finish_t only), with the unfinished one counted as in_flight and its
    # latencies None
    m.on_submit(0, now=0.0)
    m.on_submit(1, now=0.0)
    m.on_first_token(0, now=0.2)
    m.on_finish(0, n_tokens=2, now=0.5)
    s = m.summary()
    assert s["completed"] == 1 and s["in_flight"] == 1
    assert [r["id"] for r in s["requests"]] == [0, 1]
    assert s["requests"][1]["ttft_s"] is None
    assert s["requests"][1]["tpot_s"] is None
    assert s["total_tokens"] == 2
    # means stay unpolluted by the in-flight request's None latencies
    assert s["ttft_mean_s"] == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# generation API v2: SamplingParams, seeded stochastic decode, stop
# conditions, typed RequestOutput, generate/stream/on_token
# ---------------------------------------------------------------------------

def test_sampling_params_validated_at_submit():
    """Malformed decode controls must be rejected at submit (with the
    request id in the error), never reach a jitted step, and leave the
    engine empty."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    bad = [SamplingParams(temperature=-0.5),
           SamplingParams(temperature=float("nan")),
           SamplingParams(top_k=-1),
           SamplingParams(top_k=TINY.vocab + 1),
           SamplingParams(top_p=0.0),
           SamplingParams(top_p=1.5),
           SamplingParams(seed=-1),
           SamplingParams(seed=2 ** 32),
           SamplingParams(stop_token_ids=(TINY.vocab,)),
           SamplingParams(stop_token_ids=(-3,))]
    for sp in bad:
        with pytest.raises(ValueError, match="request 5"):
            eng.submit(Request(id=5, prompt=np.arange(1, 5, dtype=np.int32),
                               sampling=sp))
    assert not eng.has_work
    # the same checks are usable standalone
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=2.0).validate()
    SamplingParams(temperature=0.7, top_k=10, top_p=0.9, seed=3,
                   stop_token_ids=(1, 2), logprobs=True).validate(TINY.vocab)


def test_temperature_zero_ignores_other_knobs_and_matches_goldens():
    """temperature=0 through explicit SamplingParams lowers to exact argmax
    regardless of top_k/top_p/seed — bit parity with the greedy goldens,
    including under forced preemption."""
    mesh = make_host_mesh()
    sp = SamplingParams(temperature=0.0, top_k=3, top_p=0.4, seed=1234)
    for scenario, kw in [("tiny/base", dict(block_size=4, prefill_chunk=3)),
                         ("hybrid/preempt", dict(block_size=4, num_blocks=8,
                                                 prefill_chunk=8)),
                         ("mla/preempt", dict(block_size=4, num_blocks=8,
                                              prefill_chunk=8))]:
        eng, got = _run_scenario(scenario, mesh, sampling=sp, **kw)
        assert got == load_goldens(scenario), scenario
        if scenario.endswith("preempt"):
            assert eng.metrics.preemptions > 0


def test_top_k_one_is_greedy_at_any_temperature():
    """End-to-end mask check: top_k=1 collapses the candidate set to the
    argmax, so even a hot temperature must reproduce the greedy goldens."""
    mesh = make_host_mesh()
    sp = SamplingParams(temperature=1.5, top_k=1, seed=7)
    _, got = _run_scenario("tiny/base", mesh, sampling=sp,
                           block_size=4, prefill_chunk=3)
    assert got == load_goldens("tiny/base")


def test_sampled_decode_deterministic_and_seed_sensitive():
    """Same seed => bit-identical reruns; different seed => a different
    stream (vocab 256, 8 tokens — collision odds are negligible); logprobs
    are per-token, finite and <= 0."""
    mesh = make_host_mesh()

    def run(seed):
        eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh,
                                       slots=2, max_len=64, block_size=4,
                                       prefill_chunk=3)
        sp = SamplingParams(temperature=0.9, top_k=50, top_p=0.9, seed=seed,
                            logprobs=True)
        return eng.generate([Request(id=0,
                                     prompt=np.arange(1, 9, dtype=np.int32),
                                     max_new_tokens=8, sampling=sp)])[0]

    a, b, c = run(123), run(123), run(321)
    assert a.token_ids == b.token_ids
    assert a.logprobs == b.logprobs
    assert a.token_ids != c.token_ids
    assert a.finish_reason == "length" and a.n_tokens == 8
    assert len(a.logprobs) == 8
    assert all(np.isfinite(lp) and lp <= 0 for lp in a.logprobs)
    # greedy requests don't carry logprobs unless asked
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=3)
    out = eng.generate([Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                                max_new_tokens=4)])[0]
    assert out.logprobs is None and out.prompt_len == 8


def test_sampled_determinism_under_forced_preemption():
    """The acceptance property: a seeded temperature>0 run is bit-identical
    with and without forced recompute-preemption of the sampling requests —
    keys derive from (seed, absolute position) only, so a preempted
    request's re-prefill regenerates exactly the tokens it lost."""
    mesh = make_host_mesh()

    def run(**kw):
        eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh,
                                       slots=2, max_len=64, prefill_chunk=8,
                                       block_size=4, **kw)
        reqs = [Request(id=i, prompt=np.arange(1, 9, dtype=np.int32) + i,
                        max_new_tokens=8,
                        sampling=SamplingParams(temperature=0.8, top_p=0.95,
                                                seed=100 + i))
                for i in range(4)]
        return eng, {o.request_id: o.token_ids for o in eng.generate(reqs)}

    eng_ample, ample = run()
    eng_tight, tight = run(num_blocks=8)         # forces preemption
    assert eng_ample.metrics.preemptions == 0
    assert eng_tight.metrics.preemptions > 0
    assert tight == ample


def test_sampled_preemption_rematches_prefix_cache_blocks():
    """With share_prefix, a preempted sampling request must re-match its
    own retired blocks at re-admission — only possible because the
    regenerated tokens are identical, keeping the block hash chain
    stable."""
    mesh = make_host_mesh()

    def run(**kw):
        eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh,
                                       slots=2, max_len=64, prefill_chunk=8,
                                       block_size=4, share_prefix=True, **kw)
        reqs = [Request(id=i, prompt=np.arange(1, 9, dtype=np.int32) + i,
                        max_new_tokens=8,
                        sampling=SamplingParams(temperature=0.8, seed=7 + i))
                for i in range(4)]
        return eng, {o.request_id: o.token_ids for o in eng.generate(reqs)}

    eng_tight, tight = run(num_blocks=8)
    eng_ample, ample = run()
    assert eng_tight.metrics.preemptions > 0
    assert eng_tight.cache.prefix_stats()["hit_tokens"] > 0
    assert tight == ample


def test_sampled_neighbor_does_not_perturb_greedy_requests():
    """Per-slot parameter isolation: a hot-temperature request sharing the
    batch (and fighting for the same blocks) must not change its greedy
    neighbors' tokens — their outputs are position-pure functions of their
    own context and must still match the goldens."""
    mesh = make_host_mesh()
    arch, reqs, slots, max_len = scenario_requests("tiny/base")
    eng = ContinuousBatchingEngine(arch, _params_for(arch), mesh,
                                   slots=slots, max_len=max_len,
                                   block_size=4, prefill_chunk=3)
    outs = eng.generate([
        Request(id=rid, prompt=prompt.copy(), max_new_tokens=max_new,
                sampling=(SamplingParams(temperature=1.2, seed=5)
                          if rid == 1 else SamplingParams()))
        for rid, prompt, max_new in reqs])
    want = load_goldens("tiny/base")
    for o in outs:
        if o.request_id == 1:
            assert o.token_ids != want[1]        # it really sampled
        else:
            assert o.token_ids == want[o.request_id]


def test_stop_token_finishes_with_reason_and_budget_release():
    """Sampling a stop token finishes the request with
    finish_reason="stop" (the stop token is the last entry of token_ids),
    releases its cache blocks AND its scheduler token-budget charge — a
    budget sized for one request must admit the next one only because the
    stop cut the first short."""
    mesh = make_host_mesh()
    want = load_goldens("tiny/base")[0]          # greedy stream for prompt 0
    stop_tok = want[2]
    sched = RequestScheduler(max_tokens_in_flight=14)   # one 8+6 request
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=3,
                                   scheduler=sched)
    prompt = np.arange(1, 9, dtype=np.int32)
    outs = eng.generate([
        Request(id=0, prompt=prompt.copy(), max_new_tokens=6,
                sampling=SamplingParams(stop_token_ids=(stop_tok,))),
        Request(id=1, prompt=prompt.copy(), max_new_tokens=6)])
    assert outs[0].finish_reason == "stop"
    assert outs[0].token_ids == want[:3]         # truncated at the stop hit
    assert outs[1].finish_reason == "length"
    assert outs[1].token_ids == want             # same prompt, full stream
    assert sched._in_flight_tokens == 0          # charges fully released
    assert eng.cache.allocator.num_used == 0
    # a stop token the stream never samples is inert
    eng2 = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                    max_len=64, block_size=4, prefill_chunk=3)
    out = eng2.generate([Request(
        id=0, prompt=prompt.copy(), max_new_tokens=6,
        sampling=SamplingParams(stop_token_ids=(stop_tok + 1,)))])[0]
    assert out.finish_reason == "length" and out.token_ids == want


def test_stop_token_on_first_token_finishes_in_prefill():
    """A stop token sampled as the very first token finishes the request
    straight out of prefill — it never enters decode."""
    mesh = make_host_mesh()
    want = load_goldens("tiny/base")[0]
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=3)
    out = eng.generate([Request(
        id=0, prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=6,
        sampling=SamplingParams(stop_token_ids=(want[0],)))])[0]
    assert out.finish_reason == "stop" and out.token_ids == want[:1]
    assert eng.metrics.decode_steps == 0


def test_top_p_mask_keeps_mass_and_never_empties():
    """Property test for the nucleus mask: over random logit rows and
    top_p values, the kept set (finite entries) is never empty, its
    probability mass is >= top_p, and it is minimal — dropping its least
    probable member would fall below top_p."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        b, v = 8, 64
        logits = jnp.asarray(rng.normal(0, 3, size=(b, v)), jnp.float32)
        top_p = jnp.asarray(rng.uniform(0.05, 1.0, size=(b,)), jnp.float32)
        masked = np.asarray(apply_top_p(logits, top_p))
        probs = np.asarray(jax.nn.softmax(logits, axis=-1))
        for i in range(b):
            kept = np.isfinite(masked[i])
            assert kept.any()                       # never empty
            mass = probs[i][kept].sum()
            assert mass >= float(top_p[i]) - 1e-6   # >= p mass kept
            if kept.sum() > 1:                      # minimal
                assert mass - probs[i][kept].min() < float(top_p[i]) + 1e-6
    # top_p = 1.0 keeps the whole (finite) vocabulary
    full = np.asarray(apply_top_p(jnp.zeros((1, 8)), jnp.ones((1,))))
    assert np.isfinite(full).all()


def test_top_k_mask_keeps_exactly_k():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)  # no ties a.s.
    for k, want in [(1, 1), (5, 5), (32, 32), (0, 32)]:   # 0 disables
        masked = np.asarray(apply_top_k(logits, jnp.full((4,), k, jnp.int32)))
        assert (np.isfinite(masked).sum(axis=-1) == want).all()
        # the survivors are the k largest
        for i in range(4):
            kept = set(np.flatnonzero(np.isfinite(masked[i])))
            top = set(np.argsort(np.asarray(logits[i]))[-(k or 32):])
            assert kept == top
    # top-p composes after top-k: mass is renormalized over the survivors
    masked = apply_top_k(logits, jnp.full((4,), 4, jnp.int32))
    both = np.asarray(apply_top_p(masked, jnp.full((4,), 0.5, jnp.float32)))
    assert (np.isfinite(both).sum(axis=-1) <= 4).all()
    assert (np.isfinite(both).sum(axis=-1) >= 1).all()


def test_generate_returns_outputs_in_submission_order():
    """generate() orders results by the request list, not by finish order
    (the short request finishes long before the 20-token one)."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    prompt = np.arange(1, 9, dtype=np.int32)
    outs = eng.generate([
        Request(id=10, prompt=prompt.copy(), max_new_tokens=20),
        Request(id=11, prompt=prompt.copy(), max_new_tokens=2)])
    assert [o.request_id for o in outs] == [10, 11]
    assert [o.n_tokens for o in outs] == [20, 2]
    # finish order on the engine's completed list was the reverse
    assert [o.request_id for o in eng.completed] == [11, 10]


def test_stream_and_on_token_fire_per_sampled_token():
    """stream() yields (request_id, token) pairs in sampling order and
    composes with a caller-installed on_token; the reassembled streams
    equal the final RequestOutputs."""
    mesh = make_host_mesh()
    cb: list = []
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=3,
                                   on_token=lambda rid, tok: cb.append((rid,
                                                                        tok)))
    prompt = np.arange(1, 9, dtype=np.int32)
    reqs = [Request(id=i, prompt=prompt.copy() + i, max_new_tokens=4)
            for i in range(3)]
    pairs = list(eng.stream(reqs))
    assert pairs == cb                      # tap preserved the user callback
    assert eng.on_token is not None         # and restored it afterwards
    streams: dict = {}
    for rid, tok in pairs:
        streams.setdefault(rid, []).append(tok)
    assert streams == {o.request_id: o.token_ids for o in eng.completed}
    assert len(eng.completed) == 3


def test_engine_clock_injection_keeps_latencies_coherent():
    """Satellite regression: submit used to accept a synthetic `now` while
    _prefill_chunk/_finish stamped real perf_counter() times, fabricating
    TTFTs of ~perf_counter magnitude.  With the injected clock every
    lifecycle stamp shares one time source."""
    mesh = make_host_mesh()
    t = {"now": 1000.0}

    def clock():
        t["now"] += 1.0                      # one tick per lifecycle stamp
        return t["now"]

    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8,
                                   clock=clock)
    eng.submit(Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=4))    # stamped by the fake clock too
    eng.run_until_drained()
    out = eng.completed[0]
    rep = eng.metrics.request_report(0)
    # every latency is a small positive number of fake ticks — mixing in a
    # real perf_counter() would make TTFT ~1e3 negative or ~1e5 positive
    assert 0 < rep["ttft_s"] < 100 and 0 < rep["tpot_s"] < 100
    assert out.ttft_s == rep["ttft_s"] and out.tpot_s == rep["tpot_s"]
    s = eng.metrics.summary()
    assert 0 < s["ttft_mean_s"] < 100
    assert s["in_flight"] == 0


def test_request_output_latency_joined_from_metrics():
    """RequestOutput carries the same TTFT/TPOT the metrics report — one
    join at finish time, no second bookkeeping path."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    outs = eng.generate([Request(id=i,
                                 prompt=np.arange(1, 9, dtype=np.int32),
                                 max_new_tokens=3) for i in [4, 9]])
    for o in outs:
        rep = eng.metrics.request_report(o.request_id)
        assert o.ttft_s == rep["ttft_s"] is not None
        assert o.tpot_s == rep["tpot_s"] is not None
        assert o.prompt_len == 8 and o.n_tokens == 3
        assert isinstance(o, RequestOutput)


def test_metrics_id_reuse_starts_a_fresh_lifecycle():
    """Review regression: a reused request id (finished request
    resubmitted) must not inherit the previous run's first-token stamp —
    first-write-wins on_first_token would otherwise fabricate a NEGATIVE
    TTFT (old first token < new submit)."""
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.5)
    m.on_finish(0, n_tokens=3, now=1.0)
    m.on_submit(0, now=10.0)                  # same id, second lifecycle
    rep = m.request_report(0)
    assert rep["ttft_s"] is None              # stale stamps cleared
    assert m.summary()["in_flight"] == 1
    m.on_first_token(0, now=10.5)
    m.on_first_token(0, now=12.0)             # preemption-resume: kept
    m.on_finish(0, n_tokens=2, now=11.0)
    rep = m.request_report(0)
    assert rep["ttft_s"] == pytest.approx(0.5)
    assert rep["n_tokens"] == 2


def test_resubmitted_request_reports_fresh_latency():
    """End-to-end twin of the metrics regression: the second serve of the
    same Request object reports its own (positive) TTFT, not one computed
    against the first run's stamps."""
    mesh = make_host_mesh()
    t = {"now": 0.0}

    def clock():
        t["now"] += 1.0
        return t["now"]

    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8,
                                   clock=clock)
    req = Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                  max_new_tokens=3)
    eng.submit(req)
    eng.run_until_drained()
    eng.submit(req)
    eng.run_until_drained()
    first, second = eng.completed
    assert second.token_ids == first.token_ids
    assert second.ttft_s is not None and second.ttft_s > 0
    assert second.tpot_s is not None and second.tpot_s > 0


def test_stream_submits_eagerly_before_iteration():
    """Review regression: stream() must put its requests in flight when
    called, not at first next() — a caller who drains the engine some
    other way would otherwise find their requests were silently never
    submitted."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    it = eng.stream([Request(id=i, prompt=np.arange(1, 9, dtype=np.int32),
                             max_new_tokens=2) for i in range(2)])
    assert eng.has_work                       # submitted without iterating
    eng.run_until_drained()                   # drained out of band
    assert len(eng.completed) == 2
    assert list(it) == []                     # iterator finds nothing left


def test_sampling_params_accept_numpy_scalars():
    """Review regression: token ids sliced from prompt arrays are np.int32
    (and temperatures may be np.float32) — validate must accept numpy
    scalars, and an np.int32 stop id must actually terminate the stream."""
    prompt = np.arange(1, 9, dtype=np.int32)
    SamplingParams(temperature=np.float32(0.8), top_k=np.int32(5),
                   top_p=np.float64(0.9), seed=np.int64(3),
                   stop_token_ids=(prompt[-1],)).validate(TINY.vocab)
    with pytest.raises(ValueError, match="outside the vocabulary"):
        SamplingParams(stop_token_ids=(np.int32(TINY.vocab),)) \
            .validate(TINY.vocab)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p="0.9").validate()   # TypeError-proof
    mesh = make_host_mesh()
    want = load_goldens("tiny/base")[0]
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=3)
    out = eng.generate([Request(
        id=0, prompt=prompt.copy(), max_new_tokens=6,
        sampling=SamplingParams(stop_token_ids=(np.int32(want[2]),)))])[0]
    assert out.finish_reason == "stop" and out.token_ids == want[:3]


def test_metrics_aggregates_survive_id_reuse():
    """Review regression: resetting a reused id's lifecycle stamps must not
    deflate engine-lifetime aggregates — completions, token totals and the
    throughput span accumulate across lifecycles."""
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.5)
    m.on_finish(0, n_tokens=3, now=1.0)
    m.on_submit(0, now=10.0)                  # reuse
    m.on_first_token(0, now=10.5)
    m.on_finish(0, n_tokens=2, now=11.0)
    s = m.summary()
    assert s["completed"] == 2                # both lifecycles counted
    assert s["total_tokens"] == 5
    # span covers first submit -> last finish: 5 tokens / 11s
    assert s["tokens_per_sec"] == pytest.approx(5 / 11.0)
    assert s["in_flight"] == 0


def test_generate_validates_whole_batch_before_submitting():
    """Review regression: generate() must vet every request (including
    intra-batch duplicate ids) before putting ANY in flight — a malformed
    entry mid-list used to leave its predecessors running with their
    outputs unreturned."""
    mesh = make_host_mesh()
    eng = ContinuousBatchingEngine(TINY, _params_for(TINY), mesh, slots=2,
                                   max_len=64, block_size=4, prefill_chunk=8)
    ok = Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                 max_new_tokens=2)
    bad = Request(id=1, prompt=np.arange(1, 9, dtype=np.int32),
                  sampling=SamplingParams(top_p=0.0))
    with pytest.raises(ValueError, match="request 1"):
        eng.generate([ok, bad])
    assert not eng.has_work                   # ok was NOT left in flight
    dup = Request(id=0, prompt=np.arange(1, 9, dtype=np.int32),
                  max_new_tokens=2)
    with pytest.raises(ValueError, match="appears twice"):
        eng.generate([ok, dup])
    assert not eng.has_work
    assert len(eng.generate([ok])) == 1       # engine still healthy
