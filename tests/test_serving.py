"""Continuous-batching serving subsystem tests: paged-cache invariants,
scheduler admission/preemption policy, and greedy-decode parity between the
continuous engine and the wave Server baseline."""
import json

import jax
import numpy as np
import pytest

from repro.configs.base import ArchConfig, Segment, ShapeSpec, SSMSpec
from repro.core.asa import AdaptiveScheduler
from repro.launch.mesh import make_host_mesh, mesh_shape_of
from repro.models import transformer as T
from repro.runtime.server import Request as WaveRequest, Server
from repro.serving import (BlockAllocator, ContinuousBatchingEngine,
                           PagedKVCache, Request, RequestScheduler,
                           ServingMetrics)
from repro.serving.paged_cache import NULL_BLOCK, PagedCacheConfig, blocks_for

TINY = ArchConfig(name="tiny-serve", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  pattern=(Segment(("attn",), 2),), dtype="float32",
                  param_dtype="float32")

TINY_SSM = ArchConfig(name="tiny-ssm", family="ssm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
                      ssm=SSMSpec(d_state=16, head_dim=16, chunk=16),
                      pattern=(Segment(("mamba2",), 2),), dtype="float32",
                      param_dtype="float32")


# ---------------------------------------------------------------------------
# paged cache
# ---------------------------------------------------------------------------

def test_block_allocator_invariants():
    a = BlockAllocator(8)                     # blocks 1..7 usable
    assert a.num_free == 7
    got = a.alloc(3)
    assert len(got) == 3 and NULL_BLOCK not in got
    assert a.num_free == 4 and a.num_used == 3
    # all-or-nothing: over-ask leaves state untouched
    assert a.alloc(5) is None
    assert a.num_free == 4
    a.free(got[:2])
    assert a.num_free == 6
    with pytest.raises(ValueError):           # double free
        a.free(got[:1])
    with pytest.raises(ValueError):           # null block is never freeable
        a.free([NULL_BLOCK])
    # freed blocks are reused
    again = a.alloc(6)
    assert again is not None and set(got[:2]) <= set(again)


def test_paged_cache_reserve_release_reuse():
    cache = PagedKVCache(TINY, PagedCacheConfig(block_size=4, num_blocks=9,
                                                max_blocks_per_seq=4),
                         dtype=np.float32)
    assert cache.reserve(0, 10)               # 3 blocks
    assert cache.allocator.num_used == 3
    assert cache.reserve(0, 12)               # same 3 blocks suffice
    assert cache.allocator.num_used == 3
    assert cache.reserve(0, 13)               # grows by one
    assert cache.allocator.num_used == 4
    assert cache.reserve(1, 16)               # 4 more -> pool full (8 usable)
    assert not cache.reserve(2, 1)            # OOM, state unchanged
    assert 2 not in cache.tables
    cache.release(0)
    assert cache.allocator.num_used == 4
    assert cache.reserve(2, 16)               # reuses request 0's blocks
    row = cache.table_row(2)
    assert row.shape == (4,) and NULL_BLOCK not in row
    assert (cache.table_row(None) == NULL_BLOCK).all()


def test_blocks_for():
    assert blocks_for(1, 4) == 1
    assert blocks_for(4, 4) == 1
    assert blocks_for(5, 4) == 2


def test_paged_cache_specs_match_pool_tree():
    mesh = make_host_mesh()
    plan = AdaptiveScheduler(faithful=False).plan(
        TINY, ShapeSpec("serve", 64, 2, "decode"), mesh_shape_of(mesh))
    pools = T.init_paged_cache(TINY, 8, 4, np.float32)
    specs = plan.paged_cache_specs()
    assert jax.tree.structure(pools) == jax.tree.structure(specs)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _req(i, plen=8, max_new=4, priority=0):
    return Request(id=i, prompt=np.arange(1, plen + 1, dtype=np.int32),
                   max_new_tokens=max_new, priority=priority)


def test_scheduler_fcfs_within_priority_class():
    s = RequestScheduler()
    for i in range(3):
        s.submit(_req(i))
    urgent = _req(99, priority=-1)
    s.submit(urgent)
    order = [s.next_admission().id for _ in range(4)]
    assert order == [99, 0, 1, 2]


def test_scheduler_token_budget_blocks_admission():
    s = RequestScheduler(max_tokens_in_flight=30)
    s.submit(_req(0, plen=8, max_new=4))      # footprint 12
    s.submit(_req(1, plen=8, max_new=4))
    s.submit(_req(2, plen=8, max_new=4))
    a, b = s.next_admission(), s.next_admission()
    assert a.id == 0 and b.id == 1
    assert s.next_admission() is None         # 24 + 12 > 30
    s.on_finish(a)
    assert s.next_admission().id == 2
    with pytest.raises(ValueError):           # can never be admitted
        s.submit(_req(3, plen=40, max_new=4))


def test_scheduler_preemption_victim_and_requeue_order():
    s = RequestScheduler()
    for i in range(3):
        s.submit(_req(i))
    running = [s.next_admission() for _ in range(2)]
    running[0].out_tokens = [1, 2, 3]         # longest-running
    running[1].out_tokens = [1]
    victim = s.pick_preemption_victim(running)
    assert victim.id == 0
    s.preempt(victim)
    # preempted request keeps its original arrival seq: head of its class
    assert s.next_admission().id == 0
    # priority dominates generated length
    hi = _req(7, priority=-1); hi.out_tokens = [1, 2, 3, 4]
    assert s.pick_preemption_victim([hi, running[1]]).id == running[1].id


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _wave_outputs(params, mesh, prompts, max_new):
    srv = Server(TINY, params, mesh, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        srv.submit(WaveRequest(id=i, prompt=p.copy(), max_new_tokens=max_new))
    srv.run_until_drained()
    return {r.id: r.out_tokens for r in srv.completed}


def test_continuous_engine_greedy_parity_with_wave():
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(5)]
    wave = _wave_outputs(params, mesh, prompts, max_new=6)

    # chunked prefill (chunk 3 < prompt 8) + slot churn (5 reqs, 2 slots)
    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=64,
                                   block_size=4, prefill_chunk=3)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=6))
    eng.run_until_drained()
    cont = {r.id: r.out_tokens for r in eng.completed}
    assert cont == wave                       # token-for-token
    assert eng.metrics.summary()["completed"] == 5
    assert eng.cache.allocator.num_used == 0  # every block returned


def test_continuous_engine_parity_under_preemption():
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(4)]
    wave = _wave_outputs(params, mesh, prompts, max_new=8)

    # 7 usable blocks * 4 tokens < 2 slots * 16 tokens -> cache pressure
    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=64,
                                   block_size=4, num_blocks=8,
                                   prefill_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=8))
    eng.run_until_drained()
    cont = {r.id: r.out_tokens for r in eng.completed}
    assert cont == wave                       # recompute-preemption is exact
    assert eng.metrics.preemptions > 0
    assert eng.cache.allocator.num_used == 0


def test_parity_with_multiple_victims_in_one_step():
    """Regression: a slot preempted as a victim for an earlier slot's block
    grab must be skipped by the rest of that decode step (slot.req is None).
    4 decoding slots x 2 blocks each > 6 usable blocks forces it."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 17, dtype=np.int32) + i for i in range(6)]
    srv = Server(TINY, params, mesh, slots=4, max_len=64)
    for i, p in enumerate(prompts):
        srv.submit(WaveRequest(id=i, prompt=p.copy(), max_new_tokens=8))
    srv.run_until_drained()
    wave = {r.id: r.out_tokens for r in srv.completed}

    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=4, max_len=64,
                                   block_size=16, num_blocks=7,
                                   prefill_chunk=16)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(), max_new_tokens=8))
    eng.run_until_drained()
    assert {r.id: r.out_tokens for r in eng.completed} == wave
    assert eng.metrics.preemptions > 0


def test_parity_with_mixed_max_new_tokens():
    """Regression: the wave Server's decode bound must follow the *active*
    requests — with mixed max_new a finished slot 0 used to let longer
    requests decode past max_len into a clamped (corrupting) cache write.
    Both engines must truncate the long request identically."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(2)]
    max_news = [2, 20]                        # 8 + 20 > max_len=12
    srv = Server(TINY, params, mesh, slots=2, max_len=12)
    for i, p in enumerate(prompts):
        srv.submit(WaveRequest(id=i, prompt=p.copy(),
                               max_new_tokens=max_news[i]))
    srv.run_until_drained()
    wave = {r.id: r.out_tokens for r in srv.completed}
    assert len(wave[1]) <= 12 - 8             # truncated at max_len

    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=12,
                                   block_size=4, prefill_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p.copy(),
                           max_new_tokens=max_news[i]))
    eng.run_until_drained()
    assert {r.id: r.out_tokens for r in eng.completed} == wave


def test_prefill_serves_oldest_request_first():
    """Regression: chunked prefill must advance the oldest admitted request
    (scheduler FCFS seq), not the lowest slot index."""
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    eng = ContinuousBatchingEngine(TINY, params, mesh, slots=2, max_len=64,
                                   block_size=4, prefill_chunk=2)
    older, newer = _req(0, plen=8), _req(1, plen=8)
    eng.submit(older)
    eng.submit(newer)
    eng._admit()
    # simulate slot churn: the older request ends up in the *higher* slot
    eng.slots[0], eng.slots[1] = eng.slots[1], eng.slots[0]
    assert eng.slots[0].req is newer and eng.slots[1].req is older
    eng._prefill_chunk()
    assert eng.slots[1].prefill_pos == 2      # older advanced
    assert eng.slots[0].prefill_pos == 0      # newer waits


def test_engine_rejects_non_attention_arch():
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY_SSM)
    with pytest.raises(ValueError, match="wave|Server|attention"):
        ContinuousBatchingEngine(TINY_SSM, params, mesh)


def test_metrics_json_report():
    m = ServingMetrics()
    m.on_submit(0, now=0.0)
    m.on_first_token(0, now=0.5)
    m.on_first_token(0, now=9.9)              # resumed request: TTFT kept
    m.on_step(queue_depth=1, busy_slots=1, slots=2)
    m.on_finish(0, n_tokens=3, now=1.5)
    rep = json.loads(m.to_json(engine="continuous"))
    assert rep["engine"] == "continuous"
    assert rep["completed"] == 1 and rep["total_tokens"] == 3
    assert rep["requests"][0]["ttft_s"] == pytest.approx(0.5)
    assert rep["requests"][0]["tpot_s"] == pytest.approx(0.5)  # 1.0s / 2
    assert rep["tokens_per_sec"] == pytest.approx(2.0)         # 3 tok / 1.5s
    assert rep["slot_occupancy_mean"] == pytest.approx(0.5)
    for key in ("ttft_mean_s", "tpot_mean_s", "queue_depth_max",
                "preemptions", "decode_steps"):
        assert key in rep
