"""Sharding-layer invariants for every assigned arch x strategy:
spec trees mirror param trees exactly and every spec divides its dim."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.core import components as C
from repro.core import sharding as SH
from repro.core.costmodel import MeshShape
from repro.core.strategy import Strategy, UNIFORM_STRATEGIES
from repro.models import transformer as T

MESHES = [MeshShape(16, 16), MeshShape(16, 16, pod=2)]
SIZES = {"data": 16, "model": 16, "pod": 2}


def _check_divisible(spec, shape, where):
    for i, ax in enumerate(spec):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = 1
        for a in axes:
            total *= SIZES[a]
        assert shape[i] % total == 0, (where, spec, shape)


@pytest.mark.parametrize("name", sorted(ARCHS))
@pytest.mark.parametrize("strategy", [Strategy.DP, Strategy.MP, Strategy.HP,
                                      Strategy.FS])
def test_param_specs_mirror_and_divide(name, strategy):
    arch = ARCHS[name]
    aparams = C.abstract_params(arch)
    comps = C.components_for_shape(arch,
        __import__("repro.configs.base", fromlist=["SHAPES"]).SHAPES["train_4k"])
    assignment = {c.name: strategy for c in comps}
    for mesh in MESHES:
        specs = SH.param_specs(arch, assignment, mesh)
        # same tree structure
        assert jax.tree.structure(specs) == jax.tree.structure(aparams)
        flat_p = jax.tree.leaves(aparams)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape), (name, spec, leaf.shape)
            _check_divisible(spec, leaf.shape, name)


@pytest.mark.parametrize("name", ["qwen3-8b", "zamba2-2.7b",
                                  "deepseek-v3-671b", "whisper-medium",
                                  "llama-3.2-vision-90b"])
def test_cache_specs_mirror_cache_tree(name):
    import jax.numpy as jnp
    arch = ARCHS[name]
    from repro.configs.base import SHAPES
    comps = C.components_for_shape(arch, SHAPES["decode_32k"])
    assignment = {c.name: Strategy.MP for c in comps}
    mesh = MeshShape(16, 16)
    cache_sds = jax.eval_shape(
        lambda: T.init_cache(arch, 128, 256, jnp.bfloat16))
    specs = SH.cache_specs(arch, assignment, mesh, 128)
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
        == jax.tree.structure(cache_sds)
    for leaf, spec in zip(jax.tree.leaves(cache_sds),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        _check_divisible(spec, leaf.shape, name)


def test_batch_axes_fallbacks():
    ms = MeshShape(16, 16)
    assert SH.batch_axes(ms, 256) == "data"
    assert SH.batch_axes(ms, 1) is None
    assert SH.batch_axes(ms, 256, full=True) == ("data", "model")
    ms2 = MeshShape(16, 16, pod=2)
    assert SH.batch_axes(ms2, 256) == ("pod", "data")
    assert SH.batch_axes(ms2, 512, full=True) == ("pod", "data", "model")
