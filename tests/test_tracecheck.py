"""tracecheck (IR-level serving-step analysis) tests.

Three layers, mirroring tests/test_analysis.py for reprolint:

  * positive: the analyzers are clean over reference registry archs, and a
    real engine stays within the per-step compile budgets for every tiny
    serving family (the runtime recompile regression the trace-cache
    analyzer models statically);
  * mutation-injection: each of the five analyzers provably FIRES when its
    invariant is broken (un-donated cache, injected host callback, extra
    host-bound output, perturbed sharding declarations, zeroed cost
    tolerance, engine shape leak);
  * contracts: BENCH_static_costs.json schema validation, costmodel
    serving predictions against the committed bench rows, and the shared
    reprolint/tracecheck finding emitters (json / github formats).
"""
from __future__ import annotations

import io
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.analysis import ircost as IC
from repro.analysis import tracecheck as TC
from repro.analysis.lint import Finding, emit_findings
from repro.core import costmodel as CM
from repro.runtime import steps as ST
from serving_fixtures import ARCH_BY_KEY

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_static_costs.json"

# small geometry: every lowering in this file compiles in seconds
GEOM = IC.ServeGeom(slots=2, max_len=32, block_size=8, prefill_chunk=8)
MESH = TC.serve_mesh()

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the serving CI mesh) to distinguish shardings")


def _ctx(arch_or_name) -> TC.ArchContext:
    if isinstance(arch_or_name, str):
        return TC.ArchContext.for_arch(arch_or_name, GEOM, MESH)
    return TC.ArchContext(arch_or_name, GEOM, MESH)


# ---------------------------------------------------------------------------
# positive: clean over reference archs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-780m"])
def test_static_analyzers_clean_on_reference_archs(name):
    findings = TC.run_analyzers(
        [name], select=["donation", "host-transfer", "sharding",
                        "cost-drift"], geom=GEOM, mesh=MESH)
    assert findings == [], [f.format() for f in findings]


@pytest.mark.slow
def test_trace_cache_clean_on_tiny_arch():
    assert TC.check_trace_cache(_ctx(ARCH_BY_KEY["tiny"])) == []


# ---------------------------------------------------------------------------
# satellite: engine recompile regression — every tiny serving family stays
# within the tracecheck budgets over a drained mixed workload
# ---------------------------------------------------------------------------

def _drained_engine(arch):
    from repro.serving.engine import ContinuousBatchingEngine
    params = jax.jit(lambda k: IC.T.init_lm(k, arch))(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(arch, params, MESH, slots=2, max_len=48,
                                   block_size=4, num_blocks=13,
                                   prefill_chunk=8)
    eng.generate(TC._mixed_workload(_ctx(arch)))
    return eng


def _assert_within_budget(eng):
    jitted = {"paged_prefill": eng._prefill, "paged_decode": eng._decode}
    if eng._admit_slot_state is not None:
        jitted["slot_admit"] = eng._admit_slot_state
    for kind, fn in jitted.items():
        n = fn._cache_size()
        assert 1 <= n <= TC.TRACE_BUDGETS[kind], \
            f"{eng.arch.name}/{kind}: {n} trace signatures " \
            f"(budget {TC.TRACE_BUDGETS[kind]})"


@pytest.mark.parametrize("key", ["tiny", "hybrid", "mla"])
def test_engine_recompile_budget(key):
    _assert_within_budget(_drained_engine(ARCH_BY_KEY[key]))


@pytest.mark.slow
@pytest.mark.parametrize("key", ["ssm", "cross", "shared", "encdec"])
def test_engine_recompile_budget_all_families(key):
    _assert_within_budget(_drained_engine(ARCH_BY_KEY[key]))


# ---------------------------------------------------------------------------
# mutation-injection: every analyzer fires on its broken invariant
# ---------------------------------------------------------------------------

def _mutated(ctx, kind, fn=None, jit_kwargs=None, lower=True):
    """A LoweredStep whose jit deviates from the engine's construction."""
    real = fn or IC.build_step_fn(ctx.arch, kind)
    args = IC.step_arguments(ctx.arch, kind, ctx.geom)
    lowered = jax.jit(real, **(jit_kwargs or {})).lower(*args) if lower \
        else None
    return IC.LoweredStep(ctx.arch, kind, real, args, lowered)


def test_donation_analyzer_fires_on_undonated_cache():
    ctx = _ctx("qwen3-8b")
    bad = _mutated(ctx, "paged_decode")          # plain jit: donates nothing
    ctx.lowered = lambda kind, *, meshful: bad
    findings = TC.check_donation(ctx)
    assert any(f.rule == "donation" and "STEP_DONATION" in f.message
               for f in findings), [f.format() for f in findings]


def test_host_transfer_analyzer_fires_on_injected_callback():
    ctx = _ctx("qwen3-8b")
    real = IC.build_step_fn(ctx.arch, "paged_decode")

    def leaky(*args):
        out = real(*args)
        jax.debug.callback(lambda t: None, out[0])   # host round-trip
        return out

    bad = _mutated(ctx, "paged_decode", fn=leaky, lower=False)
    ctx.lowered = lambda kind, *, meshful: bad
    findings = TC.check_host_transfer(ctx)
    assert any(f.rule == "host-transfer" and "callback" in f.message
               for f in findings), [f.format() for f in findings]


def test_host_transfer_analyzer_fires_on_extra_output():
    ctx = _ctx("qwen3-8b")
    real = IC.build_step_fn(ctx.arch, "paged_decode")

    def chatty(*args):
        tok, logp, cache = real(*args)
        return tok, logp, cache, args[0]         # leaks params to host

    bad = _mutated(ctx, "paged_decode", fn=chatty, lower=False)
    ctx.lowered = lambda kind, *, meshful: bad
    findings = TC.check_host_transfer(ctx)
    assert any("sanctioned" in f.message for f in findings), \
        [f.format() for f in findings]


def test_sharding_analyzer_fires_on_spec_tree_drift():
    ctx = _ctx("qwen3-8b")

    class _Plan:
        def __init__(self, real):
            self._real = real

        def __getattr__(self, name):
            return getattr(self._real, name)

        def paged_cache_specs(self):
            specs = self._real.paged_cache_specs()
            mutated = [dict(seg) for seg in specs]
            first = next(iter(mutated[0]))
            mutated[0][first] = {"k": mutated[0][first]["k"]}   # drop "v"
            return mutated

    ctx._plan = _Plan(ctx.plan)
    findings = TC.check_sharding(ctx)
    assert any(f.rule == "sharding" for f in findings), \
        [f.format() for f in findings]


@multi_device
def test_sharding_analyzer_fires_on_replicated_pool():
    ctx = _ctx("qwen3-8b")
    from jax.sharding import PartitionSpec as P

    class _Plan:
        def __init__(self, real):
            self._real = real

        def __getattr__(self, name):
            return getattr(self._real, name)

        def paged_cache_specs(self):
            # declare every pool model-replicated: the compiled steps
            # (lowered against the REAL plan) shard kv heads over `model`,
            # so conformance must fail
            return jax.tree.map(lambda s: P(),
                                self._real.paged_cache_specs())

    real_plan = ctx.plan
    for kind in ctx.kinds():                      # lower with the real plan
        ctx.lowered(kind, meshful=True)
    ctx._plan = _Plan(real_plan)
    findings = TC.check_sharding(ctx)
    assert any(f.rule == "sharding" and "declares" in f.message
               for f in findings), [f.format() for f in findings]


def test_cost_drift_analyzer_fires_on_zero_tolerance(monkeypatch):
    ctx = _ctx("qwen3-8b")
    monkeypatch.setattr(CM, "SERVING_FLOPS_RTOL", 0.0)
    monkeypatch.setattr(CM, "SERVING_BYTES_RFACTOR", 1.0)
    findings = TC.check_cost_drift(ctx)
    assert any(f.rule == "cost-drift" for f in findings), \
        "XLA and the analytic model can never agree to 0 ULP — a zeroed " \
        "tolerance must fire"


@pytest.mark.slow
def test_trace_cache_analyzer_fires_on_shape_leak(monkeypatch):
    from repro.serving.engine import ContinuousBatchingEngine as CBE

    orig = CBE._prefill_chunk

    def leaky(self):
        ran = orig(self)
        if ran and not getattr(self, "_leaked", False):
            # one extra prefill at HALF the chunk width: the class of bug
            # where a caller stops padding and every distinct prompt tail
            # compiles its own executable
            self._leaked = True
            mbps = self.cache.cfg.max_blocks_per_seq
            _, _, self.cache.pools = self._prefill(
                self.params, self.cache.pools,
                jnp.zeros((1, self.prefill_chunk // 2), jnp.int32),
                jnp.zeros((1,), jnp.int32),
                jnp.zeros((1, mbps), jnp.int32),
                jnp.ones((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
                jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.int32),
                jnp.ones((1,), jnp.float32), jnp.zeros((1,), jnp.uint32))
        return ran

    monkeypatch.setattr(CBE, "_prefill_chunk", leaky)
    findings = TC.check_trace_cache(_ctx(ARCH_BY_KEY["tiny"]))
    assert any(f.rule == "trace-cache" and "trace signatures" in f.message
               for f in findings), [f.format() for f in findings]


# ---------------------------------------------------------------------------
# donation convention (satellite: one helper, no per-call-site tables)
# ---------------------------------------------------------------------------

def test_jit_step_owns_donation():
    with pytest.raises(ValueError, match="jit_step owns donate_argnums"):
        ST.jit_step("paged_decode", lambda p, c: (p, c),
                    donate_argnums=(0,))


def test_step_donation_covers_every_kind():
    assert set(ST.STEP_DONATION) == {"train", "prefill", "decode",
                                     "paged_prefill", "paged_decode",
                                     "slot_admit"}
    # params are never donated outside training
    for kind, argnums in ST.STEP_DONATION.items():
        if kind != "train":
            assert argnums == (1,), (kind, argnums)


# ---------------------------------------------------------------------------
# BENCH_static_costs.json: schema + costmodel cross-validation (satellite)
# ---------------------------------------------------------------------------

def _bench_doc():
    with open(BENCH_PATH) as f:
        return json.load(f)


def test_committed_bench_is_valid():
    errors = TC.validate_bench(_bench_doc())
    assert errors == []


def test_validate_bench_catches_corruption():
    doc = _bench_doc()
    assert TC.validate_bench({"rows": []})   # missing top-level keys
    broken = json.loads(json.dumps(doc))
    broken["rows"][0]["flops_rel_err"] = 9.9
    assert any("exceeds" in e for e in TC.validate_bench(broken))
    short = json.loads(json.dumps(doc))
    dropped = short["rows"].pop()
    assert any(dropped["arch"] in e for e in TC.validate_bench(short))


@pytest.mark.parametrize("name", ["qwen3-8b", "mamba2-780m"])
def test_costmodel_serving_predictions_match_bench(name):
    """core/costmodel.predict_serving_step must reproduce the committed
    predicted values exactly AND stay within the declared tolerance of the
    committed extracted FLOPs — the cost model is a checked serving input,
    not a free-floating estimate."""
    doc = _bench_doc()
    rows = {(r["arch"], r["step"]): r for r in doc["rows"]}
    arch = configs.reduce_for_smoke(configs.get_arch(name))
    for step in ("paged_prefill", "paged_decode"):
        row = rows[(arch.name, step)]
        pred = CM.predict_serving_step(
            arch, batch=row["batch"], new_tokens=row["new_tokens"],
            table_len=row["table_len"])
        assert pred["flops"] == pytest.approx(row["flops_predicted"],
                                              rel=1e-9)
        # same normalization as tracecheck.bench_row: drift relative to
        # the model's prediction
        rel = abs(pred["flops"] - row["flops_extracted"]) / \
            max(pred["flops"], 1.0)
        assert rel <= doc["tolerances"]["flops_rtol"], \
            f"{arch.name}/{step}: rel err {rel:.3f}"


# ---------------------------------------------------------------------------
# finding emitters (shared reprolint/tracecheck output formats)
# ---------------------------------------------------------------------------

_FINDINGS = [Finding("src/x.py", 3, 1, "clock-injection", "bad\nclock"),
             Finding("qwen3-8b-smoke/paged_decode", 0, 0, "donation",
                     "cache 50% undonated")]


def test_emit_findings_json_round_trips():
    buf = io.StringIO()
    emit_findings(_FINDINGS, "json", stream=buf)
    parsed = json.loads(buf.getvalue())
    assert [p["rule"] for p in parsed] == ["clock-injection", "donation"]
    assert parsed[0]["line"] == 3 and parsed[1]["path"].endswith("decode")


def test_emit_findings_github_annotations():
    buf = io.StringIO()
    emit_findings(_FINDINGS, "github", tool="tracecheck", stream=buf)
    lines = buf.getvalue().splitlines()
    assert lines[0].startswith("::error file=src/x.py,line=3,col=1,"
                               "title=tracecheck(clock-injection)::")
    assert "%0A" in lines[0] and "\n" not in lines[0][2:]
    assert "title=tracecheck(donation)" in lines[1]


def test_lint_cli_format_json(tmp_path, capsys):
    from repro.analysis.lint import main as lint_main
    (tmp_path / "serving").mkdir()               # clock-injection is scoped
    bad = tmp_path / "serving" / "bad.py"
    bad.write_text("import time\n\n"
                   "def submit(self, req):\n"
                   "    t = time.perf_counter()\n"
                   "    return t\n")
    rc = lint_main([str(bad), "--select", "clock-injection",
                    "--format", "json"])
    out = capsys.readouterr().out
    parsed = json.loads(out)                     # whole stdout is JSON
    assert rc == 1 and parsed \
        and parsed[0]["rule"] == "clock-injection"


def test_tracecheck_cli_plumbing(tmp_path, capsys):
    assert TC.main(["--list-analyzers"]) == 0
    out = capsys.readouterr().out
    for name in TC.ANALYZERS:
        assert name in out
    with pytest.raises(SystemExit):
        TC.main(["--select", "nope"])
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps({"rows": []}))
    assert TC.main(["--validate-bench", str(bench)]) == 1
    bench.write_text(BENCH_PATH.read_text())
    assert TC.main(["--validate-bench", str(bench)]) == 0
