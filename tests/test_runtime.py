"""Trainer / Server / monitor runtime tests (single-device CPU mesh)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Segment, ShapeSpec
from repro.core.profiler import StepMonitor
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.runtime.server import Request, Server
from repro.runtime.trainer import TrainConfig, Trainer

TINY = ArchConfig(name="tiny-rt", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  pattern=(Segment(("attn",), 2),), dtype="float32",
                  param_dtype="float32")
SHAPE = ShapeSpec("smoke", 32, 8, "train")


def test_trainer_end_to_end(tmp_path):
    mesh = make_host_mesh()
    tr = Trainer(TINY, SHAPE, mesh,
                 TrainConfig(lr=3e-3, warmup_steps=2, total_steps=40,
                             checkpoint_every=10),
                 checkpoint_dir=str(tmp_path / "ck"))
    params, opt_state = tr.init_state()
    data = SyntheticLM(TINY.vocab, 32, 8)
    params, opt_state, hist = tr.train(params, opt_state, data, steps=20)
    assert len(hist) == 20
    assert hist[-1]["loss"] < hist[0]["loss"]
    tr.ckpt.wait()
    assert tr.ckpt.latest_step() == 20


def test_trainer_restart_resumes(tmp_path):
    mesh = make_host_mesh()
    cfg = TrainConfig(lr=1e-3, checkpoint_every=5, total_steps=40)
    tr = Trainer(TINY, SHAPE, mesh, cfg, checkpoint_dir=str(tmp_path / "ck"))
    params, opt_state = tr.init_state()
    data = SyntheticLM(TINY.vocab, 32, 8)
    params, opt_state, _ = tr.train(params, opt_state, data, steps=10)
    tr.ckpt.wait()

    tr2 = Trainer(TINY, SHAPE, mesh, cfg, checkpoint_dir=str(tmp_path / "ck"))
    p2, o2 = tr2.init_state()
    p2, o2 = tr2.maybe_restore(p2, o2)
    assert tr2.step == 10
    a = np.concatenate([np.ravel(x) for x in jax.tree.leaves(params)])
    b = np.concatenate([np.ravel(x) for x in jax.tree.leaves(p2)])
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_step_monitor_triggers_on_drift():
    mon = StepMonitor(alpha=0.5, drift_threshold=0.2, min_steps=5)
    for _ in range(10):
        assert not mon.update(1.0)
    fired = any(mon.update(3.0) for _ in range(10))
    assert fired


def test_server_greedy_decode_matches_reference():
    mesh = make_host_mesh()
    params = T.init_lm(jax.random.PRNGKey(0), TINY)
    srv = Server(TINY, params, mesh, slots=2, max_len=64)
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(3)]
    for i, p in enumerate(prompts):
        srv.submit(Request(id=i, prompt=p, max_new_tokens=4))
    srv.run_until_drained()
    assert len(srv.completed) == 3
    # reference greedy decode with plain forward passes
    for req in srv.completed:
        ctx = list(req.prompt)
        for tok in req.out_tokens:
            logits = T.lm_apply(params, TINY,
                                jnp.asarray([ctx], jnp.int32)).logits
            expect = int(jnp.argmax(logits[0, -1, : TINY.vocab]))
            assert tok == expect, (req.id, ctx, tok, expect)
            ctx.append(tok)
