"""schedcheck — the model checker itself under test.

Two halves:

* the **clean gate**: every bundled config must exhaust its state space
  (fixpoint) with zero violations, and must actually exercise the paths
  it claims to (preemption, prefix re-match, partial-order pruning) —
  coverage assertions keep the gate from passing vacuously.

* **mutation injection**: seed one known bug class at a time into the
  real scheduler / cache (or the event model) and assert the checker
  catches it with the right property id and a minimized counterexample
  that ``replay_trace`` reproduces deterministically.  This is the
  evidence that a green schedcheck run means something — each detector
  is proven live against the failure mode it exists for.
"""
import subprocess
import sys

import pytest

from repro.analysis.schedcheck import (
    CONFIGS,
    CheckConfig,
    ControlPlaneModel,
    PROPERTIES,
    emit_replay,
    findings_from,
    main as schedcheck_main,
    replay_trace,
    run_config,
)
from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import RequestScheduler

# generous caps: a correct mutant run stays far below; a mutant that
# blows up the state space (e.g. unbounded counters) fails fast instead
# of hanging the suite
MUTANT_BOUNDS = dict(max_violations=100_000, max_states=60_000)


# ---------------------------------------------------------------------
# clean gate: the shipped matrix is exhaustive and violation-free
# ---------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_config_explores_clean_to_fixpoint(name):
    result = run_config(CONFIGS[name])
    assert result.fixpoint, f"{name}: state space not exhausted"
    assert result.ok, f"{name}: " + "\n".join(
        v.format() for v in result.violations)
    assert result.accepting > 0, f"{name}: no drained state reachable"
    assert result.states < 50_000, f"{name}: blow-up ({result.states})"


def test_tight_configs_actually_preempt():
    """Coverage, not correctness: the forced-preemption configs must
    execute preempt transitions or their OOM/eviction checking is
    vacuous."""
    for name in ("fcfs-tight", "preempt-rematch"):
        result = run_config(CONFIGS[name])
        assert result.event_counts.get("preempt", 0) > 0, name


def test_prefix_configs_actually_share():
    """share_prefix configs must see cache hits: a drained run of
    priority-prefix re-uses request 1's first block for requests 2/3."""
    for name in ("priority-prefix", "preempt-rematch"):
        cfg = CONFIGS[name]
        assert cfg.share_prefix
        result = run_config(cfg)
        assert result.ok and result.fixpoint, name


def test_wide_block_engages_partial_order_pruning():
    result = run_config(CONFIGS["wide-block"])
    assert result.pruned > 0, "sleep sets never pruned a transition"
    assert result.ok and result.fixpoint


def test_ample_config_reaches_stop_branches():
    result = run_config(CONFIGS["ample-stop"])
    assert result.ok and result.fixpoint
    # every event class except preempt is reachable with ample blocks
    for cls in ("submit", "admit", "prefill", "decode"):
        assert result.event_counts.get(cls, 0) > 0, cls


# ---------------------------------------------------------------------
# mutation injection: each detector class proven live
# ---------------------------------------------------------------------

class EvictLeakCache(PagedKVCache):
    """Seeded bug: eviction forgets to drop the index's refcount, so the
    evicted block is deindexed but never freed — a slow leak exactly on
    the OOM edge (``_evict_for`` only runs when ``reserve`` is short)."""

    def _evict_for(self, need: int) -> None:
        while self.allocator.num_free < need and self._lru:
            b, _ = self._lru.popitem(last=False)
            key = self._block_to_hash.pop(b)
            del self._hash_to_block[key]
            # BUG: missing self.allocator.decref(b)
            self.prefix_evictions += 1


class OverchargeScheduler(RequestScheduler):
    """Seeded bug: preemption re-queues the request without releasing
    its token-budget charge, stranding budget forever."""

    def preempt(self, req) -> None:
        self._enqueue(req)
        self.stats["preemptions"] += 1


class DroppingScheduler(RequestScheduler):
    """Seeded bug: preemption releases the budget but never re-enqueues
    the request — it silently vanishes from the system."""

    def preempt(self, req) -> None:
        self._release_budget(req)
        self.stats["preemptions"] += 1


def test_detects_leaked_block_on_eviction():
    result = run_config(CONFIGS["preempt-rematch"],
                        cache_cls=EvictLeakCache, **MUTANT_BOUNDS)
    kinds = {v.kind for v in result.violations}
    assert "invariant" in kinds, kinds
    first = min((v for v in result.violations if v.kind == "invariant"),
                key=lambda v: v.depth)
    # the counterexample replays deterministically against the mutant
    model = ControlPlaneModel(CONFIGS["preempt-rematch"],
                              cache_cls=EvictLeakCache)
    _state, violations = replay_trace(CONFIGS["preempt-rematch"],
                                      first.trace, model=model)
    assert any(rule == "invariant" for _n, rule, _m in violations)
    # ...and the pristine implementation does NOT reproduce it
    _state, clean = replay_trace(CONFIGS["preempt-rematch"], first.trace)
    assert not any(rule == "invariant" for _n, rule, _m in clean)


def test_detects_budget_overcharge():
    result = run_config(CONFIGS["fcfs-tight"],
                        sched_cls=OverchargeScheduler, **MUTANT_BOUNDS)
    kinds = {v.kind for v in result.violations}
    assert "budget" in kinds, kinds
    first = min((v for v in result.violations if v.kind == "budget"),
                key=lambda v: v.depth)
    model = ControlPlaneModel(CONFIGS["fcfs-tight"],
                              sched_cls=OverchargeScheduler)
    _state, violations = replay_trace(CONFIGS["fcfs-tight"], first.trace,
                                      model=model)
    assert any(rule == "budget" for _n, rule, _m in violations)


def test_detects_lost_request():
    result = run_config(CONFIGS["fcfs-tight"],
                        sched_cls=DroppingScheduler, **MUTANT_BOUNDS)
    kinds = {v.kind for v in result.violations}
    # the dropped request violates conservation immediately and leaves
    # the system unable to drain (deadlock: nothing left to run)
    assert "conservation" in kinds, kinds
    assert "deadlock" in kinds, kinds
    first = min((v for v in result.violations
                 if v.kind == "conservation"), key=lambda v: v.depth)
    model = ControlPlaneModel(CONFIGS["fcfs-tight"],
                              sched_cls=DroppingScheduler)
    _state, violations = replay_trace(CONFIGS["fcfs-tight"], first.trace,
                                      model=model)
    assert any(rule == "conservation" for _n, rule, _m in violations)


LIVELOCK_CFG = CheckConfig(
    name="livelock-handoff",
    description="test-local: 1 slot, ample blocks, naive-fairness mutant",
    requests=((1, (3, 4), 2, 0), (2, (5, 6), 2, 0)),
    slots=1, block_size=2, num_blocks=9, max_len=8, prefill_chunk=4,
    max_tokens_in_flight=None, share_prefix=False,
    with_stop=False, nondet_victims=True)


class HandoffModel(ControlPlaneModel):
    """Seeded bug at the policy level: whenever work is queued and a
    slot is busy, the engine preempts instead of making progress — a
    naive immediate-handoff 'fairness' rule.  With one slot and two
    requests this is a finite admit/preempt ping-pong that never
    drains: the textbook admission livelock."""

    def enabled_events(self, state):
        events = super().enabled_events(state)
        sched = self._materialize(state)[0]
        busy = [i for i, s in enumerate(state.data["slots"])
                if s is not None]
        if sched.queue_depth > 0 and busy:
            events = [e for e in events
                      if e[0] not in ("prefill", "decode")]
            for i in busy:
                if ("preempt", i) not in events:
                    events.append(("preempt", i))
        return events


def test_detects_admission_livelock():
    result = run_config(LIVELOCK_CFG, model=HandoffModel(LIVELOCK_CFG),
                        **MUTANT_BOUNDS)
    assert result.fixpoint          # liveness is only checked at fixpoint
    kinds = {v.kind for v in result.violations}
    assert "livelock" in kinds, kinds
    # the witness is minimal: two submits put the system into the trap
    first = min((v for v in result.violations if v.kind == "livelock"),
                key=lambda v: v.depth)
    assert first.depth <= 4, first.trace


# ---------------------------------------------------------------------
# replay harness round trip
# ---------------------------------------------------------------------

def test_emit_replay_writes_runnable_regression(tmp_path):
    result = run_config(CONFIGS["preempt-rematch"],
                        cache_cls=EvictLeakCache, **MUTANT_BOUNDS)
    first = min((v for v in result.violations if v.kind == "invariant"),
                key=lambda v: v.depth)
    path = tmp_path / "test_replay_regression.py"
    emit_replay(str(path), CONFIGS["preempt-rematch"], first)
    src = path.read_text()
    assert "replay_trace" in src and "EXPECT_RULE = 'invariant'" in src
    # the generated module is valid, importable pytest code
    compile(src, str(path), "exec")
    # NOTE: running it would *fail* here — the seeded bug is not in the
    # shipped cache — which is exactly the point: emitted regressions
    # pin the violation until the fix lands, then keep it fixed.
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", str(path)],
        capture_output=True, text=True)
    assert proc.returncode != 0
    assert "no longer reproduces" in proc.stdout


def test_replay_on_clean_traces_is_silent():
    """Any trace the clean model can actually execute replays without a
    single safety report."""
    model = ControlPlaneModel(CONFIGS["ample-stop"])
    state = model.initial_state()
    trace = []
    for _ in range(12):
        events = model.enabled_events(state)
        if not events:
            break
        trace.append(events[0])
        state = model.apply(state, events[0])
    _state, violations = replay_trace(CONFIGS["ample-stop"], tuple(trace))
    assert violations == []


# ---------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------

def test_cli_clean_run_exits_zero(capsys):
    assert schedcheck_main(["wide-block"]) == 0
    err = capsys.readouterr().err
    assert "fixpoint" in err and "schedcheck: clean" in err


def test_cli_unknown_config_and_property_exit_two(capsys):
    assert schedcheck_main(["no-such-config"]) == 2
    assert schedcheck_main(["--select", "no-such-prop"]) == 2


def test_cli_list_flags(capsys):
    assert schedcheck_main(["--list-configs"]) == 0
    out = capsys.readouterr().out
    for name in CONFIGS:
        assert name in out
    assert schedcheck_main(["--list-properties"]) == 0
    out = capsys.readouterr().out
    for rule in PROPERTIES:
        assert rule in out


def test_cli_truncated_run_reports_not_fixpoint(capsys):
    assert schedcheck_main(["--max-states", "10", "wide-block"]) == 0
    assert "TRUNCATED" in capsys.readouterr().err


def test_findings_have_lint_shape():
    result = run_config(CONFIGS["fcfs-tight"],
                        sched_cls=DroppingScheduler, **MUTANT_BOUNDS)
    findings = findings_from(CONFIGS["fcfs-tight"], result)
    assert findings
    f = findings[0]
    assert f.path.startswith("fcfs-tight/") and f.rule in PROPERTIES
    assert "trace" in f.message
    only = findings_from(CONFIGS["fcfs-tight"], result,
                         select={"conservation"})
    assert only and all(f.rule == "conservation" for f in only)


# ---------------------------------------------------------------------
# unified front-end: python -m repro.analysis
# ---------------------------------------------------------------------

from repro.analysis.__main__ import main as analysis_main  # noqa: E402


def test_front_end_routes_select_to_owning_tool(capsys):
    # "no-bare-assert" is a lint rule; "budget" is a schedcheck property
    rc = analysis_main(["lint", "schedcheck",
                        "--select", "no-bare-assert,budget"])
    assert rc == 0
    err = capsys.readouterr().err
    assert "lint: clean" in err and "schedcheck: clean" in err


def test_front_end_rejects_unowned_check(capsys):
    assert analysis_main(["lint", "--select", "not-a-check"]) == 2
    assert "no tool owns" in capsys.readouterr().err


def test_front_end_rejects_unknown_tool(capsys):
    assert analysis_main(["lintcheck"]) == 2
    assert "unknown tool" in capsys.readouterr().err


def test_front_end_lists_tools_and_checks(capsys):
    assert analysis_main(["--list-tools"]) == 0
    out = capsys.readouterr().out
    for tool in ("lint", "tracecheck", "schedcheck"):
        assert tool in out
    assert analysis_main(["lint", "schedcheck", "--list-checks"]) == 0
    out = capsys.readouterr().out
    assert "lint:no-bare-assert" in out
    assert "schedcheck:livelock" in out


def test_front_end_json_is_one_document(tmp_path, capsys):
    import json as _json
    bad = tmp_path / "serving" / "bad.py"
    bad.parent.mkdir()
    bad.write_text("def f(x):\n    assert x\n    return x\n")
    rc = analysis_main(["lint", "--format", "json",
                        "--lint-paths", str(bad)])
    assert rc == 1
    doc = _json.loads(capsys.readouterr().out)
    assert doc and doc[0]["tool"] == "lint"
    assert doc[0]["rule"] == "no-bare-assert"
