"""Fault-tolerance integration: crash/restart determinism, elastic
re-planning, straggler-driven input reassignment under a live loop."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Segment, ShapeSpec
from repro.data import HostShardedLoader, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import TrainConfig, Trainer

TINY = ArchConfig(name="tiny-ft", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  pattern=(Segment(("attn",), 2),), dtype="float32",
                  param_dtype="float32")
SHAPE = ShapeSpec("ft", 32, 8, "train")


def test_crash_restart_reaches_same_state(tmp_path):
    """Train 12 steps with a checkpoint at 6; 'crash'; restart and replay
    6..12; final loss must match the uninterrupted run exactly (determinism
    of data offsets + exact state restore)."""
    mesh = make_host_mesh()
    cfg = TrainConfig(lr=1e-3, checkpoint_every=6, total_steps=24)

    # uninterrupted reference
    tr = Trainer(TINY, SHAPE, mesh, cfg, checkpoint_dir=str(tmp_path / "a"))
    p, o = tr.init_state()
    data = SyntheticLM(TINY.vocab, 32, 8)
    p, o, hist_ref = tr.train(p, o, data, steps=12)
    tr.ckpt.wait()

    # crashy run: 7 steps (checkpoint landed at 6), then abandon
    tr1 = Trainer(TINY, SHAPE, mesh, cfg, checkpoint_dir=str(tmp_path / "b"))
    p1, o1 = tr1.init_state()
    p1, o1, _ = tr1.train(p1, o1, SyntheticLM(TINY.vocab, 32, 8), steps=7)
    tr1.ckpt.wait()

    # restart from the step-6 checkpoint and replay to 12
    tr2 = Trainer(TINY, SHAPE, mesh, cfg, checkpoint_dir=str(tmp_path / "b"))
    p2, o2 = tr2.init_state()
    p2, o2 = tr2.maybe_restore(p2, o2)
    assert tr2.step == 6
    data2 = SyntheticLM(TINY.vocab, 32, 8).skip(tr2.data_offset)
    p2, o2, hist2 = tr2.train(p2, o2, data2, steps=6)

    a = np.concatenate([np.ravel(x) for x in jax.tree.leaves(p)])
    b = np.concatenate([np.ravel(x) for x in jax.tree.leaves(p2)])
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    assert abs(hist_ref[-1]["loss"] - hist2[-1]["loss"]) < 1e-6


def test_elastic_resize_preserves_state():
    """Trainer.resize re-plans on a new mesh and reshards live state; a
    same-size resize must be invisible to the trajectory, so the five
    post-resize losses match an uninterrupted 10-step run exactly."""
    mesh = make_host_mesh()
    tr0 = Trainer(TINY, SHAPE, mesh, TrainConfig(lr=1e-3, total_steps=40))
    p0, o0 = tr0.init_state()
    p0, o0, href = tr0.train(p0, o0, SyntheticLM(TINY.vocab, 32, 8), steps=10)

    tr = Trainer(TINY, SHAPE, mesh, TrainConfig(lr=1e-3, total_steps=40))
    p, o = tr.init_state()
    data = SyntheticLM(TINY.vocab, 32, 8)
    p, o, _ = tr.train(p, o, data, steps=5)
    p, o = tr.resize(make_host_mesh(), p, o)   # same size, full reshard path
    p, o, h2 = tr.train(p, o, data, steps=5)
    assert np.isfinite([m["loss"] for m in h2]).all()
    np.testing.assert_allclose([m["loss"] for m in h2],
                               [m["loss"] for m in href[5:]],
                               rtol=0, atol=0)


def test_straggler_reassignment_preserves_coverage():
    """After a host dies, the union of assigned shards across live hosts
    still covers every shard exactly once."""
    loaders = [HostShardedLoader(
        lambda shard, n: SyntheticLM(100, 8, 2, seed=shard),
        n_hosts=4, host_id=h, heartbeat_timeout_s=0.05) for h in range(4)]
    now = time.monotonic()
    for ld in loaders:
        for h in range(4):
            ld.heartbeat(h, now if h != 3 else now - 10)   # host 3 dies
    assignments = []
    for h in range(3):
        next(loaders[h])
        assignments += loaders[h].assigned
    assert sorted(assignments) == [0, 1, 2, 3]
