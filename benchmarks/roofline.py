"""Roofline analysis (deliverable g) — reads experiments/dryrun/*.json.

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = HLO_FLOPs / (chips x 197e12)        [bf16 peak]
  memory     = HLO_bytes / (chips x 819e9)         [HBM]
  collective = collective_bytes / (chips x 50e9)   [ICI per spec formula]

HLO_FLOPs: XLA's cost_analysis on the CPU backend does not scale loop bodies
by trip count (verified: ~150x under), so the compute/memory terms use the
analytic per-component model (core/components.py — the same math XLA emits:
matmul dims + attention + MoE capacity), with the lowering-accurate
adjustments: x3 fwd:bwd for training, x4/3 for full-remat recompute, and 2x
on attention scores for the XLA chunked fallback (the Pallas kernel removes
that — both variants reported).  collective_bytes IS parsed from the
compiled HLO (trip-count-aware; dryrun.parse_collectives), x chips for
fabric-total; ring all-reduce counts 2x bytes.

Also reported: MODEL_FLOPS = 6·N_active·D and the ratio to HLO_FLOPs
(useful-compute fraction), the dominant term, and a one-line lever.
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.core import components as C
from repro.core import hardware as HW

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
HWP = HW.TPU_V5E


def hlo_flops_analytic(arch_name: str, shape_name: str, *,
                       remat: str = "full", pallas_attention: bool = False,
                       microbatches: int = 1) -> float:
    """Global FLOPs per step as the current lowering executes them."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    comps = C.components_for_shape(arch, shape)
    total = 0.0
    for c in comps:
        f = c.total_flops_fwd
        if not pallas_attention and shape.kind != "decode" and \
                c.keys and "attn" in c.keys:
            # XLA chunked fallback computes full (not causal-half) scores
            f *= 2.0
        total += f
    if shape.kind == "train":
        total *= 3.0                          # bwd = 2x fwd
        if remat == "full":
            total *= 4.0 / 3.0                # recompute fwd in bwd
    return total


def hbm_bytes_analytic(arch_name: str, shape_name: str, *,
                       microbatches: int = 1, remat: str = "full") -> float:
    """Global HBM traffic per step (both directions, all chips)."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    comps = C.components_for_shape(arch, shape)
    total = 0.0
    train = shape.kind == "train"
    for c in comps:
        pb = c.total_params * 2               # bf16 resident params
        if train:
            # params read fwd + read bwd (+ recompute read) + grads write/read
            # + opt state read/write (approximated 4 bytes moments pass)
            total += pb * (3 if remat == "full" else 2) + \
                c.total_params * (4 + 8 + 8)
            # activation write+read per microbatch pass
            total += 2 * c.act_bytes * c.count * (2 if remat == "full" else 1)
        else:
            total += pb                       # weights read once per step
            total += 2 * c.kv_bytes * c.count  # cache read + write
            total += 2 * c.act_bytes * c.count
    return total


def load_cell(arch: str, shape: str, mesh: str, tag: str = "") -> dict | None:
    sfx = f"__{tag}" if tag else ""
    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh}{sfx}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def analyze_cell(arch: str, shape: str, mesh: str = "16_16",
                 tag: str = "") -> dict | None:
    rec = load_cell(arch, shape, mesh, tag)
    if rec is None:
        return None
    if rec.get("status") == "skipped":
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": "skipped", "reason": rec["reason"]}
    chips = 512 if mesh == "2_16_16" else 256
    mb = rec.get("microbatches", 1)
    flops = hlo_flops_analytic(arch, shape, microbatches=mb)
    bytes_hbm = hbm_bytes_analytic(arch, shape, microbatches=mb)
    coll = rec["collectives"]
    # ring all-reduce moves 2x bytes; others ~1x
    coll_bytes_dev = (2 * coll["all-reduce"]["bytes"]
                      + coll["all-gather"]["bytes"]
                      + coll["reduce-scatter"]["bytes"]
                      + coll["all-to-all"]["bytes"]
                      + coll["collective-permute"]["bytes"])
    coll_total = coll_bytes_dev * chips

    t_compute = flops / (chips * HWP.peak_flops)
    t_memory = bytes_hbm / (chips * HWP.hbm_bw)
    t_collective = coll_total / (chips * HWP.link_bw)
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    mf = rec.get("model_flops", 0.0)
    step_time = max(terms.values())          # overlap-optimistic bound
    mfu_bound = (mf / 3 * (3 if SHAPES[shape].kind == "train" else 1)
                 ) / (chips * HWP.peak_flops) / step_time if step_time else 0
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "status": "ok",
        "microbatches": mb, "method": rec.get("method"),
        "t_compute": t_compute, "t_memory": t_memory,
        "t_collective": t_collective, "dominant": dominant,
        "hlo_flops": flops, "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": t_compute / step_time if step_time else 0.0,
        "mem_analysis": rec.get("memory", {}),
        "collective_detail": {k: v for k, v in coll.items()
                              if isinstance(v, dict)},
    }


LEVERS = {
    "compute": "swap XLA chunked attention for the Pallas flash kernel "
               "(removes the 2x causal-score waste) / raise matmul efficiency",
    "memory": "decode is weight/cache-bound: quantize KV to int8 or raise "
              "batch to amortize weight reads",
    "collective": "reduce model-axis activation all-reduces: sequence-"
                  "parallel layout or coarser TP; overlap grad reduction "
                  "with backward",
}


def full_table(mesh: str = "16_16", tag: str = "") -> list[dict]:
    rows = []
    for a in ARCHS:
        for s in SHAPES:
            r = analyze_cell(a, s, mesh, tag)
            if r is not None:
                rows.append(r)
    return rows


def render_markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | mb | compute (s) | memory (s) | collective (s) "
           "| dominant | roofline frac | MODEL/HLO |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"skipped | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['microbatches']} "
            f"| {r['t_compute']:.3f} | {r['t_memory']:.3f} "
            f"| {r['t_collective']:.3f} | **{r['dominant']}** "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} |")
    return "\n".join(out)


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16_16"
    tag = sys.argv[2] if len(sys.argv) > 2 else ""
    rows = full_table(mesh, tag)
    print(render_markdown(rows))
    print()
    for r in rows:
        if r["status"] == "ok":
            print(f"{r['arch']} x {r['shape']}: dominant={r['dominant']} -> "
                  f"{LEVERS[r['dominant']]}")


if __name__ == "__main__":
    main()
